"""Streaming control service: event ingestion, drift detection, delta
solves.

The paper's schedulers are long-running services; this package is the
operational wrapper that makes ``BalanceController`` one.  See
docs/streaming_service.md for the runbook.
"""

from repro.service.drift import (DELTA, FULL, NOOP, DriftConfig,
                                 DriftDecision, DriftDetector)
from repro.service.events import (AdvisoryBatch, AppArrival, AppDeparture,
                                  CapacityUpdate, FaultSignal, LatencyDelta,
                                  ServiceEvent, TelemetryDelta)
from repro.service.loop import ServiceConfig, ServiceLoop, ServiceStepResult
from repro.service.shadow import DIRTY_REL, FleetShadow

__all__ = [
    "AdvisoryBatch",
    "AppArrival",
    "AppDeparture",
    "CapacityUpdate",
    "DELTA",
    "DIRTY_REL",
    "DriftConfig",
    "DriftDecision",
    "DriftDetector",
    "FaultSignal",
    "FleetShadow",
    "FULL",
    "LatencyDelta",
    "NOOP",
    "ServiceConfig",
    "ServiceEvent",
    "ServiceLoop",
    "ServiceStepResult",
    "TelemetryDelta",
]
