"""Typed event vocabulary for the streaming control service.

The paper's schedulers are always-on services fed by the fleet; everything
the controller used to learn through method calls (telemetry observations,
advisory schedules, admissions) is expressed here as a small closed set of
``ServiceEvent`` records.  The service loop (``service.loop``) drains them
into a fleet shadow state; the controller's ``ingest`` accepts the same
records directly — one vocabulary for both paths.

Dispatch is duck-typed on the ``kind`` class attribute (a short string):
``repro.core`` never imports this module, so the core controller can
ingest events without a core -> service dependency cycle.

Events are frozen: the loop stamps a global monotonic sequence number at
enqueue time *outside* the record (``service.loop``), and the shadow logs
the applied sequence per app — the basis of the no-drop / no-reorder
integrity contract fuzzed in tests/test_fuzz_scenarios.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

TELEMETRY = "telemetry"
CAPACITY = "capacity"
LATENCY = "latency"
ARRIVAL = "arrival"
DEPARTURE = "departure"
ADVISORIES = "advisories"
FAULT = "fault"


@dataclasses.dataclass(frozen=True)
class ServiceEvent:
    """Base record; concrete events override ``kind``."""

    kind = "event"


@dataclasses.dataclass(frozen=True)
class TelemetryDelta(ServiceEvent):
    """Fresh demand/task readings for a subset of apps.

    ``app_ids`` are global pool rows; ``demand`` is f32[K, R] and ``tasks``
    f32[K] aligned with them.  ``collected_at`` stamps when the readings
    were taken (the staleness the telemetry monitor scores).
    """

    kind = TELEMETRY
    app_ids: tuple
    demand: np.ndarray
    tasks: np.ndarray
    collected_at: int = 0


@dataclasses.dataclass(frozen=True)
class CapacityUpdate(ServiceEvent):
    """A structural change to the tier side of the world: capacity scales,
    task limits, SLO eligibility, or region latency.  ``None`` fields are
    unchanged.  Always a *full-solve* signal to the drift detector — shard
    boundaries and feasibility both move under it."""

    kind = CAPACITY
    capacity: Optional[np.ndarray] = None  # f32[T, R]
    task_limit: Optional[np.ndarray] = None  # f32[T]
    slo_allowed: Optional[np.ndarray] = None  # bool[T, S]
    region_latency: Optional[np.ndarray] = None  # f32[Rg, Rg]
    hosts_per_tier: Optional[np.ndarray] = None  # i32[T]


@dataclasses.dataclass(frozen=True)
class LatencyDelta(ServiceEvent):
    """Fresh region-pair latency estimates (the measured-latency control
    plane's p99 matrix, or the simulator's ground truth).

    Unlike folding latency into ``CapacityUpdate``, this is *not* a
    structural signal: capacities, limits and shard boundaries are all
    unchanged, so it must not force a full pass.  The shadow re-stages the
    matrix, marks the apps whose standing placement now breaches the
    latency budget dirty, and raises ``latency_breach`` — which enables
    the drift detector's *delta* branch over just those shards.
    ``budget_ms`` overrides the static region budget when the measured
    plane has calibrated per-pair budgets (``None`` = static contract).
    """

    kind = LATENCY
    region_latency: np.ndarray  # f32[Rg, Rg]
    collected_at: int = 0
    budget_ms: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class AppArrival(ServiceEvent):
    """One app joining the fleet (a pool row flipping live).

    ``tier`` is the placement decided by the frontend/admission path; -1
    asks the shadow to place greedily (most post-placement headroom among
    SLO-eligible tiers — the same rule as ``sim.harness.place_arrivals``).
    """

    kind = ARRIVAL
    app_id: int
    demand: np.ndarray  # f32[R]
    tasks: float
    slo: int
    criticality: float = 0.5
    tier: int = -1


@dataclasses.dataclass(frozen=True)
class AppDeparture(ServiceEvent):
    """One app leaving the fleet: its row goes inert (valid False, zero
    demand/tasks — the pad_problem convention)."""

    kind = DEPARTURE
    app_id: int


@dataclasses.dataclass(frozen=True)
class AdvisoryBatch(ServiceEvent):
    """A declared maintenance/demand schedule replacing the controller's
    advisory channel (a tuple of ``core.planner.Advisory``)."""

    kind = ADVISORIES
    advisories: tuple = ()
    horizon: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class FaultSignal(ServiceEvent):
    """An externally-declared control-plane fault window (a monitoring
    system paging the service).  While ``now < until`` the drift detector
    refuses *delta* solves — partial re-solves on suspect telemetry risk
    moving apps on stale shard views — and the controller folds
    ``severity`` into its composite health score."""

    kind = FAULT
    source: str
    until: int
    severity: float = 0.5  # health-score factor in [0, 1] while active
