"""Drift-triggered control: no-op / delta solve / full cooperate.

Lorenz et al. (arXiv 1602.03770) argue online reconfiguration must be
incremental and triggered by *observed drift*, not fixed cadence.  The
detector keeps a per-tier EWMA of worst-resource load fractions and a
baseline snapshot taken at the last solve; the divergence between the two
is the drift signal.  Per tick it answers one question — is this tick
worth a solve, and if so, how much of the fleet needs re-pricing?

Decision table (first match wins; see docs/streaming_service.md):

  ================================  ==========================
  signal                            action
  ================================  ==========================
  capacity/structural change        FULL  (shard boundaries move)
  advisory deadline in horizon      FULL  (planner steers the solver)
  stranded apps >= threshold        FULL  (feasibility, not balance)
  tier load > overload_full         FULL  (standing overload)
  d2b > full gate                   FULL  (standing imbalance)
  over-ideal > over gate            FULL  (tiers above ideal line)
  EWMA divergence > full_threshold  FULL  (fleet-wide drift)
  fault signal active               NOOP  (no delta on suspect data)
  dirty apps + divergence > delta   DELTA (dirty shards only)
  dirty apps + d2b > delta gate     DELTA (dirty shards only)
  arrivals/departures pending       DELTA (dirty shards only)
  latency-SLO breach + dirty apps   DELTA (dirty shards only)
  otherwise                         NOOP
  ================================  ==========================

The EWMA divergence is *relative* — it re-bases at every solve, so it
catches change, not standing state.  The standing-state signals are the
lockstep controller's own: the max tier load (overload) and the
difference-to-balance of the shadow incumbent (the Fig. 5 metric behind
``trigger_d2b``), so the service trigger polices the same quantity the
cadence policy did.  The d2b gates carry a *solver floor*: the d2b the
last applied solve left behind, margin added.  Imbalance the solver
demonstrably cannot remove (capacity heterogeneity, movement budget) must
not burn a full pass every tick; the floor decays per decision so a high
watermark from a transient peak re-probes instead of masking drift
forever.

A ``full_interval`` safety valve (None = off) forces a periodic full pass
so unmodeled cross-shard drift cannot accumulate forever.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

NOOP = "noop"
DELTA = "delta"
FULL = "full"


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    # EWMA weight of the newest tier-load sample.
    ewma_alpha: float = 0.35
    # Divergence (max over tiers of |ewma - baseline| load fraction) above
    # which a *delta* solve is worth pricing; above ``full_threshold`` the
    # imbalance is fleet-wide and only a full pass can chase it.
    delta_threshold: float = 0.02
    full_threshold: float = 0.12
    # Stranded-app count that forces a full pass (feasibility beats cost).
    stranded_full: int = 1
    # Max tier load fraction that is a standing overload (always FULL).
    overload_full: float = 1.0
    # Standing-imbalance gates on the shadow's difference-to-balance:
    # ``d2b_full`` matches the lockstep ``trigger_d2b`` default; the
    # effective gate is max(d2b_full, solver floor + floor_margin), and
    # the delta gate max(d2b_delta, solver floor + floor_margin / 2).
    d2b_full: float = 0.15
    d2b_delta: float = 0.08
    # Worst excess over the ideal utilization line that forces a full pass
    # (matches the lockstep ``trigger_over_ideal``), behind the same
    # solver-floor guard.
    over_ideal_full: float = 0.05
    floor_margin: float = 0.075
    floor_decay: float = 0.98
    # Safety valve: force a full pass every this many decisions (None off).
    full_interval: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class DriftDecision:
    action: str  # noop | delta | full
    reason: str
    divergence: float
    dirty_shards: tuple = ()


class DriftDetector:
    """Stateful drift scorer; one instance per service loop."""

    def __init__(self, config: DriftConfig = DriftConfig()):
        self.config = config
        self._ewma: Optional[np.ndarray] = None
        self._baseline: Optional[np.ndarray] = None
        self._floor = 0.0       # d2b the last applied solve left behind
        self._over_floor = 0.0  # over-ideal the last applied solve left
        self._since_full = 0
        self.fault_until = -1

    def note_fault(self, until: int) -> None:
        self.fault_until = max(self.fault_until, int(until))

    def note_solve(self, loads: np.ndarray, *, full: bool,
                   d2b: float = 0.0, over_ideal: float = 0.0) -> None:
        """A solve covered the fleet (full) or the dirty region (delta):
        re-base the drift baseline to the post-solve loads and remember
        the d2b / over-ideal the solver achieved (the floors for the
        standing gates)."""
        loads = np.asarray(loads, np.float64)
        self._baseline = loads.copy()
        self._ewma = loads.copy()
        if full:
            # Only a full pass measures the solver's best: a delta solve
            # is scoped (and shard-local), so its residual d2b must not
            # ratchet the standing gates upward.
            self._floor = float(d2b)
            self._over_floor = max(0.0, float(over_ideal))
            self._since_full = 0
        else:
            self._floor = min(self._floor, float(d2b))
            self._over_floor = min(self._over_floor,
                                   max(0.0, float(over_ideal)))

    def observe(self, loads: np.ndarray) -> float:
        """Fold this tick's tier loads into the EWMA; returns divergence."""
        loads = np.asarray(loads, np.float64)
        if self._ewma is None:
            self._ewma = loads.copy()
            self._baseline = loads.copy()
            return 0.0
        a = self.config.ewma_alpha
        self._ewma = (1.0 - a) * self._ewma + a * loads
        return float(np.abs(self._ewma - self._baseline).max())

    def decide(
        self,
        *,
        loads: np.ndarray,
        now: int,
        capacity_dirty: bool,
        outlook_active: bool,
        stranded: int,
        dirty_shards: tuple,
        pending_membership: bool,
        d2b: float = 0.0,
        over_ideal: float = -1.0,
        latency_breach: bool = False,
    ) -> DriftDecision:
        cfg = self.config
        loads = np.asarray(loads, np.float64)
        div = self.observe(loads)
        peak = float(loads.max()) if loads.size else 0.0
        self._floor *= cfg.floor_decay
        self._over_floor *= cfg.floor_decay
        full_gate = max(cfg.d2b_full, self._floor + cfg.floor_margin)
        delta_gate = max(cfg.d2b_delta, self._floor + cfg.floor_margin / 2)
        over_gate = max(cfg.over_ideal_full,
                        self._over_floor + cfg.floor_margin)
        self._since_full += 1

        def full(reason: str) -> DriftDecision:
            return DriftDecision(FULL, reason, div)

        if capacity_dirty:
            return full("capacity/structural change")
        if outlook_active:
            return full("advisory deadline inside planning horizon")
        if stranded >= cfg.stranded_full:
            return full(f"{stranded} stranded apps")
        if peak > cfg.overload_full:
            return full(f"tier load {peak:.3f} > {cfg.overload_full}")
        if d2b > full_gate:
            return full(f"d2b {d2b:.3f} > gate {full_gate:.3f}")
        if over_ideal > over_gate:
            return full(f"over-ideal {over_ideal:.3f} > gate "
                        f"{over_gate:.3f}")
        if div > cfg.full_threshold:
            return full(f"divergence {div:.3f} > {cfg.full_threshold}")
        if cfg.full_interval is not None and self._since_full >= cfg.full_interval:
            return full(f"full_interval {cfg.full_interval} elapsed")
        if now < self.fault_until:
            # Suspect telemetry: a partial re-solve could move apps on a
            # stale shard view.  Hold; the FULL triggers above still fire.
            return DriftDecision(NOOP, "fault signal active (delta held)", div)
        if dirty_shards and (d2b > delta_gate or pending_membership
                             or latency_breach):
            # The delta gate is d2b-driven, not divergence-driven: load
            # moving around while the fleet stays balanced is not worth a
            # solve, however fast it moves.  Divergence only forces the
            # hand at the FULL threshold above (fleet-wide change).  A
            # latency-SLO breach bypasses the d2b gate: the fleet may be
            # perfectly balanced while apps sit behind a degraded link.
            why = ("latency-SLO breach, " if latency_breach else "")
            return DriftDecision(
                DELTA,
                f"{why}divergence {div:.3f}, d2b {d2b:.3f}, "
                f"{len(dirty_shards)} dirty shards",
                div,
                tuple(dirty_shards),
            )
        return DriftDecision(
            NOOP, f"quiescent (divergence {div:.3f}, d2b {d2b:.3f})", div)
