"""Fleet shadow state: the service's materialized view of the world.

The ingestion queue drains here.  The shadow owns host-side staging copies
of the per-app arrays (demand, tasks, valid) and the tier-side geometry, so
applying an event is a few numpy writes — no jnp churn per event — and
``view(now)`` materializes a ``ClusterState`` only when the control loop
actually decides to look.

Dirty tracking is the delta solver's contract: an app is *dirty* when its
demand moved by more than ``dirty_rel`` (relative, worst resource) since
the last solve that covered it, or when it arrived/departed; the tier side
is a single ``capacity_dirty`` bit (structural changes always force a full
pass).  ``clean(app_ids)`` is called by the loop after a solve covered
those apps' shards.

Event-integrity bookkeeping: ``apply`` records the sequence number of
every event against each app it touched (``applied_seq``), in application
order.  The service loop's contract — no event dropped, no per-app
reordering — is asserted against this log in tests/test_fuzz_scenarios.py.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.hierarchy import RegionScheduler
from repro.core.levels import REGION_LATENCY_BUDGET_MS
from repro.core.telemetry import ClusterState
from repro.service import events as E

# Relative demand drift (worst resource) above which an app is dirty.
DIRTY_REL = 0.05


class FleetShadow:
    """Mutable observed-world state fed by ``ServiceEvent`` records."""

    def __init__(self, cluster: ClusterState, *, dirty_rel: float = DIRTY_REL):
        self._cluster = cluster
        p = cluster.problem
        self.dirty_rel = float(dirty_rel)
        self._demand = np.asarray(p.demand, np.float32).copy()
        self._tasks = np.asarray(p.tasks, np.float32).copy()
        self._valid = np.asarray(p.valid, bool).copy()
        self._slo = np.asarray(p.slo, np.int32).copy()
        self._crit = np.asarray(p.criticality, np.float32).copy()
        self._x0 = np.asarray(p.assignment0, np.int32).copy()
        self._capacity = np.asarray(p.capacity, np.float32).copy()
        self._task_limit = np.asarray(p.task_limit, np.float32).copy()
        self._slo_allowed = np.asarray(p.slo_allowed, bool).copy()
        self._region_latency = np.asarray(cluster.region_latency).copy()
        self._hosts = np.asarray(cluster.hosts_per_tier).copy()
        self._ideal = np.asarray(p.ideal_frac, np.float64).copy()
        self._ideal_t = np.asarray(p.ideal_task_frac, np.float64).copy()
        # Last-solved reference demand per app (dirty bits diff against it).
        self._ref_demand = self._demand.copy()
        self.dirty_apps: set[int] = set()
        self.capacity_dirty = False
        # Latest latency measurement found live apps over budget (enables
        # the drift detector's delta branch; cleared by a concluded solve
        # or a newer in-budget measurement).
        self.latency_breach = False
        self.collected_at = int(cluster.collected_at)
        # Integrity log: app id -> sequence numbers applied, in order.
        self.applied_seq: dict[int, list[int]] = {}
        self.events_applied = 0
        self._geometry_stale = False

    # -- event application ---------------------------------------------------
    def apply(self, event, seq: int) -> None:
        """Apply one event (dispatch on the duck-typed ``kind``)."""
        kind = getattr(event, "kind", None)
        if kind == E.TELEMETRY:
            self._apply_telemetry(event, seq)
        elif kind == E.CAPACITY:
            self._apply_capacity(event)
        elif kind == E.LATENCY:
            self._apply_latency(event, seq)
        elif kind == E.ARRIVAL:
            self._apply_arrival(event, seq)
        elif kind == E.DEPARTURE:
            self._apply_departure(event, seq)
        # ADVISORIES / FAULT carry no fleet state; the loop routes them to
        # the controller / drift detector.  Every kind counts as applied.
        self.events_applied += 1

    def _log(self, app_id: int, seq: int) -> None:
        self.applied_seq.setdefault(int(app_id), []).append(int(seq))

    def _apply_telemetry(self, ev, seq: int) -> None:
        ids = np.asarray(ev.app_ids, np.int64)
        dem = np.asarray(ev.demand, np.float32).reshape(ids.size, -1)
        tsk = np.asarray(ev.tasks, np.float32).reshape(ids.size)
        self._demand[ids] = dem
        self._tasks[ids] = tsk
        self.collected_at = max(self.collected_at, int(ev.collected_at))
        ref = self._ref_demand[ids]
        drift = np.abs(dem - ref) / np.maximum(np.abs(ref), 1e-9)
        dirty = ids[drift.max(axis=1) > self.dirty_rel]
        self.dirty_apps.update(int(n) for n in dirty)
        for n in ids:
            self._log(n, seq)

    def _apply_capacity(self, ev) -> None:
        if ev.capacity is not None:
            self._capacity = np.asarray(ev.capacity, np.float32).copy()
        if ev.task_limit is not None:
            self._task_limit = np.asarray(ev.task_limit, np.float32).copy()
        if ev.slo_allowed is not None:
            self._slo_allowed = np.asarray(ev.slo_allowed, bool).copy()
        if ev.region_latency is not None:
            self._region_latency = np.asarray(ev.region_latency).copy()
            self._geometry_stale = True
        if ev.hosts_per_tier is not None:
            self._hosts = np.asarray(ev.hosts_per_tier).copy()
            self._geometry_stale = True
        self.capacity_dirty = True

    def _apply_latency(self, ev, seq: int) -> None:
        """Re-stage the region-latency matrix WITHOUT the structural bit.

        ``capacity_dirty`` stays False: shard boundaries and capacities
        did not move, so a latency-SLO breach should cost a *delta* solve
        over the breaching apps' shards, not a fleet-wide pass.  Breach =
        an app whose current tier's worst-case region latency (the
        ``RegionScheduler`` contract) exceeds the budget."""
        self._region_latency = np.asarray(ev.region_latency).copy()
        self._geometry_stale = True
        self.collected_at = max(self.collected_at, int(ev.collected_at))
        budget = (float(ev.budget_ms) if ev.budget_ms is not None
                  else REGION_LATENCY_BUDGET_MS)
        tiers = np.asarray(self._cluster.tier_regions, bool)     # [T, Rg]
        lat = self._region_latency
        worst = np.where(tiers[None, :, :], lat[:, None, :],
                         -np.inf).max(axis=2)                    # [Rg, T]
        app_region = np.asarray(self._cluster.app_region)
        per_app = worst[app_region, self._x0]
        breaching = np.where(self._valid & (per_app > budget))[0]
        for n in breaching:
            self.dirty_apps.add(int(n))
            self._log(n, seq)
        # Latest measurement wins: an in-budget matrix clears the flag.
        self.latency_breach = bool(breaching.size)

    def _apply_arrival(self, ev, seq: int) -> None:
        n = int(ev.app_id)
        self._valid[n] = True
        self._demand[n] = np.asarray(ev.demand, np.float32)
        self._tasks[n] = float(ev.tasks)
        self._slo[n] = int(ev.slo)
        self._crit[n] = float(ev.criticality)
        self._x0[n] = int(ev.tier) if ev.tier >= 0 else self._place(n)
        self._ref_demand[n] = self._demand[n]
        self.dirty_apps.add(n)
        self._log(n, seq)

    def _apply_departure(self, ev, seq: int) -> None:
        n = int(ev.app_id)
        self._valid[n] = False
        self._demand[n] = 0.0
        self._tasks[n] = 0.0
        self.dirty_apps.add(n)
        self._log(n, seq)

    def _place(self, n: int) -> int:
        """Greedy arrival placement: the SLO-eligible, region-reachable
        tier with the most post-placement headroom (the harness rule)."""
        T = self._capacity.shape[0]
        live = self._valid.copy()
        live[n] = False
        util = np.zeros_like(self._capacity, np.float64)
        tsk = np.zeros(T, np.float64)
        np.add.at(util, self._x0[live], self._demand[live])
        np.add.at(tsk, self._x0[live], self._tasks[live])
        ok = self._slo_allowed[:, self._slo[n]]
        region_ok = RegionScheduler(self.view()).feasibility_matrix()[n]
        if (ok & region_ok).any():
            ok = ok & region_ok
        if not ok.any():
            ok = np.ones(T, bool)
        frac = np.maximum(
            ((util + self._demand[n]) / np.maximum(self._capacity, 1e-9)).max(axis=1),
            (tsk + self._tasks[n]) / np.maximum(self._task_limit, 1e-9),
        )
        return int(np.argmin(np.where(ok, frac, np.inf)))

    # -- solve bookkeeping ---------------------------------------------------
    def adopt_assignment(self, assignment) -> None:
        """A solve was applied: its mapping is the shadow's new incumbent."""
        self._x0 = np.asarray(assignment, np.int32).copy()

    def clean(self, app_ids=None) -> None:
        """Mark apps as covered by a solve (all when ``app_ids`` is None):
        their dirty bits clear and the drift reference re-bases."""
        if app_ids is None:
            self.dirty_apps.clear()
            self._ref_demand = self._demand.copy()
            self.capacity_dirty = False
            self.latency_breach = False
            return
        ids = np.asarray(list(app_ids), np.int64)
        self._ref_demand[ids] = self._demand[ids]
        self.dirty_apps.difference_update(int(n) for n in ids)
        # A scoped solve covered the breaching apps' shards (they were the
        # dirty set that triggered it); a persisting breach re-raises on
        # the next latency measurement.
        self.latency_breach = False

    # -- materialization -----------------------------------------------------
    def stranded(self) -> int:
        """Live apps whose current tier is SLO-ineligible (trigger input)."""
        ok = self._slo_allowed[self._x0, self._slo]
        return int(np.sum(~ok & self._valid))

    def tier_loads(self) -> np.ndarray:
        """f32[T] worst-resource load fraction per tier (drift input)."""
        util = np.zeros_like(self._capacity, np.float64)
        live = self._valid
        np.add.at(util, self._x0[live], self._demand[live])
        return (util / np.maximum(self._capacity, 1e-9)).max(axis=1)

    def over_ideal(self) -> float:
        """Worst excess over the ideal utilization line — the quantity the
        lockstep ``trigger_over_ideal`` polices and the SLO accountant
        integrates as over-ideal tier-ticks."""
        live = self._valid
        cap = np.maximum(self._capacity, 1e-9)
        lim = np.maximum(self._task_limit, 1e-9)
        util = np.zeros_like(self._capacity, np.float64)
        tsk = np.zeros(cap.shape[0], np.float64)
        np.add.at(util, self._x0[live], self._demand[live])
        np.add.at(tsk, self._x0[live], self._tasks[live])
        over = float((util / cap - self._ideal).max())
        return max(over, float((tsk / lim - self._ideal_t).max()))

    def d2b(self) -> float:
        """Difference-to-balance of the shadow incumbent — the same Fig. 5
        metric the lockstep trigger polices (``core.metrics``), in plain
        numpy so quiescent ticks stay cheap."""
        live = self._valid
        cap = np.maximum(self._capacity, 1e-9)
        lim = np.maximum(self._task_limit, 1e-9)
        util = np.zeros_like(self._capacity, np.float64)
        tsk = np.zeros(cap.shape[0], np.float64)
        np.add.at(util, self._x0[live], self._demand[live])
        np.add.at(tsk, self._x0[live], self._tasks[live])
        util_frac = util / cap
        task_frac = tsk / lim
        total_frac = self._demand[live].sum(axis=0) / cap.sum(axis=0)
        total_task = self._tasks[live].sum() / lim.sum()
        worst = float(np.abs(util_frac - total_frac[None, :]).max())
        return max(worst, float(np.abs(task_frac - total_task).max()))

    def view(self, now: int | None = None) -> ClusterState:
        """The shadow as a ``ClusterState`` the controller can plan on."""
        p = dataclasses.replace(
            self._cluster.problem,
            demand=jnp.asarray(self._demand * self._valid[:, None]),
            tasks=jnp.asarray(self._tasks * self._valid),
            valid=jnp.asarray(self._valid),
            slo=jnp.asarray(self._slo),
            criticality=jnp.asarray(self._crit),
            assignment0=jnp.asarray(self._x0),
            capacity=jnp.asarray(self._capacity),
            task_limit=jnp.asarray(self._task_limit),
            slo_allowed=jnp.asarray(self._slo_allowed),
        )
        return dataclasses.replace(
            self._cluster,
            problem=p,
            region_latency=self._region_latency,
            hosts_per_tier=self._hosts,
            collected_at=(self.collected_at if now is None else int(now)),
        )
