"""The always-on control loop: events in, drift-scoped solves out.

``ServiceLoop`` is the streaming frontend over ``BalanceController``:
producers ``submit`` typed ``ServiceEvent`` records (or feed an
``asyncio.Queue`` drained by ``serve``), the loop folds them into a
``FleetShadow`` in submission order, and a ``DriftDetector`` decides per
``step`` whether the state has drifted enough to pay for a solve at all —
and if so, whether a *delta* solve over the dirty shards suffices or the
whole fleet needs a full cooperate pass.  Lockstep cadence (solve every
tick, trigger or not) becomes event-driven control: quiescent ticks cost a
few numpy reductions, and localized drift costs a batched solve over a few
shards instead of the fleet.

Integrity contract: every submitted event is stamped with a global
monotonic sequence number and applied exactly once, in order, before the
tick's decision — ``dropped_events`` is computed, not asserted, and stays
zero by construction.  The per-app applied-sequence log lives on the
shadow (fuzzed in tests/test_fuzz_scenarios.py).

Shard-scope note: dirty shard ids are computed against ``plan_shards`` of
the *shadow view*.  The controller re-plans at solve time, but the
partition is region-affine — it only moves under structural (capacity /
host) changes, and those force a FULL pass by the drift table, so the ids
never go stale across a delta solve.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core.controller import BalanceController, TickInput, TickResult
from repro.service import events as E
from repro.service.drift import DELTA, FULL, NOOP, DriftConfig, DriftDetector
from repro.service.shadow import DIRTY_REL, FleetShadow


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs for the streaming loop."""

    drift: DriftConfig = dataclasses.field(default_factory=DriftConfig)
    # Shard count for the partitioned delta solver; an attached controller
    # that already solves sharded (config.shards) wins over this.
    num_shards: int = 4
    # Relative demand drift above which an app's shard is dirty.
    dirty_rel: float = DIRTY_REL


@dataclasses.dataclass
class ServiceStepResult:
    """What one ``step`` did: the drift decision and (when a solve ran)
    the controller's full ``TickResult``."""

    now: int
    action: str  # noop | delta | full
    reason: str
    divergence: float
    dirty_shards: tuple = ()
    result: Optional[TickResult] = None
    events_drained: int = 0
    latency_s: float = 0.0

    @property
    def applied(self) -> bool:
        return self.result is not None and self.result.applied


class ServiceLoop:
    """Event-driven control service over one ``BalanceController``."""

    def __init__(self, cluster=None, controller: BalanceController = None,
                 *, config: ServiceConfig = ServiceConfig()):
        if controller is None:
            if cluster is None:
                raise ValueError("need a cluster or a controller")
            from repro.core.controller import ControllerConfig
            # Full passes keep the global cooperate engine (config.shards
            # stays None); only delta solves route through the partitioned
            # path, scoped by TickInput.num_shards.
            controller = BalanceController(cluster, ControllerConfig())
        self.controller = controller
        self.config = config
        # Delta solves route through the partitioned solver at this shard
        # count; full passes keep whatever engine the controller config
        # names (the global cooperate pass unless config.shards is set).
        self.num_shards = int(controller.config.shards or config.num_shards)
        self.shadow = FleetShadow(controller.cluster,
                                  dirty_rel=config.dirty_rel)
        self.drift = DriftDetector(config.drift)
        self._queue: collections.deque = collections.deque()
        self._seq = 0
        # Producers may submit from multiple threads (the ingestion bench
        # does); the lock keeps (seq, enqueue) atomic so the global order
        # stays gap-free.  ``step`` stays single-consumer.
        self._submit_lock = threading.Lock()
        self.submitted = 0
        self.applied_events = 0
        self._pending_membership = False
        self.steps: list[ServiceStepResult] = []
        self.counts = {NOOP: 0, DELTA: 0, FULL: 0}       # drift decisions
        self.executed = {DELTA: 0, FULL: 0}              # solver actually ran
        self.latency = {NOOP: [], DELTA: [], FULL: []}
        self.solves_applied = 0
        self.delta_reverts = 0
        self._wall_s = 0.0

    # -- ingestion ------------------------------------------------------------
    def submit(self, event) -> int:
        """Enqueue one event; returns its global sequence number.

        Safe to call from concurrent producer threads."""
        with self._submit_lock:
            seq = self._seq
            self._seq += 1
            self.submitted += 1
            self._queue.append((seq, event))
        return seq

    def _drain(self, now: int) -> int:
        """Apply every queued event, in sequence order."""
        drained = 0
        while self._queue:
            seq, event = self._queue.popleft()
            kind = getattr(event, "kind", None)
            if kind == E.ADVISORIES:
                self.controller.ingest(event)
            elif kind == E.FAULT:
                self.controller.ingest(event)
                self.drift.note_fault(event.until)
            elif kind in (E.ARRIVAL, E.DEPARTURE):
                self._pending_membership = True
            self.shadow.apply(event, seq)
            self.applied_events += 1
            drained += 1
        return drained

    # -- shard scoping --------------------------------------------------------
    def _dirty_shards(self) -> tuple:
        if not self.shadow.dirty_apps:
            return ()
        from repro.shard.partition import plan_shards
        plan = plan_shards(self.shadow.view(), self.num_shards)
        ids = np.fromiter(self.shadow.dirty_apps, np.int64)
        return tuple(int(s) for s in np.unique(plan.app_shard[ids]))

    def _shard_apps(self, shard_ids) -> np.ndarray:
        from repro.shard.partition import plan_shards
        plan = plan_shards(self.shadow.view(), self.num_shards)
        return np.where(np.isin(plan.app_shard, np.asarray(shard_ids)))[0]

    # -- one service tick -----------------------------------------------------
    def step(self, now: Optional[int] = None) -> ServiceStepResult:
        """Drain the queue, decide noop/delta/full, run what was decided."""
        t0 = time.perf_counter()
        now = len(self.steps) if now is None else int(now)
        drained = self._drain(now)

        ctl = self.controller
        outlook_active = False
        if ctl.planner is not None:
            outlook = ctl.planner.outlook(now, self.shadow.view(now))
            outlook_active = bool(outlook.active)
        dirty = self._dirty_shards()
        decision = self.drift.decide(
            loads=self.shadow.tier_loads(), now=now,
            capacity_dirty=self.shadow.capacity_dirty,
            outlook_active=outlook_active,
            stranded=self.shadow.stranded(),
            dirty_shards=dirty,
            pending_membership=self._pending_membership,
            d2b=self.shadow.d2b(),
            over_ideal=self.shadow.over_ideal(),
            latency_breach=self.shadow.latency_breach)

        res: Optional[TickResult] = None
        if decision.action is not NOOP:
            scoped = (decision.dirty_shards
                      if decision.action == DELTA else None)
            res = ctl.step(TickInput(
                cluster=self.shadow.view(now), now=now,
                collected_at=self.shadow.collected_at,
                dirty_shards=scoped,
                num_shards=self.num_shards if scoped is not None else None))
            # Adopt + re-base only when the controller actually concluded
            # something about the fleet: it applied a plan, or it looked at
            # the fresh view and judged it balanced.  A *hold* (cooldown,
            # safe/conservative mode) deferred the work — keep the dirty
            # bits and, critically, the solver floor: rebasing on a held
            # round would ratchet the drift gates up to unsolved d2b and
            # mask the very imbalance the deferred solve was meant to fix.
            concluded = res.applied or (
                not res.triggered and res.reason.startswith("balanced"))
            if concluded:
                self.shadow.adopt_assignment(
                    np.asarray(ctl.cluster.problem.assignment0))
                if decision.action == DELTA:
                    self.shadow.clean(self._shard_apps(scoped))
                else:
                    self.shadow.clean()
                self._pending_membership = False
                self.drift.note_solve(self.shadow.tier_loads(),
                                      full=decision.action == FULL,
                                      d2b=self.shadow.d2b(),
                                      over_ideal=self.shadow.over_ideal())
            if res.triggered:
                self.executed[decision.action] += 1
            if res.applied:
                self.solves_applied += 1
            if (res.decision is not None and res.decision.solve.extra
                    .get("sharded", {}).get("delta_reverted")):
                self.delta_reverts += 1

        latency = time.perf_counter() - t0
        self._wall_s += latency
        self.counts[decision.action] += 1
        self.latency[decision.action].append(latency)
        out = ServiceStepResult(
            now=now, action=decision.action, reason=decision.reason,
            divergence=decision.divergence,
            dirty_shards=decision.dirty_shards, result=res,
            events_drained=drained, latency_s=latency)
        self.steps.append(out)
        return out

    # -- async frontend -------------------------------------------------------
    async def serve(self, queue, *, batch_ticks: bool = True) -> int:
        """Drain an ``asyncio.Queue`` of events until a ``None`` sentinel.

        Each await wakes on at least one event, greedily drains whatever
        else is already queued (one ``step`` per burst when
        ``batch_ticks``, one per event otherwise), and steps the loop.
        Returns the number of steps taken."""
        steps = 0
        stop = False
        while not stop:
            event = await queue.get()
            if event is None:
                break
            self.submit(event)
            while batch_ticks and not queue.empty():
                more = queue.get_nowait()
                if more is None:
                    stop = True
                    break
                self.submit(more)
            self.step()
            steps += 1
        if self._queue:
            self.step()
            steps += 1
        return steps

    # -- accounting -----------------------------------------------------------
    @property
    def dropped_events(self) -> int:
        return self.submitted - self.applied_events - len(self._queue)

    def stats(self) -> dict:
        """Operator-facing counters (the BENCH service_loop section)."""
        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        total = max(1, len(self.steps))
        solved = self.executed[DELTA] + self.executed[FULL]
        return {
            "steps": len(self.steps),
            "events_submitted": self.submitted,
            "events_applied": self.applied_events,
            "dropped_events": self.dropped_events,
            "events_per_s": (self.applied_events / self._wall_s
                             if self._wall_s > 0 else 0.0),
            "noop_ticks": self.counts[NOOP],
            # *_solves count executed solver passes; *_decisions count what
            # the drift table asked for (cooldown/mode gates may hold one).
            "delta_solves": self.executed[DELTA],
            "full_solves": self.executed[FULL],
            "delta_decisions": self.counts[DELTA],
            "full_decisions": self.counts[FULL],
            "solves_applied": self.solves_applied,
            "delta_fraction": (self.executed[DELTA] / solved
                               if solved else 0.0),
            "noop_fraction": self.counts[NOOP] / total,
            "delta_reverts": self.delta_reverts,
            "resolve_p50_ms": pct(
                self.latency[DELTA] + self.latency[FULL], 50) * 1e3,
            "resolve_p99_ms": pct(
                self.latency[DELTA] + self.latency[FULL], 99) * 1e3,
            "noop_p50_ms": pct(self.latency[NOOP], 50) * 1e3,
        }
