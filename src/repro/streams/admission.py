"""Admission control: price an arriving stream app before it joins the fleet.

Reactive balancing (the controller) and overload shedding (core.shedding)
both deal with load that is *already* inside the fleet.  The cheapest point
to resolve overload is earlier — at arrival, before the app has partitions
placed, caches warmed, and an SLO being breached.  The gate prices each
arriving ``StreamApp`` with a **warm-started delta-solve**: the fleet's
current tier loads are the warm start, and only the dirty region — the
candidate's row against each SLO-eligible tier's column — is touched.  No
full re-solve; pricing an arrival is O(T^2 R) arithmetic on host numpy.

Outcomes:

  * **ADMIT** — some eligible tier holds the app at full demand within the
    headroom margin; the decision names the utility-cheapest such tier (the
    exact scalarized-objective delta of placing the app there, same decade
    weights as the solver).
  * **ADMIT_DEGRADED** — no tier fits the full demand, but one fits at a
    delivery cap >= ``min_degraded_cap``.  The app enters throttled at the
    best such cap with a *declared* utility (the curve value it signed up
    for); the cap joins the LoadShedder's managed set and lifts through the
    same hysteresis when capacity recovers.
  * **DEFER** — not even degraded service fits.  The app is turned away
    with a ``retry_after`` that backs off exponentially per app
    (``backoff_base ** attempts``, capped), so a thundering herd of
    deferred arrivals cannot re-price itself every tick.
  * **REJECT** — SAFE mode only: arrivals below ``critical_floor``
    criticality are refused outright while the control plane distrusts its
    own telemetry (no retry hint — the caller should re-submit only after
    the fleet leaves SAFE).

Mode wiring (the PR-6 degraded-mode machine): CONSERVATIVE tightens
admission — the headroom margin grows by ``conservative_headroom`` and
degraded admissions are disabled (suspect telemetry is no basis for
promising a throttled app its cap is safe).  SAFE additionally rejects all
non-critical arrivals.  Every decision is appended to ``log`` for audit.
"""

from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.constraints import FEAS_TOL
from repro.core.goals import FLEET_UTILITY_WEIGHT
from repro.core.problem import Problem
from repro.core.utility import default_curves


class AdmissionState(str, enum.Enum):
    ADMIT = "admit"
    ADMIT_DEGRADED = "admit_degraded"
    DEFER = "defer"
    REJECT = "reject"


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    # Capacity margin an admission must leave free (fraction of each tier's
    # capacity).  0.0 admits up to the hard constraint; the controller's
    # balance pass still owns pushing tiers back under ideal_frac.
    headroom: float = 0.0
    # Degraded admissions below this delivery cap are not worth running.
    min_degraded_cap: float = 0.25
    # DEFER backoff: retry_after = min(backoff_cap, backoff_base**attempts).
    backoff_base: int = 2
    backoff_cap: int = 32
    # CONSERVATIVE mode adds this much headroom on top of ``headroom``.
    conservative_headroom: float = 0.1
    # SAFE mode rejects arrivals below this criticality outright.
    critical_floor: float = 0.7


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    state: AdmissionState
    key: str
    tier: int = -1  # priced placement (ADMIT / ADMIT_DEGRADED)
    cap: float = 1.0  # delivery cap the app enters at
    declared_utility: float = 0.0  # curve value at ``cap`` (what it signed up for)
    objective_delta: float = 0.0  # scalarized-objective cost of the placement
    retry_after: int = 0  # DEFER: ticks until the next attempt
    reason: str = ""

    @property
    def admitted(self) -> bool:
        return self.state in (AdmissionState.ADMIT, AdmissionState.ADMIT_DEGRADED)


class AdmissionController:
    """The gate.  Stateful only for audit and per-app backoff counters."""

    def __init__(self, config: AdmissionConfig = AdmissionConfig()):
        self.config = config
        self.log: list[AdmissionDecision] = []
        self._attempts: dict[str, int] = {}

    # -- the warm-started delta-solve ----------------------------------------
    def _price(
        self, problem: Problem, demand: np.ndarray, tasks: float, slo: int, headroom: float
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(max_cap[T], obj_delta[T], eligible[T]) against the current loads.

        ``max_cap[t]`` is the largest delivery cap at which the candidate
        fits tier ``t``'s remaining headroom (0 when even the task slot is
        unavailable).  The fit is *marginal per resource*: the candidate
        needs headroom only on resources it actually consumes — a tier
        saturated on a resource the candidate demands none of is still
        admissible (it neither fits into nor worsens that overflow; the
        shedder owns it).  ``obj_delta[t]`` is the exact scalarized-objective
        change of placing the candidate on ``t`` at full demand — only
        tier ``t``'s loads change (the dirty region), every other tier's
        contribution is reused from the warm start.
        """
        # Host float64 accumulation (same semantics as ``tier_loads``, which
        # segment-sums in f32 on device): admission is priced against the
        # same arithmetic the sim's post-admit recount uses, so a correct
        # admission can never be flagged infeasible by f32 drift — at fleet
        # scale that drift is ~1e-3, three decades past FEAS_TOL.
        x0 = np.asarray(problem.assignment0)
        valid = np.asarray(problem.valid, bool)
        dem_all = np.asarray(problem.demand, np.float64)
        tsk_all = np.asarray(problem.tasks, np.float64)
        T = problem.num_tiers
        util = np.zeros((T, dem_all.shape[1]))  # [T, R]
        tier_tasks = np.zeros(T)  # [T]
        np.add.at(util, x0[valid], dem_all[valid])
        np.add.at(tier_tasks, x0[valid], tsk_all[valid])
        capacity = np.asarray(problem.capacity, np.float64) * (1.0 - headroom)
        task_limit = np.asarray(problem.task_limit, np.float64)

        eligible = np.asarray(problem.slo_allowed)[:, int(slo)].copy()
        eligible &= tier_tasks + tasks <= task_limit + FEAS_TOL

        free = np.maximum(capacity - util, 0.0)  # [T, R]
        with np.errstate(divide="ignore"):
            per_res = np.where(demand > 0.0, free / np.maximum(demand, 1e-12), np.inf)
        max_cap = np.clip(per_res.min(axis=1), 0.0, 1.0)  # [T]
        max_cap = np.where(eligible, max_cap, 0.0)

        # Exact objective delta of a full-demand placement, per tier: the
        # candidate only perturbs one column of the [T, R] load matrix, so
        # each candidate tier's objective is the warm-start matrix plus a
        # rank-one update.  (Movement/criticality goals are untouched — the
        # arrival isn't a move.)
        cap_full = np.asarray(problem.capacity, np.float64)
        w = problem.weights
        obj_delta = np.full(T, np.inf)

        ideal = np.asarray(problem.ideal_frac, np.float64)
        ideal_t = np.asarray(problem.ideal_task_frac, np.float64)

        def partial_obj(uf: np.ndarray, tf: np.ndarray) -> float:
            over = np.maximum(uf - ideal, 0.0)
            over_t = np.maximum(tf - ideal_t, 0.0)
            under_ideal = float((over * over).sum() + (over_t * over_t).sum())
            balance = float(((uf - uf.mean(axis=0, keepdims=True)) ** 2).sum())
            task_balance = float(((tf - tf.mean()) ** 2).sum())
            return (
                w.under_ideal * under_ideal
                + w.resource_balance * balance
                + w.task_balance * task_balance
            )

        uf0 = util / cap_full
        tf0 = tier_tasks / task_limit
        base = partial_obj(uf0, tf0)
        for t in range(T):
            if not eligible[t]:
                continue
            uf = uf0.copy()
            uf[t] = (util[t] + demand) / cap_full[t]
            tf = tf0.copy()
            tf[t] = (tier_tasks[t] + tasks) / task_limit[t]
            obj_delta[t] = partial_obj(uf, tf) - base
        return max_cap, obj_delta, eligible

    # -- one arrival ----------------------------------------------------------
    def decide(
        self,
        problem: Problem,
        *,
        demand,
        tasks: float,
        slo: int,
        criticality: float,
        key: str,
        mode: str = "normal",
        now: int = 0,
    ) -> AdmissionDecision:
        """Price one arrival against ``problem``'s current state.

        ``demand`` is the candidate's f32[R] resource vector; ``key``
        identifies the app across retries (backoff state); ``mode`` is the
        controller's operating mode string (``Mode.value``).
        """
        cfg = self.config
        demand = np.asarray(demand, np.float64).reshape(-1)
        crit = float(criticality)

        if mode == "safe" and crit < cfg.critical_floor:
            decision = AdmissionDecision(
                AdmissionState.REJECT,
                key,
                reason=f"safe-mode rejects non-critical arrivals "
                f"(criticality {crit:.2f} < {cfg.critical_floor})",
            )
            self.log.append(decision)
            return decision

        headroom = cfg.headroom
        if mode in ("conservative", "safe"):
            headroom += cfg.conservative_headroom
        max_cap, obj_delta, eligible = self._price(
            problem, demand, float(tasks), int(slo), headroom
        )

        knee, slope, weight = (
            np.asarray(a, np.float64).reshape(()) for a in default_curves([crit])
        )
        # Best degraded offer and the utility the candidate would declare
        # at it — a cap whose curve value is 0 buys nothing (cliff slopes,
        # step curves), so it cannot justify an admission.
        best_cap = float(max_cap.max(initial=0.0))
        deficit = max(0.0, float(knee) - best_cap)
        best_u = float(weight) * min(1.0, max(0.0, 1.0 - float(slope) * deficit))
        full = max_cap >= 1.0 - FEAS_TOL
        if np.any(full):
            # Utility-cheapest full placement: lowest objective delta, with
            # the fleet-utility decade breaking ties toward emptier tiers
            # implicitly (a fuller tier hurts under_ideal/balance more).
            t = int(np.argmin(np.where(full, obj_delta, np.inf)))
            decision = AdmissionDecision(
                AdmissionState.ADMIT,
                key,
                tier=t,
                cap=1.0,
                declared_utility=float(weight),
                objective_delta=float(obj_delta[t]),
                reason=f"fits tier {t} at full demand",
            )
            self._attempts.pop(key, None)
        elif mode == "normal" and best_cap >= cfg.min_degraded_cap and best_u > 0.0:
            # Highest cap wins, objective delta as the tiebreak.  Declared
            # utility is the curve value at that cap — scaled by the
            # fleet-utility weight it is exactly what the solver will be
            # paid for keeping the app served.
            ties = max_cap >= best_cap - FEAS_TOL
            t = int(np.argmin(np.where(ties, obj_delta, np.inf)))
            decision = AdmissionDecision(
                AdmissionState.ADMIT_DEGRADED,
                key,
                tier=t,
                cap=best_cap,
                declared_utility=best_u,
                objective_delta=float(obj_delta[t]),
                reason=f"degraded to cap {best_cap:.2f} on tier {t} "
                f"(declared utility {best_u:.3f}, "
                f"{FLEET_UTILITY_WEIGHT:g}-weighted)",
            )
            self._attempts.pop(key, None)
        else:
            attempts = self._attempts.get(key, 0)
            retry = min(cfg.backoff_cap, cfg.backoff_base**attempts)
            self._attempts[key] = attempts + 1
            if not np.any(eligible):
                why = "no eligible tier"
            elif mode != "normal":
                why = f"{mode} mode disables degraded admission"
            elif best_cap < cfg.min_degraded_cap:
                why = f"best cap {best_cap:.2f} < {cfg.min_degraded_cap}"
            else:
                why = f"cap {best_cap:.2f} earns zero declared utility"
            decision = AdmissionDecision(
                AdmissionState.DEFER,
                key,
                retry_after=int(retry),
                reason=f"{why}; retry after {int(retry)} ticks",
            )
        self.log.append(decision)
        return decision

    def audit(self) -> dict:
        counts: dict[str, int] = {s.value: 0 for s in AdmissionState}
        for d in self.log:
            counts[d.state.value] += 1
        return {"decisions": len(self.log), **counts, "backlog": len(self._attempts)}


def admission_row(app) -> dict:
    """A ``StreamApp``'s scheduler-visible arrival record, as ``decide``
    keyword arguments (the streams-layer adapter)."""
    return dict(
        demand=np.array([app.flops_demand, app.hbm_demand], np.float64),
        tasks=float(app.num_partitions),
        slo=int(app.slo),
        criticality=float(app.criticality),
        key=app.name,
    )
