"""Stream-application model: the unit SPTLB schedules.

A ``StreamApp`` is a training/serving job fed by a partitioned token stream.
Its scheduler-visible footprint is exactly the paper's app record:
p99 compute/memory demand, task count (= stream partitions), SLO class,
criticality, and a data-source region.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamApp:
    name: str
    num_partitions: int            # "task count"
    flops_demand: float            # sustained TFLOP/s (p99)  -> "cpu"
    hbm_demand: float              # GB of state/cache (p99)  -> "mem"
    slo: int                       # latency class
    criticality: float             # [0, 1]
    data_region: int
    arch: str = "smollm-360m"      # model served/trained by this job


def demo_apps(num: int = 32, *, num_regions: int = 6, seed: int = 0
              ) -> list[StreamApp]:
    rng = np.random.default_rng(seed)
    apps = []
    for i in range(num):
        apps.append(StreamApp(
            name=f"stream_{i:04d}",
            num_partitions=int(rng.integers(1, 64)),
            flops_demand=float(rng.lognormal(1.0, 0.8)),
            hbm_demand=float(rng.lognormal(1.5, 0.8)),
            slo=int(rng.choice(4, p=[0.2, 0.2, 0.45, 0.15])),
            criticality=float(rng.beta(2, 5)),
            data_region=int(rng.integers(num_regions)),
        ))
    return apps
