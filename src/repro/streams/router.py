"""SPTLB-driven routing of stream apps onto pod slices.

Bridges the paper's scheduler to the training runtime: StreamApps become the
solver's entities, pod slices become tiers, and the resulting app->tier
mapping tells each slice which stream partitions to consume.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import ClusterState, CoopConfig, Sptlb, make_problem
from repro.core.telemetry import PAPER_SLO_TABLE
from repro.streams.admission import (AdmissionController, AdmissionDecision,
                                     admission_row)
from repro.streams.app import StreamApp


@dataclasses.dataclass(frozen=True)
class PodSlice:
    """A tier: a group of hosts within one pod with aggregate headroom."""
    name: str
    pod: int
    num_hosts: int
    flops_capacity: float          # TFLOP/s
    hbm_capacity: float            # GB
    task_slots: int
    regions: tuple[int, ...]


def build_cluster(apps: list[StreamApp], slices: list[PodSlice],
                  *, num_regions: int = 6, move_frac: float = 0.10,
                  seed: int = 0) -> ClusterState:
    """Assemble a ClusterState from streaming apps + pod slices."""
    rng = np.random.default_rng(seed)
    N, T = len(apps), len(slices)
    demand = np.array([[a.flops_demand, a.hbm_demand] for a in apps],
                      np.float32)
    tasks = np.array([a.num_partitions for a in apps], np.float32)
    slo = np.array([a.slo for a in apps], np.int32)
    crit = np.array([a.criticality for a in apps], np.float32)
    capacity = np.array([[s.flops_capacity, s.hbm_capacity] for s in slices],
                        np.float32)
    task_limit = np.array([s.task_slots for s in slices], np.float32)

    S = PAPER_SLO_TABLE.shape[1]
    slo_allowed = (PAPER_SLO_TABLE if T == 5
                   else np.ones((T, S), bool))

    # initial placement: first feasible slice with headroom (greedy fill)
    x0 = np.zeros(N, np.int32)
    load = np.zeros((T, 2), np.float32)
    for i, a in enumerate(apps):
        ok = [t for t in range(T) if slo_allowed[t, a.slo]]
        t = min(ok, key=lambda t: (load[t] / capacity[t]).max())
        x0[i] = t
        load[t] += demand[i]

    problem = make_problem(
        demand=demand, tasks=tasks, slo=slo, criticality=crit,
        assignment0=x0, capacity=capacity, task_limit=task_limit,
        slo_allowed=slo_allowed, move_frac=move_frac)

    tier_regions = np.zeros((T, num_regions), bool)
    for t, s in enumerate(slices):
        tier_regions[t, list(s.regions)] = True
    ring = np.abs(np.arange(num_regions)[:, None] - np.arange(num_regions))
    ring = np.minimum(ring, num_regions - ring)
    lat = (4.0 + 14.0 * ring).astype(np.float32)

    return ClusterState(
        problem=problem,
        app_names=[a.name for a in apps],
        tier_names=[s.name for s in slices],
        app_region=np.array([a.data_region for a in apps], np.int32),
        tier_regions=tier_regions,
        region_latency=lat,
        hosts_per_tier=np.array([s.num_hosts for s in slices], np.int32),
        host_capacity=np.array(
            [capacity[:, 0].sum(), capacity[:, 1].sum()], np.float32)
            / max(sum(s.num_hosts for s in slices), 1) * 1.6,
    )


class StreamRouter:
    """Holds the live app->slice routing table; re-routes via SPTLB.

    Constructed with the ``apps``/``slices`` it was built from, the router
    also runs the admission gate (``streams.admission``): ``admit`` prices
    an arriving app with the warm-started delta-solve and, when the answer
    is admit / admit-degraded, rebuilds the cluster with the newcomer
    pinned to the priced slice (incumbents keep their current routing).
    """

    def __init__(self, cluster: ClusterState, *,
                 apps: Optional[list[StreamApp]] = None,
                 slices: Optional[list[PodSlice]] = None,
                 admission: Optional[AdmissionController] = None):
        self.cluster = cluster
        self.assignment = np.asarray(cluster.problem.assignment0).copy()
        self.apps = list(apps) if apps is not None else None
        self.slices = list(slices) if slices is not None else None
        self.admission = (admission if admission is not None
                          else AdmissionController())

    def route(self, *, engine: str = "local", variant: str = "manual_cnst"):
        decision = Sptlb(self.cluster).balance(
            engine, config=CoopConfig(variant=variant))
        self.assignment = np.asarray(decision.assignment)
        return decision

    def admit(self, app: StreamApp, *, mode: str = "normal",
              now: int = 0) -> AdmissionDecision:
        """Gate one arrival.  ``mode`` is the owning controller's operating
        mode string (CONSERVATIVE tightens, SAFE rejects non-critical)."""
        decision = self.admission.decide(
            self.cluster.problem, mode=mode, now=now, **admission_row(app))
        if decision.admitted and self.apps is not None:
            if decision.cap < 1.0:
                # Degraded entry: the app joins at its capped (served)
                # demand — the declared-utility contract it signed.
                app = dataclasses.replace(
                    app, flops_demand=app.flops_demand * decision.cap,
                    hbm_demand=app.hbm_demand * decision.cap)
            self.apps.append(app)
            cluster = build_cluster(self.apps, self.slices)
            x0 = np.append(self.assignment,
                           np.int32(decision.tier)).astype(np.int32)
            self.cluster = dataclasses.replace(
                cluster, problem=cluster.problem.with_assignment0(
                    jnp.asarray(x0)))
            self.assignment = x0
        return decision

    # -- streaming-service frontend ------------------------------------------
    def arrival_event(self, app: StreamApp, app_id: int, *,
                      mode: str = "normal", now: int = 0):
        """Gate one arrival and express it as a ``ServiceEvent``.

        The router is the service's frontend: instead of rebuilding the
        cluster itself (``admit``), it prices the app through the admission
        gate and — when admitted — returns the ``AppArrival`` record to
        submit to the owning ``ServiceLoop``, with the priced slice as the
        placement hint and the (possibly capped) served demand.  Returns
        ``(decision, event)``; ``event`` is None when the gate deferred or
        rejected."""
        from repro.service.events import AppArrival
        decision = self.admission.decide(
            self.cluster.problem, mode=mode, now=now, **admission_row(app))
        if not decision.admitted:
            return decision, None
        event = AppArrival(
            app_id=int(app_id),
            demand=np.array([app.flops_demand, app.hbm_demand],
                            np.float32) * decision.cap,
            tasks=float(app.num_partitions), slo=int(app.slo),
            criticality=float(app.criticality), tier=int(decision.tier))
        return decision, event

    @staticmethod
    def departure_event(app_id: int):
        """The ``AppDeparture`` record for an app leaving its slice."""
        from repro.service.events import AppDeparture
        return AppDeparture(app_id=int(app_id))

    def sync(self, result) -> np.ndarray:
        """Adopt an applied ``TickResult`` (or ``ServiceStepResult``) as
        the live routing table; a no-op for unapplied rounds."""
        if getattr(result, "result", None) is not None:
            result = result.result           # unwrap a ServiceStepResult
        if getattr(result, "applied", False) and result.decision is not None:
            self.assignment = np.asarray(
                result.decision.assignment).copy()
        return self.assignment

    def partitions_for_tier(self, tier: int,
                            apps: list[StreamApp]) -> dict[str, int]:
        """Which apps (and their partition counts) this slice consumes."""
        return {apps[i].name: apps[i].num_partitions
                for i in np.where(self.assignment == tier)[0]}
