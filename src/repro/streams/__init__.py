from repro.streams.admission import (AdmissionConfig, AdmissionController,
                                     AdmissionDecision, AdmissionState,
                                     admission_row)
from repro.streams.app import StreamApp, demo_apps
from repro.streams.pipeline import (BackpressureError, Prefetcher,
                                    PrefetchStats, StreamConfig, TokenStream)
from repro.streams.router import PodSlice, StreamRouter, build_cluster

__all__ = ["AdmissionConfig", "AdmissionController", "AdmissionDecision",
           "AdmissionState", "admission_row",
           "StreamApp", "demo_apps", "BackpressureError", "Prefetcher",
           "PrefetchStats", "StreamConfig", "TokenStream", "PodSlice",
           "StreamRouter", "build_cluster"]
