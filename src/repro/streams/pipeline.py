"""Deterministic sharded token-stream pipeline (the training data substrate).

Properties a 1000-node deployment needs and this implements:
  * deterministic, seekable sharding — every (partition, step) pair maps to
    a unique, reproducible batch; restart-from-checkpoint replays exactly
    (the pipeline state is just ``step``),
  * host-side prefetch with a bounded queue (overlaps data with compute),
  * per-partition streams so SPTLB can move partitions between tiers without
    resharding the dataset.

The source here is a synthetic-but-stationary token generator (zipfian
unigram mixture with per-partition phase) — the framework treats it as an
opaque ``sample(partition, step) -> tokens`` function, which is exactly the
interface a real corpus reader would implement.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_partitions: int = 16
    seed: int = 0
    prefetch: int = 2
    # Backpressure policy: a ``put`` that cannot place a batch within
    # ``stall_timeout_s`` is one stall; ``max_stalls`` *consecutive* stalls
    # mean the consumer is wedged, not slow, and the prefetcher fails loudly
    # (``BackpressureError``) instead of spinning forever.  0 disables.
    stall_timeout_s: float = 1.0
    max_stalls: int = 600


class BackpressureError(RuntimeError):
    """The prefetch consumer stopped draining: ``StreamConfig.max_stalls``
    consecutive put timeouts elapsed with the queue still full."""


@dataclasses.dataclass
class PrefetchStats:
    """Counters the prefetcher surfaces instead of silently spinning.

    ``stalls`` are put timeouts (backpressure ticks — the batch is *kept*
    and retried, never recomputed); ``dropped`` are batches produced but
    never consumed (counted when ``close`` drains the queue);
    ``join_timeouts`` are closes where the worker failed to exit in time.
    """

    produced: int = 0
    consumed: int = 0
    stalls: int = 0
    max_stall_run: int = 0
    dropped: int = 0
    join_timeouts: int = 0


class TokenStream:
    """Deterministic, seekable synthetic token source."""

    def __init__(self, cfg: StreamConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # zipf-ish unigram distribution, fixed per stream
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.phase = base.integers(0, 2**31, size=cfg.num_partitions)

    def rows_for(self, partition: int) -> int:
        """Rows this partition contributes (remainder spread over the first
        few partitions so any (global_batch, num_partitions) pair works)."""
        cfg = self.cfg
        base, extra = divmod(cfg.global_batch, cfg.num_partitions)
        return base + (1 if partition < extra else 0)

    def sample(self, partition: int, step: int) -> np.ndarray:
        """tokens i32[rows, seq_len+1] for this (partition, step)."""
        cfg = self.cfg
        rows = self.rows_for(partition)
        rng = np.random.default_rng(
            (int(self.phase[partition]) * 1_000_003 + step) % (2**63))
        return rng.choice(cfg.vocab_size, p=self.probs,
                          size=(rows, cfg.seq_len + 1)).astype(np.int32)

    def batch(self, step: int, partitions: Optional[list[int]] = None) -> dict:
        """Assemble the global batch from (a subset of) partitions."""
        cfg = self.cfg
        parts = partitions if partitions is not None else list(
            range(cfg.num_partitions))
        chunks = [self.sample(p, step) for p in parts
                  if self.rows_for(p) > 0]
        toks = np.concatenate(chunks, axis=0)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class Prefetcher:
    """Bounded background prefetch queue over a TokenStream.

    Backpressure is accounted, not swallowed: a full queue keeps the
    pending batch (no recompute), counts a stall, and after
    ``StreamConfig.max_stalls`` consecutive stalls the worker parks a
    ``BackpressureError`` that the next ``__next__`` raises to the
    consumer.  ``stats`` carries the counters either way.
    """

    def __init__(self, stream: TokenStream, start_step: int = 0):
        self.stream = stream
        self.q: queue.Queue = queue.Queue(maxsize=stream.cfg.prefetch)
        self.step = start_step
        self.stats = PrefetchStats()
        self._error: Optional[BackpressureError] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        cfg = self.stream.cfg
        step = self.step
        pending: Optional[dict] = None
        stall_run = 0
        while not self._stop.is_set():
            if pending is None:
                pending = self.stream.batch(step)
                pending["_step"] = step
            try:
                self.q.put(pending, timeout=cfg.stall_timeout_s)
            except queue.Full:
                self.stats.stalls += 1
                stall_run += 1
                self.stats.max_stall_run = max(self.stats.max_stall_run,
                                               stall_run)
                if cfg.max_stalls and stall_run >= cfg.max_stalls:
                    self._error = BackpressureError(
                        f"prefetch consumer wedged: {stall_run} consecutive "
                        f"stalls of {cfg.stall_timeout_s}s with the queue "
                        f"full at step {step}")
                    return
                continue
            self.stats.produced += 1
            pending = None
            stall_run = 0
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._error is not None:
            raise self._error
        batch = self.q.get()
        self.stats.consumed += 1
        return batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
        if self._thread.is_alive():
            self.stats.join_timeouts += 1
        # Whatever is still queued was produced but will never be consumed.
        self.stats.dropped += self.q.qsize()
