"""Deterministic sharded token-stream pipeline (the training data substrate).

Properties a 1000-node deployment needs and this implements:
  * deterministic, seekable sharding — every (partition, step) pair maps to
    a unique, reproducible batch; restart-from-checkpoint replays exactly
    (the pipeline state is just ``step``),
  * host-side prefetch with a bounded queue (overlaps data with compute),
  * per-partition streams so SPTLB can move partitions between tiers without
    resharding the dataset.

The source here is a synthetic-but-stationary token generator (zipfian
unigram mixture with per-partition phase) — the framework treats it as an
opaque ``sample(partition, step) -> tokens`` function, which is exactly the
interface a real corpus reader would implement.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_partitions: int = 16
    seed: int = 0
    prefetch: int = 2


class TokenStream:
    """Deterministic, seekable synthetic token source."""

    def __init__(self, cfg: StreamConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # zipf-ish unigram distribution, fixed per stream
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.phase = base.integers(0, 2**31, size=cfg.num_partitions)

    def rows_for(self, partition: int) -> int:
        """Rows this partition contributes (remainder spread over the first
        few partitions so any (global_batch, num_partitions) pair works)."""
        cfg = self.cfg
        base, extra = divmod(cfg.global_batch, cfg.num_partitions)
        return base + (1 if partition < extra else 0)

    def sample(self, partition: int, step: int) -> np.ndarray:
        """tokens i32[rows, seq_len+1] for this (partition, step)."""
        cfg = self.cfg
        rows = self.rows_for(partition)
        rng = np.random.default_rng(
            (int(self.phase[partition]) * 1_000_003 + step) % (2**63))
        return rng.choice(cfg.vocab_size, p=self.probs,
                          size=(rows, cfg.seq_len + 1)).astype(np.int32)

    def batch(self, step: int, partitions: Optional[list[int]] = None) -> dict:
        """Assemble the global batch from (a subset of) partitions."""
        cfg = self.cfg
        parts = partitions if partitions is not None else list(
            range(cfg.num_partitions))
        chunks = [self.sample(p, step) for p in parts
                  if self.rows_for(p) > 0]
        toks = np.concatenate(chunks, axis=0)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class Prefetcher:
    """Bounded background prefetch queue over a TokenStream."""

    def __init__(self, stream: TokenStream, start_step: int = 0):
        self.stream = stream
        self.q: queue.Queue = queue.Queue(maxsize=stream.cfg.prefetch)
        self.step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.stream.batch(step)
            batch["_step"] = step
            try:
                self.q.put(batch, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
