"""xlstm-125m [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 vocab=50304.  d_ff=0 per the assignment: FFN
capacity lives inside the blocks (mLSTM up-proj x2, sLSTM post-FFN x4/3).
One sLSTM block every 6 layers (2 of 12), rest mLSTM.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        slstm_every=6,
        activation="gelu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
