"""smollm-360m [dense]: llama-arch small model.

32L d_model=960 15H (kv=5) d_ff=2560 vocab=49152 [hf:HuggingFaceTB/SmolLM; hf].
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49152,
        rope_theta=10000.0,
        activation="silu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
