"""Assigned input shapes x per-arch applicability + ShapeDtypeStruct specs.

Shapes (LM-family; seq_len x global_batch):
  train_4k     4,096 x 256   -> lowers train_step
  prefill_32k  32,768 x 32   -> lowers prefill (serve)
  decode_32k   32,768 x 128  -> lowers serve_step (1 new token, full KV cache)
  long_500k    524,288 x 1   -> serve_step; SUB-QUADRATIC ARCHS ONLY

Skips (documented in DESIGN.md §5):
  * long_500k skipped for pure full-attention archs (dense/moe/vlm/audio)
  * decode shapes skipped for encoder-only archs (hubert)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable?, reason-if-not)."""
    s = SHAPES[shape_name]
    if cfg.is_encoder_only and s.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch; long_500k needs sub-quadratic attention"
    return True, ""


def cells(arch: str | None = None):
    """All runnable (arch, shape) cells — the dry-run grid."""
    from repro.configs import ARCHS
    out = []
    for a in ([arch] if arch else ARCHS):
        cfg = get_config(a)
        for sname in SHAPES:
            ok, _ = shape_applicable(cfg, sname)
            if ok:
                out.append((a, sname))
    return out


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, no device allocation (dry-run contract).
    For "decode" kinds the spec describes the serve_step inputs: one new
    token per sequence plus the *full* KV cache of seq_len (built separately
    via model.init_cache as ShapeDtypeStructs by the dry-run driver).
    """
    s = SHAPES[shape_name]
    B, S = s.global_batch, s.seq_len
    act_dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32

    if cfg.family == "audio":
        if s.kind == "train":
            return {
                "frames": _sds((B, S, cfg.d_model), act_dtype),
                "mask": _sds((B, S), jnp.bool_),
                "targets": _sds((B, S), jnp.int32),
            }
        return {"frames": _sds((B, S, cfg.d_model), act_dtype)}

    if s.kind == "decode":
        return {"token": _sds((B, 1), jnp.int32)}

    batch = {}
    if cfg.family == "vlm":
        P = cfg.num_patches
        batch["vision_embeds"] = _sds((B, P, cfg.d_model), act_dtype)
        text = S - P
    else:
        text = S
    batch["tokens"] = _sds((B, text), jnp.int32)
    if s.kind == "train":
        batch["targets"] = _sds((B, text), jnp.int32)
    return batch
