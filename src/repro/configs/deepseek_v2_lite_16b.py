"""deepseek-v2-lite-16b [moe]: MLA + shared/routed experts.

27L d_model=2048 16H d_ff_expert=1408 vocab=102400, MLA kv_lora=512
(qk_nope=128, qk_rope=64, v=128), 64 routed experts top-6 + 2 shared,
first layer dense (d_ff=10944) [arXiv:2405.04434; hf].

Note: the assignment line reads "2 shared+160 routed top-6"; 160 is the
full deepseek-v2 figure — v2-*lite* has 64 routed experts, which matches
the structured "MoE 64e top-6" field we follow.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,          # MLA: effective kv via latent; kept for info
        d_ff=10944,               # dense first layer
        vocab_size=102400,
        mla=True,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        num_experts=64,
        top_k=6,
        num_shared_experts=2,
        d_ff_expert=1408,
        first_dense_layers=1,
        rope_theta=10000.0,
        activation="silu",
        tie_embeddings=False,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
