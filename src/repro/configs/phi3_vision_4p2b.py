"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (stubbed).

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct; hf].  The vision frontend is a
stub: input_specs() supplies precomputed patch embeddings [B, P, d_model]
that are prepended to the text sequence.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi-3-vision-4.2b",
        family="vlm",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        rope_theta=10000.0,
        activation="silu",
        tie_embeddings=False,
        frontend="vision",
        num_patches=256,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
