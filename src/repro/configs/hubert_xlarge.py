"""hubert-xlarge [audio]: encoder-only w2v2 arch [arXiv:2106.07447; unverified].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (cluster targets).
Audio frontend stubbed: input_specs() provides precomputed frame embeddings.
Encoder-only => no decode shapes.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        use_rope=False,
        norm="layernorm",
        activation="gelu",
        tie_embeddings=False,
        frontend="audio",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
