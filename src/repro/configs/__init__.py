"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published config;
``list_archs()`` enumerates all ten.  Input-shape sets are defined in
``repro.configs.shapes``.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "zamba2_2p7b",
    "phi3_vision_4p2b",
    "gemma2_9b",
    "qwen2p5_3b",
    "smollm_360m",
    "olmo_1b",
    "deepseek_v2_lite_16b",
    "granite_moe_1b",
    "xlstm_125m",
    "hubert_xlarge",
)

# CLI aliases (--arch accepts either form)
ALIASES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "gemma2-9b": "gemma2_9b",
    "qwen2.5-3b": "qwen2p5_3b",
    "smollm-360m": "smollm_360m",
    "olmo-1b": "olmo_1b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "xlstm-125m": "xlstm_125m",
    "hubert-xlarge": "hubert_xlarge",
}


def canonical(arch: str) -> str:
    return ALIASES.get(arch, arch)


def get_config(arch: str):
    name = canonical(arch)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.config()


def list_archs():
    return list(ARCHS)
