"""granite-moe-1b-a400m [moe]: 32 experts top-8.

24L d_model=1024 16H (kv=8) d_ff_expert=512 vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-moe-1b-a400m",
        family="moe",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        num_experts=32,
        top_k=8,
        d_ff_expert=512,
        rope_theta=10000.0,
        activation="silu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
