"""zamba2-2.7b [hybrid]: 54 Mamba2 layers + shared attention block.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf].  Shared attention applied every 6 mamba layers
(9 applications, one parameter set, per-application output projection).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_headdim=64,
        attn_every=6,
        rope_theta=10000.0,
        activation="gelu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
