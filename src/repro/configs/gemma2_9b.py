"""gemma2-9b [dense]: alternating local/global attention, logit softcaps.

42L d_model=3584 16H (kv=8) d_ff=14336 vocab=256000 [arXiv:2408.00118; hf].
local sliding window 4096, attn softcap 50, final softcap 30, (1+w) RMSNorm,
pre+post sandwich norms, sqrt(d) embed scaling, query scale 1/sqrt(256).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        norm="rmsnorm",
        rms_offset=True,
        post_block_norms=True,
        embed_scale=True,
        tie_embeddings=True,
        window=4096,
        local_global_pattern=True,
        attn_softcap=50.0,
        final_softcap=30.0,
        query_scale=256 ** -0.5,
        rope_theta=10000.0,
        activation="gelu_tanh",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
