"""olmo-1b [dense]: non-parametric LayerNorm [arXiv:2402.00838; hf].

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        norm="layernorm_np",
        rope_theta=10000.0,
        activation="silu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
