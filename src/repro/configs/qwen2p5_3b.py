"""qwen2.5-3b [dense]: GQA with QKV bias.

36L d_model=2048 16H (kv=2) d_ff=11008 vocab=151936 [hf:Qwen/Qwen2.5; hf].
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2.5-3b",
        family="dense",
        num_layers=36,
        d_model=2048,
        num_heads=16,
        num_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1000000.0,
        activation="silu",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
