"""Shared neural-net building blocks (pure JAX, pytree params).

Conventions:
  * linear weights are [d_in, d_out]; ``x @ W (+ b)``
  * attention tensors are [batch, seq, heads, head_dim]
  * all matmuls accumulate in f32 (``preferred_element_type``) regardless of
    the bf16/других param dtype — the TPU MXU contract.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers / linear
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def linear(x, w, b=None):
    y = jnp.einsum("...i,io->...o", x, w,
                   preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, *, offset: bool = False, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32) if offset
                 else scale.astype(jnp.float32))
    return y.astype(x.dtype)


def layernorm(x, scale=None, bias=None, *, eps: float = 1e-5):
    """Non-parametric when scale/bias are None (OLMo)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def make_norm(cfg):
    """Returns (init_fn(key) -> params|None, apply_fn(x, params) -> x)."""
    if cfg.norm == "rmsnorm":
        def init(key):
            return jnp.zeros(cfg.d_model) if cfg.rms_offset else jnp.ones(cfg.d_model)
        return init, lambda x, p: rmsnorm(x, p, offset=cfg.rms_offset)
    if cfg.norm == "layernorm":
        def init(key):
            return {"scale": jnp.ones(cfg.d_model), "bias": jnp.zeros(cfg.d_model)}
        return init, lambda x, p: layernorm(x, p["scale"], p["bias"])
    if cfg.norm == "layernorm_np":                  # OLMo non-parametric LN
        return (lambda key: None), (lambda x, p: layernorm(x))
    raise ValueError(cfg.norm)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float, rope_dim: Optional[int] = None):
    """x: [B, S, H, D]; positions: [B, S] (i32). Rotates first rope_dim dims."""
    D = x.shape[-1]
    rd = rope_dim or D
    freqs = rope_frequencies(rd, theta)                        # [rd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, rd/2]
    cos = jnp.cos(angles)[:, :, None, :]                       # [B, S, 1, rd/2]
    sin = jnp.sin(angles)[:, :, None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# attention core (XLA path; kernels/flash_attention.py is the Pallas path)
# ---------------------------------------------------------------------------

def _softcap(logits, cap: Optional[float]):
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def attention(
    q,                       # [B, Sq, H, D]
    k,                       # [B, Skv, KV, D]
    v,                       # [B, Skv, KV, Dv]
    *,
    causal: bool,
    q_positions,             # i32[B, Sq] absolute positions of the queries
    kv_positions,            # i32[B, Skv]
    kv_valid=None,           # bool[B, Skv] (decode: cache slots written)
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
):
    """Grouped-query attention with causal/window masking — the pure-XLA
    reference path used for lowering/dry-run and CPU tests."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    Dv = v.shape[-1]
    G = H // KV                                   # query heads per kv head
    scale = scale if scale is not None else D ** -0.5

    # Keep q/k/v in their storage dtype (bf16) and accumulate the dots in
    # f32 via preferred_element_type — casting a 32k-token KV cache to f32
    # would triple the HBM traffic of a decode step (§Perf A2).  The scale
    # is applied to the f32 logits to avoid a bf16 round-trip on q.
    qs = q.reshape(B, Sq, KV, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qs, k,
                        preferred_element_type=jnp.float32) * scale
    logits = _softcap(logits, softcap)

    mask = jnp.ones((B, Sq, k.shape[1]), bool)
    if causal:
        mask &= kv_positions[:, None, :] <= q_positions[:, :, None]
    if window is not None:
        mask &= kv_positions[:, None, :] > q_positions[:, :, None] - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)

    probs = jax.nn.softmax(logits, axis=-1)           # f32
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype, scale=0.5),
    }


def mlp_apply(params, x, activation: str, *, act_sharding: bool = False):
    gate = act_fn(activation)(linear(x, params["w_gate"]))
    up = linear(x, params["w_up"])
    h = (gate * up).astype(x.dtype)
    if act_sharding:
        from repro.distributed.sharding import constrain
        # hidden activations follow the column-parallel w_gate/w_up shards
        h = constrain(h, ("dp",) + (None,) * (h.ndim - 2) + ("model",))
    return linear(h, params["w_down"])
