"""Unified model configuration covering all 10 assigned architectures.

One dataclass, many knobs — each ``src/repro/configs/<arch>.py`` fills in the
exact published numbers.  ``reduce_for_smoke`` shrinks any config to a
CPU-runnable variant of the same family for the per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family

    # --- core transformer dims ---
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads

    # --- norms / embeddings ---
    norm: Literal["rmsnorm", "layernorm", "layernorm_np"] = "rmsnorm"
    rms_offset: bool = False                # gemma-style (1 + w) scale
    tie_embeddings: bool = True
    post_block_norms: bool = False          # gemma2 pre+post sandwich norms
    embed_scale: bool = False               # gemma multiplies embeds by sqrt(d)

    # --- attention ---
    causal: bool = True
    qkv_bias: bool = False                  # qwen2.5
    use_rope: bool = True                   # hubert: conv pos embed instead
    rope_theta: float = 10_000.0
    rope_dim: Optional[int] = None          # partial rotary (defaults to head_dim)
    window: Optional[int] = None            # sliding-window size for local layers
    local_global_pattern: bool = False      # gemma2: alternate local/global
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    query_scale: Optional[float] = None     # override 1/sqrt(head_dim)

    # --- MLP ---
    activation: Literal["silu", "gelu", "gelu_tanh"] = "silu"

    # --- MoE (granite, deepseek) ---
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0             # deepseek: first k layers dense
    router_aux_coef: float = 0.01           # load-balancing aux loss
    capacity_factor: float = 1.25           # train/prefill; decode is dropless
    moe_impl: Literal["global", "ep"] = "global"   # ep = shard_map expert
                                                   # parallelism (§Perf B)

    # --- MLA (deepseek) ---
    mla: bool = False
    kv_lora_rank: int = 0                   # 512
    qk_nope_dim: int = 0                    # 128
    qk_rope_dim: int = 0                    # 64
    v_head_dim: int = 0                     # 128

    # --- Mamba2 / hybrid (zamba2) ---
    ssm_state: int = 0                      # d_state (64)
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_headdim: int = 64
    attn_every: int = 0                     # zamba2: shared attn block period

    # --- xLSTM ---
    slstm_every: int = 0                    # 1 sLSTM block per this many layers

    # --- modality frontend stubs ---
    frontend: Literal["none", "vision", "audio"] = "none"
    num_patches: int = 0                    # vision: patch tokens prepended

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # --- runtime ---
    attn_impl: Literal["xla", "pallas"] = "xla"
    remat: bool = True                      # activation checkpoint scan bodies
    remat_policy: str = "full"              # full | dots (save matmul outputs)
    unroll_layers: bool = False             # python-loop layers (cost calib)
    activation_sharding: bool = False       # explicit activation constraints
                                            # (perf variant; see §Perf C)
    attn_batch_shard: bool = False          # attention section sharded over
                                            # batch x model axis (head-count
                                            # agnostic TP; see §Perf C)
    ring_cache: bool = False                # sliding-window layers keep a
                                            # window-sized ring KV cache
                                            # instead of full seq (§Perf A)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (or attention-free/hybrid) archs run long_500k."""
        return self.family in ("hybrid", "ssm")


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink to a CPU-runnable config of the same family (smoke tests)."""
    pattern = 2 if cfg.local_global_pattern else 1
    if cfg.attn_every:
        layers = 2 * cfg.attn_every          # keep >=2 shared-attn applications
        layers = min(layers, 8)
        attn_every = max(1, layers // 2)
    else:
        attn_every = 0
        layers = max(2, 4 // pattern * pattern)
    num_heads = 4
    num_kv = max(1, min(cfg.num_kv_heads, 2))
    d_model = 64
    return dataclasses.replace(
        cfg,
        num_layers=layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 16) if cfg.window else None,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        capacity_factor=4.0,   # dropless at smoke scale => paths are consistent
        num_shared_experts=min(cfg.num_shared_experts, 1),
        d_ff_expert=32 if cfg.d_ff_expert else 0,
        first_dense_layers=min(cfg.first_dense_layers, 1),
        kv_lora_rank=32 if cfg.mla else 0,
        qk_nope_dim=16 if cfg.mla else 0,
        qk_rope_dim=8 if cfg.mla else 0,
        v_head_dim=16 if cfg.mla else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 0,
        attn_every=attn_every,
        slstm_every=min(cfg.slstm_every, 2) if cfg.slstm_every else 0,
        num_patches=8 if cfg.frontend == "vision" else 0,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
