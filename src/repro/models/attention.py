"""Attention blocks: standard GQA (7/10 archs) and MLA (deepseek-v2).

Each block exposes:
  init(cfg, key) -> params
  apply(cfg, params, x, *, positions, cache=None, cache_pos=None, layer_window)
      -> (y, new_cache_entry)
where ``cache`` is this layer's KV slice.  ``cache=None`` is the pure
training/encoder path; with a cache the same code covers prefill (S large,
cache_pos=0) and decode (S=1).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ---------------------------------------------------------------------------
# standard GQA attention
# ---------------------------------------------------------------------------

def gqa_init(cfg, key, dtype):
    D = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], cfg.d_model, cfg.num_heads * D, dtype),
        "wk": L.dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * D, dtype),
        "wv": L.dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * D, dtype),
        "wo": L.dense_init(ks[3], cfg.num_heads * D, cfg.d_model, dtype, scale=0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(cfg.num_heads * D, dtype)
        p["bk"] = jnp.zeros(cfg.num_kv_heads * D, dtype)
        p["bv"] = jnp.zeros(cfg.num_kv_heads * D, dtype)
    return p


def gqa_apply(cfg, params, x, *, positions, cache=None, cache_pos=None,
              window: Optional[int] = None):
    B, S, _ = x.shape
    D = cfg.resolved_head_dim
    if cfg.attn_batch_shard and cache is None:
        # Head-count-agnostic tensor parallelism: run the whole attention
        # section batch-sharded over (dp x model).  x arrives model-
        # replicated, so the forward reshard is a local slice; only the
        # output pays one all-gather per layer.  This sidesteps head counts
        # that do not divide the model axis (smollm: 15 q / 5 kv heads).
        from repro.distributed.sharding import constrain
        x = constrain(x, ("dpm", None, None))
    q = L.linear(x, params["wq"], params.get("bq")).reshape(B, S, cfg.num_heads, D)
    k = L.linear(x, params["wk"], params.get("bk")).reshape(B, S, cfg.num_kv_heads, D)
    v = L.linear(x, params["wv"], params.get("bv")).reshape(B, S, cfg.num_kv_heads, D)

    if cfg.use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.rope_dim)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.rope_dim)

    if cache is None:
        out = L.attention(
            q, k, v, causal=cfg.causal,
            q_positions=positions, kv_positions=positions,
            window=window, softcap=cfg.attn_softcap, scale=cfg.query_scale)
        new_cache = None
    elif window is not None and cache["k"].shape[1] <= window:
        # Ring cache (§Perf A4): sliding-window layers keep only the last
        # ``window`` positions.  The ring invariantly holds exactly the
        # causally-visible window of the current query, so no causal or
        # window masking is needed — only a written-slot check early on.
        W = cache["k"].shape[1]
        ck, cv = cache["k"], cache["v"]
        if S == 1:                                        # decode
            slot = cache_pos % W
            ck = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, slot, 0, 0))
            iota_w = jnp.broadcast_to(jnp.arange(W, dtype=jnp.int32), (B, W))
            kv_valid = iota_w <= cache_pos
            out = L.attention(
                q, ck.astype(q.dtype), cv.astype(q.dtype), causal=False,
                q_positions=positions, kv_positions=iota_w,
                kv_valid=kv_valid, softcap=cfg.attn_softcap,
                scale=cfg.query_scale)
        else:                                             # prefill
            # attend in-sequence (full k/v), then store the rotated tail
            out = L.attention(
                q, k, v, causal=cfg.causal,
                q_positions=positions, kv_positions=positions,
                window=window, softcap=cfg.attn_softcap,
                scale=cfg.query_scale)
            if S >= W:
                tail_pos = S - W + jnp.arange(W)           # absolute positions
                slots = tail_pos % W
                ck = ck.at[:, slots].set(k[:, -W:].astype(ck.dtype))
                cv = cv.at[:, slots].set(v[:, -W:].astype(cv.dtype))
            else:
                ck = jax.lax.dynamic_update_slice(
                    ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
    else:
        ck, cv = cache["k"], cache["v"]                   # [B, Smax, KV, D]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        Smax = ck.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
        kv_valid = kv_pos < (cache_pos + S)
        out = L.attention(
            q, ck.astype(q.dtype), cv.astype(q.dtype), causal=cfg.causal,
            q_positions=positions, kv_positions=kv_pos, kv_valid=kv_valid,
            window=window, softcap=cfg.attn_softcap, scale=cfg.query_scale)
        new_cache = {"k": ck, "v": cv}

    y = L.linear(out.reshape(B, S, cfg.num_heads * D), params["wo"])
    if cfg.attn_batch_shard and cache is None:
        from repro.distributed.sharding import constrain
        y = constrain(y, ("dp", None, None))
    return y, new_cache


def gqa_cache_shape(cfg, batch: int, max_seq: int, window: Optional[int] = None):
    """KV-cache slice shape for one layer (window caps local-layer caches)."""
    D = cfg.resolved_head_dim
    s = max_seq if window is None else min(max_seq, window)
    return {
        "k": (batch, s, cfg.num_kv_heads, D),
        "v": (batch, s, cfg.num_kv_heads, D),
    }


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v2)
# ---------------------------------------------------------------------------

def mla_init(cfg, key, dtype):
    ks = jax.random.split(key, 6)
    H = cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        # queries are not compressed at v2-lite size (q_lora_rank = None)
        "wq": L.dense_init(ks[0], cfg.d_model, H * qk, dtype),
        # joint KV compression to kv_lora_rank + decoupled rope key
        "wkv_down": L.dense_init(ks[1], cfg.d_model, cfg.kv_lora_rank, dtype),
        "kv_norm": jnp.ones(cfg.kv_lora_rank),
        "wk_rope": L.dense_init(ks[2], cfg.d_model, cfg.qk_rope_dim, dtype),
        "wk_up": L.dense_init(ks[3], cfg.kv_lora_rank, H * cfg.qk_nope_dim, dtype),
        "wv_up": L.dense_init(ks[4], cfg.kv_lora_rank, H * cfg.v_head_dim, dtype),
        "wo": L.dense_init(ks[5], H * cfg.v_head_dim, cfg.d_model, dtype, scale=0.5),
    }


def _mla_expand(cfg, params, c_kv, k_pe):
    """Expand compressed cache (c_kv, rope key) to per-head K/V."""
    B, S, _ = c_kv.shape
    H = cfg.num_heads
    k_nope = L.linear(c_kv, params["wk_up"]).reshape(B, S, H, cfg.qk_nope_dim)
    v = L.linear(c_kv, params["wv_up"]).reshape(B, S, H, cfg.v_head_dim)
    k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, cfg.qk_rope_dim))
    k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    return k, v


def mla_apply(cfg, params, x, *, positions, cache=None, cache_pos=None,
              window: Optional[int] = None):
    B, S, _ = x.shape
    H = cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim

    q = L.linear(x, params["wq"]).reshape(B, S, H, qk)
    q_nope, q_pe = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_pe = L.apply_rope(q_pe, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)

    c_kv = L.rmsnorm(L.linear(x, params["wkv_down"]), params["kv_norm"])
    k_pe = L.apply_rope(
        L.linear(x, params["wk_rope"])[:, :, None, :], positions,
        cfg.rope_theta)[:, :, 0, :]

    scale = cfg.query_scale or qk ** -0.5
    if cache is None:
        k, v = _mla_expand(cfg, params, c_kv, k_pe)
        out = L.attention(q, k, v, causal=cfg.causal,
                          q_positions=positions, kv_positions=positions,
                          window=window, softcap=cfg.attn_softcap, scale=scale)
        new_cache = None
    else:
        cc = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_pos, 0))
        cp = jax.lax.dynamic_update_slice(
            cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, cache_pos, 0))
        Smax = cc.shape[1]
        # Baseline decode expands the compressed cache to per-head K/V each
        # step; the absorbed-matmul variant is a recorded perf iteration.
        k, v = _mla_expand(cfg, params, cc.astype(x.dtype), cp.astype(x.dtype))
        kv_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
        kv_valid = kv_pos < (cache_pos + S)
        out = L.attention(q, k, v, causal=cfg.causal,
                          q_positions=positions, kv_positions=kv_pos,
                          kv_valid=kv_valid, window=window,
                          softcap=cfg.attn_softcap, scale=scale)
        new_cache = {"c_kv": cc, "k_pe": cp}

    y = L.linear(out.reshape(B, S, H * cfg.v_head_dim), params["wo"])
    return y, new_cache


def mla_cache_shape(cfg, batch: int, max_seq: int, window=None):
    return {
        "c_kv": (batch, max_seq, cfg.kv_lora_rank),
        "k_pe": (batch, max_seq, cfg.qk_rope_dim),
    }
