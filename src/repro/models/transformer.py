"""Decoder-only transformer LM covering the dense / MoE / VLM-backbone archs
(qwen2.5, smollm, olmo, gemma2, phi-3-vision backbone, granite-moe,
deepseek-v2-lite).

Layers are *stacked* ([L, ...] leaves) and executed with ``jax.lax.scan`` so
54-layer models lower to a small HLO (essential for 512-device AOT compiles
on this container).  Heterogeneous layer patterns are handled by stacking a
repeating *group* of layers and scanning over groups:
  * gemma2: group = (local, global)            -> scan over L/2 groups
  * deepseek: first k dense layers unrolled, then scan over MoE layers
All other archs: group = 1 uniform layer.

The same block code serves training (no cache), prefill (cache write) and
decode (cache append) — see models/attention.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.config import ModelConfig


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# one transformer block
# ---------------------------------------------------------------------------

def block_init(cfg: ModelConfig, key, *, is_moe: bool, dtype):
    norm_init, _ = L.make_norm(cfg)
    ks = jax.random.split(key, 8)
    attn_init = A.mla_init if cfg.mla else A.gqa_init
    p = {
        "ln1": norm_init(ks[0]),
        "attn": attn_init(cfg, ks[1], dtype),
        "ln2": norm_init(ks[2]),
    }
    if is_moe:
        p["moe"] = MOE.moe_init(cfg, ks[3], dtype)
    else:
        p["mlp"] = L.mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_block_norms:                      # gemma2 sandwich norms
        p["ln1_post"] = norm_init(ks[4])
        p["ln2_post"] = norm_init(ks[5])
    return p


def block_apply(cfg: ModelConfig, params, x, *, positions, window,
                cache=None, cache_pos=None, is_moe: bool = False):
    from repro.distributed.sharding import constrain
    _, norm = L.make_norm(cfg)
    attn_apply = A.mla_apply if cfg.mla else A.gqa_apply
    act_sh = cfg.activation_sharding

    if act_sh:
        # Propagation barrier: the residual stream is batch-sharded,
        # model-replicated.  Keeps an attention block whose head count does
        # not divide the model axis (e.g. smollm's 15 heads) from
        # contaminating the MLP/vocab matmuls into full replication.
        x = constrain(x, ("dp", None, None))

    h = norm(x, params["ln1"])
    attn_out, new_cache = attn_apply(
        cfg, params["attn"], h, positions=positions,
        cache=cache, cache_pos=cache_pos, window=window)
    if cfg.post_block_norms:
        attn_out = norm(attn_out, params["ln1_post"])
    if act_sh:
        attn_out = constrain(attn_out, ("dp", None, None))
    x = x + attn_out

    h = norm(x, params["ln2"])
    if is_moe:
        ffn_out, aux = MOE.moe_apply(cfg, params["moe"], h)
    else:
        ffn_out, aux = L.mlp_apply(params["mlp"], h, cfg.activation,
                                   act_sharding=act_sh), 0.0
    if cfg.post_block_norms:
        ffn_out = norm(ffn_out, params["ln2_post"])
    if act_sh:
        ffn_out = constrain(ffn_out, ("dp", None, None))
    return x + ffn_out, new_cache, aux


# ---------------------------------------------------------------------------
# the full LM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """How the L layers decompose into (unrolled prefix, scanned groups)."""
    prefix_moe: tuple[bool, ...]      # unrolled leading layers (deepseek dense)
    group_windows: tuple[Optional[int], ...]   # windows within a scanned group
    group_moe: tuple[bool, ...]
    num_groups: int


def layer_plan(cfg: ModelConfig) -> LayerPlan:
    if cfg.local_global_pattern:
        assert cfg.num_layers % 2 == 0
        return LayerPlan((), (cfg.window, None), (False, False),
                         cfg.num_layers // 2)
    n_prefix = cfg.first_dense_layers
    scanned = cfg.num_layers - n_prefix
    is_moe = cfg.num_experts > 0
    return LayerPlan(tuple(False for _ in range(n_prefix)),
                     (cfg.window,), (is_moe,), scanned)


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = layer_plan(cfg)
        self.dtype = _dtype(cfg.param_dtype)

    # -- params ------------------------------------------------------------
    def init(self, key) -> dict:
        cfg, plan = self.cfg, self.plan
        norm_init, _ = L.make_norm(cfg)
        kemb, khead, kfinal, kpre, kstack = jax.random.split(key, 5)
        params = {
            "embed": L.embed_init(kemb, cfg.vocab_size, cfg.d_model, self.dtype),
            "final_norm": norm_init(kfinal),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(khead, cfg.d_model,
                                             cfg.vocab_size, self.dtype)
        # unrolled prefix layers
        prefix = []
        for i, is_moe in enumerate(plan.prefix_moe):
            prefix.append(block_init(cfg, jax.random.fold_in(kpre, i),
                                     is_moe=is_moe, dtype=self.dtype))
        if prefix:
            params["prefix"] = prefix
        # scanned stacked groups: leaves [num_groups, ...]
        G = len(plan.group_windows)

        def init_group(key):
            ks = jax.random.split(key, G)
            return [block_init(cfg, ks[g], is_moe=plan.group_moe[g],
                               dtype=self.dtype) for g in range(G)]

        group_keys = jax.random.split(kstack, plan.num_groups)
        stacked = jax.vmap(init_group)(group_keys)
        params["layers"] = stacked
        return params

    # -- caches ------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=None) -> dict:
        cfg, plan = self.cfg, self.plan
        dtype = dtype or self.dtype
        shape_fn = A.mla_cache_shape if cfg.mla else A.gqa_cache_shape

        def zeros_for(window):
            # ring_cache: sliding-window layers hold only `window` slots
            s_alloc = (min(window, max_seq)
                       if (window is not None and cfg.ring_cache) else max_seq)
            return {k: jnp.zeros(s, dtype)
                    for k, s in shape_fn(cfg, batch, s_alloc).items()}

        cache = {"pos": jnp.zeros((), jnp.int32)}
        if plan.prefix_moe:
            cache["prefix"] = [zeros_for(None) for _ in plan.prefix_moe]
        cache["layers"] = [
            jax.tree.map(lambda z: jnp.broadcast_to(z, (plan.num_groups,) + z.shape)
                         .astype(dtype), zeros_for(w))
            for w in plan.group_windows
        ]
        return cache

    # -- forward -----------------------------------------------------------
    def _embed(self, params, tokens, vision_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        return x

    def _unembed(self, params, x):
        cfg = self.cfg
        _, norm = L.make_norm(cfg)
        x = norm(x, params["final_norm"])
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, w,
                            preferred_element_type=jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits

    def _run_layers(self, params, x, positions, cache=None, cache_pos=None):
        """Shared trunk: unrolled prefix + scanned groups."""
        cfg, plan = self.cfg, self.plan
        aux_total = 0.0
        new_prefix_cache = []
        for i, is_moe in enumerate(plan.prefix_moe):
            c = cache["prefix"][i] if cache is not None else None
            x, nc, aux = block_apply(cfg, params["prefix"][i], x,
                                     positions=positions, window=None,
                                     cache=c, cache_pos=cache_pos,
                                     is_moe=is_moe)
            aux_total += aux
            new_prefix_cache.append(nc)

        G = len(plan.group_windows)

        def scan_body(carry, xs):
            x, aux_acc = carry
            layer_params, layer_cache = xs
            new_caches = []
            for g in range(G):
                c = layer_cache[g] if layer_cache is not None else None
                x, nc, aux = block_apply(
                    cfg, layer_params[g], x, positions=positions,
                    window=plan.group_windows[g], cache=c,
                    cache_pos=cache_pos, is_moe=plan.group_moe[g])
                aux_acc = aux_acc + aux
                new_caches.append(nc)
            return (x, aux_acc), new_caches

        body = scan_body
        if cfg.remat and cache is None:
            policy = (jax.checkpoint_policies.checkpoint_dots
                      if cfg.remat_policy == "dots" else None)
            body = jax.checkpoint(scan_body, policy=policy)

        layer_cache = cache["layers"] if cache is not None else None
        xs = (params["layers"], layer_cache)
        if cfg.unroll_layers:
            # Python loop over stacked slices: identical math, no while-loop —
            # used by the dry-run cost calibration (XLA's HloCostAnalysis does
            # not multiply while-loop bodies by trip count).
            outs = []
            for i in range(plan.num_groups):
                xs_i = jax.tree.map(lambda a: a[i], xs)
                (x, aux_total), nc = body((x, aux_total), xs_i)
                outs.append(nc)
            new_layer_caches = (jax.tree.map(
                lambda *ls: jnp.stack(ls), *outs) if cache is not None else None)
        else:
            (x, aux_total), new_layer_caches = jax.lax.scan(
                body, (x, aux_total), xs)

        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            if plan.prefix_moe:
                new_cache["prefix"] = new_prefix_cache
            new_cache["layers"] = new_layer_caches
        return x, new_cache, aux_total

    # -- public entry points -------------------------------------------------
    def forward_train(self, params, batch):
        """-> (logits over text positions, aux_loss)."""
        tokens = batch["tokens"]
        vision = batch.get("vision_embeds")
        x = self._embed(params, tokens, vision)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, _, aux = self._run_layers(params, x, positions)
        if vision is not None:
            x = x[:, vision.shape[1]:]            # loss only on text positions
        return self._unembed(params, x), aux

    def prefill(self, params, batch, cache):
        tokens = batch["tokens"]
        vision = batch.get("vision_embeds")
        x = self._embed(params, tokens, vision)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, cache, _ = self._run_layers(params, x, positions,
                                       cache=cache, cache_pos=0)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        logits = self._unembed(params, x[:, -1:])
        return logits, cache

    def decode_step(self, params, token, cache):
        """token: i32[B, 1]; cache holds ``pos`` tokens already."""
        x = self._embed(params, token)
        B = x.shape[0]
        pos = cache["pos"]
        positions = jnp.full((B, 1), pos, jnp.int32)
        x, cache, _ = self._run_layers(params, x, positions,
                                       cache=cache, cache_pos=pos)
        cache = dict(cache)
        cache["pos"] = pos + 1
        return self._unembed(params, x), cache

    def loss_fn(self, params, batch):
        logits, aux = self.forward_train(params, batch)
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        if mask is None:
            loss = -jnp.mean(ll)
        else:
            loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + aux, {"ce": loss, "aux": aux}
