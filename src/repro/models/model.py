"""Unified model builder: ``build_model(cfg)`` -> family-specific model with a
common interface:

    model.init(key) -> params
    model.loss_fn(params, batch) -> (loss, metrics)      # train objective
    model.forward_train(params, batch) -> (logits, aux)
    model.init_cache(batch, max_seq) -> cache            # None for encoders
    model.prefill(params, batch, cache) -> (logits, cache)
    model.decode_step(params, token, cache) -> (logits, cache)
"""
from __future__ import annotations

from repro.models.config import ModelConfig, reduce_for_smoke
from repro.models.encoder import Encoder
from repro.models.mamba2 import Zamba2
from repro.models.transformer import TransformerLM
from repro.models.xlstm import XLSTM


def build_model(cfg: ModelConfig):
    if cfg.family == "audio":
        return Encoder(cfg)
    if cfg.family == "hybrid":
        return Zamba2(cfg)
    if cfg.family == "ssm":
        return XLSTM(cfg)
    # dense / moe / vlm share the TransformerLM trunk
    return TransformerLM(cfg)


__all__ = ["ModelConfig", "build_model", "reduce_for_smoke"]
