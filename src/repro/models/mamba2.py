"""Mamba2 (SSD) mixer + the Zamba2 hybrid model.

Zamba2 = a backbone of Mamba2 layers with one *shared* transformer block
(attention + MLP, single parameter set) applied every ``attn_every`` layers.
Each application concatenates the current hidden state with the original
embedding ([h; e] -> 2d -> d projection) and keeps its own KV cache.

The SSD scan has three implementations:
  * chunked parallel form (training/prefill) — pure jnp here, Pallas kernel in
    kernels/mamba_scan.py for the per-chunk hot loop,
  * recurrent single-step (decode) with O(1) state,
both derived from the same discretization so they agree numerically.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models.config import ModelConfig

CHUNK = 128


# ---------------------------------------------------------------------------
# Mamba2 layer
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    return d_inner, nheads


def mamba_init(cfg: ModelConfig, key, dtype):
    d_inner, H = mamba_dims(cfg)
    N = cfg.ssm_state
    conv_ch = d_inner + 2 * N                       # x, B, C share the conv
    ks = jax.random.split(key, 6)
    return {
        "norm": jnp.ones(cfg.d_model),
        "in_proj": L.dense_init(ks[0], cfg.d_model,
                                2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros(conv_ch, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones(H, jnp.float32),
        "dt_bias": jnp.zeros(H, jnp.float32),
        "gate_norm": jnp.ones(d_inner),
        "out_proj": L.dense_init(ks[2], d_inner, cfg.d_model, dtype, scale=0.5),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv, width W (small, unrolled). x: [B, S, C]."""
    W = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(pads[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return y + b


def _conv_step(conv_state, x_t, w, b):
    """conv_state: [B, W-1, C]; x_t: [B, C] -> (y_t, new_state)."""
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)   # [B, W, C]
    y = jnp.einsum("bwc,wc->bc", full, w) + b
    return y, full[:, 1:, :]


def ssd_chunked(x, dt, A, Bm, Cm, D, h0=None):
    """Chunked SSD scan (Mamba2 paper §6).

    x: [B,S,H,P], dt: [B,S,H] (already softplus'd), A: [H] (negative),
    Bm/Cm: [B,S,N], D: [H].  Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(CHUNK, S)
    assert S % Q == 0, (S, Q)
    C = S // Q

    xc = x.reshape(Bsz, C, Q, H, P)
    dtc = dt.reshape(Bsz, C, Q, H)
    Bc = Bm.reshape(Bsz, C, Q, N)
    Cc = Cm.reshape(Bsz, C, Q, N)

    dA = dtc * A                                       # [B,C,Q,H] log-decay
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    total = cum[:, :, -1:, :]                          # [B,C,1,H]

    # intra-chunk (attention-like, lower-triangular decay kernel).
    # Mask BEFORE the exp: exp of the (huge, positive) masked upper triangle
    # would overflow and poison the backward pass (0 * inf = NaN in the VJP).
    Lmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [B,C,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.exp(jnp.where(mask[None, None, :, :, None], Lmat, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # [B,C,Qi,Qj]
    weighted = scores[..., None] * Lmat                       # [B,C,Qi,Qj,H]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", weighted, dtc, xc)

    # chunk states: contribution of chunk c to the carried state
    decay_out = jnp.exp(total - cum)                          # [B,C,Q,H]
    state_c = jnp.einsum("bcqh,bcqh,bcqn,bcqhp->bchpn",
                         decay_out, dtc, Bc, xc)              # [B,C,H,P,N]

    # inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(total[:, :, 0, :])                  # [B,C,H]

    def scan_fn(h, inp):
        dec, s = inp                                          # [B,H], [B,H,P,N]
        h_new = h * dec[:, :, None, None] + s
        return h_new, h                                       # emit h_{c-1}

    init = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    hT, h_prev = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(state_c, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                       # [B,C,H,P,N]

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc, jnp.exp(cum), h_prev)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P) + D[None, None, :, None] * x
    return y.astype(x.dtype), hT


def ssd_step(h, x_t, dt_t, A, B_t, C_t, D):
    """Single-token recurrent update.  h: [B,H,P,N]."""
    dA = jnp.exp(dt_t * A)                                    # [B,H]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t, x_t)
    h = h * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", C_t, h) + D[None, :, None] * x_t
    return y, h


def mamba_apply(cfg: ModelConfig, params, x, *, cache=None):
    """x: [B,S,d].  cache (decode): {"ssm": [B,H,P,N], "conv": [B,W-1,C]}.

    Training/prefill: S arbitrary (padded to CHUNK), cache out only if given.
    Decode: S == 1, O(1) state update.
    """
    Bsz, S, _ = x.shape
    d_inner, H = mamba_dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_headdim
    resid = x
    x = L.rmsnorm(x, params["norm"])
    proj = L.linear(x, params["in_proj"])
    z, xbc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    A = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    if cache is None or S > 1:
        conv_in = xbc
        conv = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"]))
        xs, Bm, Cm = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
        xs = xs.reshape(Bsz, S, H, P)
        pad = (-S) % CHUNK
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cmp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        else:
            dtp, Cmp = dt, Cm
        h0 = cache["ssm"] if cache is not None else None
        y, hT = ssd_chunked(xs.astype(jnp.float32), dtp, A,
                            Bm.astype(jnp.float32), Cmp.astype(jnp.float32),
                            params["D"], h0)
        y = y[:, :S].reshape(Bsz, S, d_inner)
        new_cache = None
        if cache is not None:
            W = cfg.ssm_conv
            tail = jnp.pad(conv_in, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):, :]
            new_cache = {"ssm": hT.astype(cache["ssm"].dtype),
                         "conv": tail.astype(cache["conv"].dtype)}
    else:
        conv_y, conv_state = _conv_step(
            cache["conv"].astype(xbc.dtype), xbc[:, 0], params["conv_w"],
            params["conv_b"])
        conv_y = jax.nn.silu(conv_y)
        xs, Bm, Cm = jnp.split(conv_y, [d_inner, d_inner + N], axis=-1)
        y, h = ssd_step(cache["ssm"].astype(jnp.float32),
                        xs.reshape(Bsz, H, P).astype(jnp.float32),
                        dt[:, 0], A, Bm.astype(jnp.float32),
                        Cm.astype(jnp.float32), params["D"])
        y = y.reshape(Bsz, 1, d_inner)
        new_cache = {"ssm": h.astype(cache["ssm"].dtype),
                     "conv": conv_state.astype(cache["conv"].dtype)}

    y = L.rmsnorm(y.astype(resid.dtype) * jax.nn.silu(z), params["gate_norm"])
    out = L.linear(y, params["out_proj"])
    return resid + out, new_cache


def mamba_cache_shape(cfg: ModelConfig, batch: int):
    d_inner, H = mamba_dims(cfg)
    return {
        "ssm": (batch, H, cfg.ssm_headdim, cfg.ssm_state),
        "conv": (batch, cfg.ssm_conv - 1, d_inner + 2 * cfg.ssm_state),
    }


# ---------------------------------------------------------------------------
# Zamba2: Mamba2 backbone + shared attention block
# ---------------------------------------------------------------------------

class Zamba2:
    """cfg.attn_every Mamba2 layers per shared-attention application."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.num_layers % cfg.attn_every == 0
        self.cfg = cfg
        self.num_apps = cfg.num_layers // cfg.attn_every
        self.dtype = {"float32": jnp.float32,
                      "bfloat16": jnp.bfloat16}[cfg.param_dtype]

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        layer_keys = jax.random.split(ks[0], cfg.num_layers)
        stacked = jax.vmap(lambda k: mamba_init(cfg, k, self.dtype))(layer_keys)
        shared = {
            "in_proj": L.dense_init(ks[1], 2 * cfg.d_model, cfg.d_model,
                                    self.dtype),
            "ln1": jnp.ones(cfg.d_model),
            "attn": A.gqa_init(cfg, ks[2], self.dtype),
            "ln2": jnp.ones(cfg.d_model),
            "mlp": L.mlp_init(ks[3], cfg.d_model, cfg.d_ff, self.dtype),
            # per-application output projections (cheap, application-specific)
            "out_proj": jnp.stack([
                L.dense_init(jax.random.fold_in(ks[4], i), cfg.d_model,
                             cfg.d_model, self.dtype, scale=0.5)
                for i in range(self.num_apps)]),
        }
        return {
            "embed": L.embed_init(ks[5], cfg.vocab_size, cfg.d_model, self.dtype),
            "layers": stacked,
            "shared": shared,
            "final_norm": jnp.ones(cfg.d_model),
        }

    def init_cache(self, batch: int, max_seq: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        D = cfg.resolved_head_dim
        mshape = mamba_cache_shape(cfg, batch)
        return {
            "pos": jnp.zeros((), jnp.int32),
            "mamba": {k: jnp.zeros((cfg.num_layers,) + s, dtype)
                      for k, s in mshape.items()},
            "attn_k": jnp.zeros((self.num_apps, batch, max_seq,
                                 cfg.num_kv_heads, D), dtype),
            "attn_v": jnp.zeros((self.num_apps, batch, max_seq,
                                 cfg.num_kv_heads, D), dtype),
        }

    def _shared_block(self, params, h, emb, app_idx, *, positions,
                      kv=None, cache_pos=None):
        cfg = self.cfg
        s = params["shared"]
        u = L.linear(jnp.concatenate([h, emb], axis=-1), s["in_proj"])
        a_in = L.rmsnorm(u, s["ln1"])
        attn_out, new_kv = A.gqa_apply(cfg, s["attn"], a_in,
                                       positions=positions, cache=kv,
                                       cache_pos=cache_pos)
        u = u + attn_out
        u = u + L.mlp_apply(s["mlp"], L.rmsnorm(u, s["ln2"]), cfg.activation)
        return h + L.linear(u, s["out_proj"][app_idx]), new_kv

    def _trunk(self, params, x, positions, cache=None, cache_pos=None):
        cfg = self.cfg
        emb = x
        k_every = cfg.attn_every
        new_cache = None if cache is None else jax.tree.map(lambda a: a, cache)

        for app in range(self.num_apps):
            kv = None
            if cache is not None:
                kv = {"k": cache["attn_k"][app], "v": cache["attn_v"][app]}
            x, new_kv = self._shared_block(params, x, emb, app,
                                           positions=positions, kv=kv,
                                           cache_pos=cache_pos)
            if cache is not None:
                new_cache["attn_k"] = new_cache["attn_k"].at[app].set(new_kv["k"])
                new_cache["attn_v"] = new_cache["attn_v"].at[app].set(new_kv["v"])

            lo = app * k_every
            sl = jax.tree.map(lambda a: a[lo:lo + k_every], params["layers"])

            if cache is None:
                def body(h, layer_params):
                    h, _ = mamba_apply(cfg, layer_params, h)
                    return h, None
                if cfg.remat:
                    body = jax.checkpoint(body)
                if cfg.unroll_layers:
                    for i in range(k_every):
                        x, _ = body(x, jax.tree.map(lambda a: a[i], sl))
                else:
                    x, _ = jax.lax.scan(body, x, sl)
            else:
                mc = jax.tree.map(lambda a: a[lo:lo + k_every], cache["mamba"])

                def body_c(h, xs):
                    layer_params, layer_cache = xs
                    h, nc = mamba_apply(cfg, layer_params, h, cache=layer_cache)
                    return h, nc
                if cfg.unroll_layers:
                    parts = []
                    for i in range(k_every):
                        x, nc = body_c(x, jax.tree.map(lambda a: a[i], (sl, mc)))
                        parts.append(nc)
                    new_mc = jax.tree.map(lambda *ls: jnp.stack(ls), *parts)
                else:
                    x, new_mc = jax.lax.scan(body_c, x, (sl, mc))
                new_cache["mamba"] = jax.tree.map(
                    lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                        full, part, lo, axis=0),
                    new_cache["mamba"], new_mc)
        return x, new_cache

    # -- public API (matches TransformerLM) --------------------------------
    def forward_train(self, params, batch):
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, _ = self._trunk(params, x, positions)
        logits = jnp.einsum("bsd,vd->bsv", L.rmsnorm(x, params["final_norm"]),
                            params["embed"], preferred_element_type=jnp.float32)
        return logits, 0.0

    def prefill(self, params, batch, cache):
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, cache = self._trunk(params, x, positions, cache=cache, cache_pos=0)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        logits = jnp.einsum("bsd,vd->bsv",
                            L.rmsnorm(x[:, -1:], params["final_norm"]),
                            params["embed"], preferred_element_type=jnp.float32)
        return logits, cache

    def decode_step(self, params, token, cache):
        x = params["embed"][token]
        B = x.shape[0]
        pos = cache["pos"]
        positions = jnp.full((B, 1), pos, jnp.int32)
        x, cache = self._trunk(params, x, positions, cache=cache, cache_pos=pos)
        cache["pos"] = pos + 1
        logits = jnp.einsum("bsd,vd->bsv", L.rmsnorm(x, params["final_norm"]),
                            params["embed"], preferred_element_type=jnp.float32)
        return logits, cache

    def loss_fn(self, params, batch):
        logits, _ = self.forward_train(params, batch)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
        loss = -jnp.mean(ll)
        return loss, {"ce": loss, "aux": 0.0}
