"""Encoder-only transformer (hubert-xlarge backbone).

The audio frontend (waveform -> conv feature extractor) is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
[B, S, d_model].  The backbone is faithful to wav2vec2/HuBERT-XL: pre-LN
bidirectional transformer with a convolutional relative positional embedding
and a masked-prediction objective over ``vocab_size`` (504) cluster targets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models.config import ModelConfig


class Encoder:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = {"float32": jnp.float32,
                      "bfloat16": jnp.bfloat16}[cfg.param_dtype]

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        norm_init, _ = L.make_norm(cfg)

        def layer_init(k):
            lk = jax.random.split(k, 4)
            return {
                "ln1": norm_init(lk[0]),
                "attn": A.gqa_init(cfg, lk[1], self.dtype),
                "ln2": norm_init(lk[2]),
                "mlp": L.mlp_init(lk[3], cfg.d_model, cfg.d_ff, self.dtype),
            }

        layer_keys = jax.random.split(ks[0], cfg.num_layers)
        return {
            # conv relative positional embedding (depthwise, width 128 -> 8
            # here to keep HLO small; the receptive-field role is identical)
            "pos_conv_w": (jax.random.normal(ks[1], (8, cfg.d_model)) * 0.05
                           ).astype(self.dtype),
            "mask_embed": (jax.random.normal(ks[2], (cfg.d_model,)) * 0.02
                           ).astype(self.dtype),
            "layers": jax.vmap(layer_init)(layer_keys),
            "final_norm": norm_init(ks[3]),
            "head": L.dense_init(ks[4], cfg.d_model, cfg.vocab_size, self.dtype),
        }

    def encode(self, params, frames, mask=None):
        """frames: f32[B, S, d]; mask: bool[B, S] (True = replaced/masked)."""
        cfg = self.cfg
        _, norm = L.make_norm(cfg)
        x = frames.astype(self.dtype)
        if mask is not None:
            x = jnp.where(mask[..., None], params["mask_embed"], x)
        # symmetric (non-causal) conv positional embedding
        W = params["pos_conv_w"].shape[0]
        pad = W // 2
        xp = jnp.pad(x, ((0, 0), (pad, W - 1 - pad), (0, 0)))
        pos = sum(xp[:, i:i + x.shape[1], :] * params["pos_conv_w"][i]
                  for i in range(W))
        x = x + pos

        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(h, layer_params):
            a_in = norm(h, layer_params["ln1"])
            attn_out, _ = A.gqa_apply(cfg, layer_params["attn"], a_in,
                                      positions=positions)
            h = h + attn_out
            h = h + L.mlp_apply(layer_params["mlp"],
                                norm(h, layer_params["ln2"]), cfg.activation)
            return h, None

        fn = jax.checkpoint(body) if cfg.remat else body
        if cfg.unroll_layers:
            for i in range(cfg.num_layers):
                x, _ = fn(x, jax.tree.map(lambda a: a[i], params["layers"]))
        else:
            x, _ = jax.lax.scan(fn, x, params["layers"])
        return norm(x, params["final_norm"])

    def forward_train(self, params, batch):
        x = self.encode(params, batch["frames"], batch.get("mask"))
        logits = L.linear(x, params["head"]).astype(jnp.float32)
        return logits, 0.0

    def loss_fn(self, params, batch):
        """HuBERT masked prediction: CE over masked frames only."""
        logits, _ = self.forward_train(params, batch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
        m = batch["mask"].astype(jnp.float32)
        loss = -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)
        return loss, {"ce": loss, "aux": 0.0}

    # Encoder-only: no decode; prefill == full forward (used by prefill_32k).
    def prefill(self, params, batch, cache=None):
        x = self.encode(params, batch["frames"])
        return L.linear(x, params["head"]).astype(jnp.float32), cache

    def init_cache(self, batch: int, max_seq: int, dtype=None):
        return None
