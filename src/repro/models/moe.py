"""Mixture-of-Experts layer (granite-moe, deepseek-v2-lite).

Top-k routing with capacity-bounded, sort-based dispatch (GShard-style but
scatter/gather instead of one-hot einsums, so HLO FLOPs stay proportional to
*active* compute — important for an honest roofline).  Expert weights are
stacked [E, ...] so expert parallelism is a PartitionSpec on axis 0.

Deepseek-v2 specifics supported: shared experts (always-on), top-k softmax
renormalization, first-k-dense layers (handled by the caller's block pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_init(cfg, key, dtype):
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)

    def expert_stack(k, d_in, d_out, scale=1.0):
        keys = jax.random.split(k, E)
        return jnp.stack([L.dense_init(keys[e], d_in, d_out, dtype, scale)
                          for e in range(E)])

    p = {
        "router": L.dense_init(ks[0], d, E, jnp.float32),
        "w_gate": expert_stack(ks[1], d, f),
        "w_up": expert_stack(ks[2], d, f),
        "w_down": expert_stack(ks[3], f, d, scale=0.5),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, f * cfg.num_shared_experts, dtype)
    return p


def _routing(cfg, params, xf):
    """Shared routing math: -> (gates [T,k], idx [T,k], aux loss)."""
    E, k = cfg.num_experts, cfg.top_k
    T = xf.shape[0]
    logits = L.linear(xf.astype(jnp.float32), params["router"])   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                          # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(jnp.ones(T * k) / (T * k))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return gates, idx, aux


def moe_apply(cfg, params, x):
    if cfg.moe_impl == "ep":
        y_aux = _moe_apply_ep(cfg, params, x)
        if y_aux is not None:
            return y_aux
        # no mesh in scope (single-device tests): fall through to global
    return _moe_apply_global(cfg, params, x)


def _moe_apply_global(cfg, params, x):
    """x: [B, S, d] -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, d)

    logits = L.linear(xf.astype(jnp.float32), params["router"])   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                          # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(
        jnp.ones(T * k) / (T * k))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # Decode (S == 1) is dropless — capacity drops would silently corrupt
    # generation; T*k is tiny there.  Train/prefill use the configured
    # capacity factor (drops are the standard TPU MoE trade-off).
    if S == 1:
        capacity = T * k
    else:
        capacity = max(int(T * k / E * cfg.capacity_factor), k)
    capacity = min(capacity, T * k)

    # --- sort-based dispatch ---
    e_flat = idx.reshape(-1)                                      # [T*k]
    g_flat = gates.reshape(-1)
    tok_flat = jnp.arange(T * k, dtype=jnp.int32) // k
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    g_sorted = g_flat[order]
    start = jnp.searchsorted(e_sorted, jnp.arange(E, dtype=e_sorted.dtype))
    rank = jnp.arange(T * k, dtype=jnp.int32) - start[e_sorted].astype(jnp.int32)
    keep = rank < capacity
    slot = jnp.clip(rank, 0, capacity - 1)

    buf = jnp.zeros((E, capacity, d), xf.dtype)
    contrib = jnp.where(keep[:, None], xf[tok_sorted], 0)
    buf = buf.at[e_sorted, slot].add(contrib)

    # --- expert FFN on [E, capacity, d] (vmapped over the expert axis) ---
    act = L.act_fn(cfg.activation)
    def expert_ffn(b, wg, wu, wd):
        h = act(L.linear(b, wg)) * L.linear(b, wu)
        return L.linear(h.astype(b.dtype), wd)
    h = jax.vmap(expert_ffn)(buf, params["w_gate"], params["w_up"],
                             params["w_down"])                    # [E, cap, d]

    # --- combine ---
    y_slot = (h[e_sorted, slot].astype(jnp.float32)
              * jnp.where(keep, g_sorted, 0.0)[:, None])
    y = jnp.zeros((T, d), jnp.float32).at[tok_sorted].add(y_slot)

    if cfg.num_shared_experts:
        y = y + L.mlp_apply(params["shared"], xf, cfg.activation)
    return y.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# expert-parallel implementation (§Perf B): shard_map over (data x model)
# ---------------------------------------------------------------------------

def _get_mesh():
    try:
        mesh = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if mesh is not None and getattr(mesh, "axis_names", None):
            return mesh
    except Exception:
        pass
    return None


def _moe_apply_ep(cfg, params, x):
    """Expert parallelism via shard_map.

    The baseline ("global") dispatch sorts/scatters over the *globally
    sharded* token axis, which XLA can only implement by gathering tokens
    across the mesh — measured at ~1.6e13 collective bytes/step for
    deepseek train_4k.  Here instead:

      * routing + capacity dispatch run per data-shard (local tokens only),
      * each model rank scatters/computes only its E/ep experts,
      * partial expert outputs combine with one bf16 psum over "model"
        (the same wire pattern as a row-parallel matmul),
      * aux loss is pmean'd over the whole mesh (exact replication).

    Returns None when no (data, model) mesh is in scope (single-device
    tests fall back to the global path).
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    mesh = _get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    try:
        from jax import shard_map as _shard_map

        def shard_map_fn(f, in_specs, out_specs):
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
    except (ImportError, TypeError):
        from jax.experimental.shard_map import shard_map as _shard_map_legacy

        def shard_map_fn(f, in_specs, out_specs):
            return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_rep=False)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = sizes["model"]
    E, k = cfg.num_experts, cfg.top_k
    B, S, d = x.shape
    dp_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = int(np.prod([sizes[a] for a in dp_ax])) if dp_ax else 1
    if E % ep != 0 or B % max(dp, 1) != 0:
        return None
    E_loc = E // ep
    T_loc = (B // dp) * S
    capacity = max(int(T_loc * k / E * cfg.capacity_factor), k)
    if S == 1:
        capacity = T_loc * k
    capacity = min(capacity, T_loc * k)
    all_axes = dp_ax + ("model",)

    def shard_fn(xb, router_w, wg, wu, wd):
        r = jax.lax.axis_index("model")
        Tl = xb.shape[0] * xb.shape[1]
        xf = xb.reshape(Tl, d)
        logits = L.linear(xf.astype(jnp.float32), router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros(E).at[idx.reshape(-1)].add(jnp.ones(Tl * k) / (Tl * k))
        aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, all_axes)

        # local dispatch; keep only this model rank's expert payloads
        e_flat = idx.reshape(-1)
        g_flat = gates.reshape(-1)
        tok_flat = jnp.arange(Tl * k, dtype=jnp.int32) // k
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        tok_sorted = tok_flat[order]
        g_sorted = g_flat[order]
        start = jnp.searchsorted(e_sorted, jnp.arange(E, dtype=e_sorted.dtype))
        rank_in_e = (jnp.arange(Tl * k, dtype=jnp.int32)
                     - start[e_sorted].astype(jnp.int32))
        e_local = e_sorted.astype(jnp.int32) - r * E_loc
        mine = (e_local >= 0) & (e_local < E_loc) & (rank_in_e < capacity)
        slot = jnp.clip(rank_in_e, 0, capacity - 1)
        e_idx = jnp.clip(e_local, 0, E_loc - 1)

        buf = jnp.zeros((E_loc, capacity, d), xf.dtype)
        buf = buf.at[e_idx, slot].add(
            jnp.where(mine[:, None], xf[tok_sorted], 0))

        act = L.act_fn(cfg.activation)

        def ffn(b, g_, u_, d_):
            h = act(L.linear(b, g_)) * L.linear(b, u_)
            return L.linear(h.astype(b.dtype), d_)

        h = jax.vmap(ffn)(buf, wg, wu, wd)                  # [E_loc, cap, d]
        y_slot = (h[e_idx, slot].astype(jnp.float32)
                  * jnp.where(mine, g_sorted, 0.0)[:, None])
        y = jnp.zeros((Tl, d), jnp.float32).at[tok_sorted].add(y_slot)
        # bf16 partial-output combine — same wire pattern as row-parallel TP
        y = jax.lax.psum(y.astype(xb.dtype), "model")
        return y.reshape(xb.shape), aux

    in_specs = (P(dp_ax if dp_ax else None, None, None), P(None, None),
                P("model", None, None), P("model", None, None),
                P("model", None, None))
    out_specs = (P(dp_ax if dp_ax else None, None, None), P())
    f = shard_map_fn(shard_fn, in_specs, out_specs)
    y, aux = f(x, params["router"], params["w_gate"], params["w_up"],
               params["w_down"])
    if cfg.num_shared_experts:
        y = y + L.mlp_apply(params["shared"], x, cfg.activation)
    return y.astype(x.dtype), aux
