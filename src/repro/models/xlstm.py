"""xLSTM (sLSTM + mLSTM blocks) — arXiv:2405.04517.

Blocks alternate: one sLSTM per ``slstm_every`` layers, the rest mLSTM.
``d_ff == 0`` per the assigned config: feed-forward capacity lives inside the
blocks (mLSTM pre-up-projection factor 2, sLSTM post-FFN factor 4/3), as in
the paper.

Both recurrences use log-space stabilized exponential gating (the paper's
m-state trick).  Training/prefill run the recurrence with ``lax.scan`` over
time; decode is the same cell applied once.  States are O(1) in sequence
length, so xlstm runs the ``long_500k`` shape natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# mLSTM block (matrix memory)
# ---------------------------------------------------------------------------

def mlstm_init(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    di = 2 * d                                   # up-projection factor 2
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones(d),
        "w_up": L.dense_init(ks[0], d, di, dtype),
        "w_gate_up": L.dense_init(ks[1], d, di, dtype),
        "conv_w": (jax.random.normal(ks[2], (4, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros(di, dtype),
        "wq": L.dense_init(ks[3], di, di, dtype),
        "wk": L.dense_init(ks[4], di, di, dtype),
        "wv": L.dense_init(ks[5], di, di, dtype),
        "w_if": L.dense_init(ks[6], di, 2 * cfg.num_heads, dtype),
        "out_norm": jnp.ones(di),
        "w_down": L.dense_init(ks[7], di, d, dtype, scale=0.5),
    }


def _mlstm_cell(state, qkvif):
    """One time step.  state: (C [B,H,Dh,Dh], n [B,H,Dh], m [B,H])."""
    C, n, m = state
    q, k, v, i_raw, f_raw = qkvif                 # q,k,v: [B,H,Dh]
    Dh = q.shape[-1]
    f_log = jax.nn.log_sigmoid(f_raw)             # [B,H]
    m_new = jnp.maximum(f_log + m, i_raw)
    f_act = jnp.exp(f_log + m - m_new)
    i_act = jnp.exp(i_raw - m_new)
    k_s = k / jnp.sqrt(Dh)
    C = f_act[..., None, None] * C + i_act[..., None, None] * (
        v[..., :, None] * k_s[..., None, :])      # [B,H,Dh,Dh]
    n = f_act[..., None] * n + i_act[..., None] * k_s
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                        jnp.exp(-m_new)) + 1e-6
    h = jnp.einsum("bhij,bhj->bhi", C, q) / denom[..., None]
    return (C, n, m_new), h


def mlstm_apply(cfg: ModelConfig, params, x, *, cache=None):
    B, S, d = x.shape
    H = cfg.num_heads
    di = 2 * d
    Dh = di // H
    resid = x
    x = L.rmsnorm(x, params["norm"])
    up = L.linear(x, params["w_up"])
    gate = jax.nn.silu(L.linear(x, params["w_gate_up"]))

    # causal conv feature path for q, k
    W = params["conv_w"].shape[0]
    if cache is None or S > 1:
        padded = jnp.pad(up, ((0, 0), (W - 1, 0), (0, 0)))
        conv = sum(padded[:, i:i + S, :] * params["conv_w"][i] for i in range(W))
        conv = jax.nn.silu(conv + params["conv_b"])
        conv_tail = jnp.pad(up, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):, :]
    else:
        full = jnp.concatenate([cache["conv"].astype(up.dtype), up], axis=1)
        conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", full, params["conv_w"])
                           + params["conv_b"])[:, None, :]
        conv_tail = full[:, 1:, :]

    q = L.linear(conv, params["wq"]).reshape(B, S, H, Dh).astype(jnp.float32)
    k = L.linear(conv, params["wk"]).reshape(B, S, H, Dh).astype(jnp.float32)
    v = L.linear(up, params["wv"]).reshape(B, S, H, Dh).astype(jnp.float32)
    gif = L.linear(up, params["w_if"]).reshape(B, S, H, 2).astype(jnp.float32)
    i_raw, f_raw = gif[..., 0], gif[..., 1]

    if cache is None:
        C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
        n0 = jnp.zeros((B, H, Dh), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        C0 = cache["C"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)

    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
          jnp.moveaxis(i_raw, 1, 0), jnp.moveaxis(f_raw, 1, 0))
    (Cn, nn, mn), hs = jax.lax.scan(_mlstm_cell, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di).astype(resid.dtype)

    h = L.rmsnorm(h, params["out_norm"]) * gate
    out = L.linear(h, params["w_down"])
    new_cache = None
    if cache is not None:
        new_cache = {"C": Cn.astype(cache["C"].dtype),
                     "n": nn.astype(cache["n"].dtype),
                     "m": mn.astype(cache["m"].dtype),
                     "conv": conv_tail.astype(cache["conv"].dtype)}
    return resid + out, new_cache


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, recurrent connections)
# ---------------------------------------------------------------------------

def slstm_init(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    Dh = d // H
    ks = jax.random.split(key, 4)
    d_ff = int(d * 4 / 3)

    def rmat(k):                                  # block-diagonal recurrent
        return (jax.random.normal(k, (H, Dh, Dh)) / jnp.sqrt(Dh)).astype(dtype)

    rks = jax.random.split(ks[1], 4)
    return {
        "norm": jnp.ones(d),
        "w_in": L.dense_init(ks[0], d, 4 * d, dtype),    # z, i, f, o pre-acts
        "r_z": rmat(rks[0]), "r_i": rmat(rks[1]),
        "r_f": rmat(rks[2]), "r_o": rmat(rks[3]),
        "out_norm": jnp.ones(d),
        "ffn": L.mlp_init(ks[2], d, d_ff, dtype),
    }


def _slstm_cell(params):
    def cell(state, w_in_t):
        c, n, h, m = state                        # [B,H,Dh] each, m [B,H,Dh]
        wz, wi, wf, wo = jnp.split(w_in_t, 4, axis=-1)     # [B, d] each
        B = wz.shape[0]
        H, Dh, _ = params["r_z"].shape
        hh = h.reshape(B, H, Dh)

        def rec(r, pre):
            return pre.reshape(B, H, Dh) + jnp.einsum("bhj,hij->bhi", hh, r)

        z = jnp.tanh(rec(params["r_z"], wz))
        i_raw = rec(params["r_i"], wi)
        f_raw = rec(params["r_f"], wf)
        o = jax.nn.sigmoid(rec(params["r_o"], wo))
        f_log = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(f_log + m, i_raw)
        i_act = jnp.exp(i_raw - m_new)
        f_act = jnp.exp(f_log + m - m_new)
        c = f_act * c + i_act * z
        n = f_act * n + i_act
        h_new = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h_new, m_new), h_new
    return cell


def slstm_apply(cfg: ModelConfig, params, x, *, cache=None):
    B, S, d = x.shape
    H = cfg.num_heads
    Dh = d // H
    resid = x
    xn = L.rmsnorm(x, params["norm"])
    w_in = L.linear(xn, params["w_in"]).astype(jnp.float32)   # [B,S,4d]

    if cache is None:
        zeros = jnp.zeros((B, H, Dh), jnp.float32)
        state = (zeros, zeros, zeros, zeros)
    else:
        state = tuple(cache[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))

    (c, n, h, m), hs = jax.lax.scan(_slstm_cell(params), state,
                                    jnp.moveaxis(w_in, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(resid.dtype)
    y = L.rmsnorm(y, params["out_norm"])
    y = y + L.mlp_apply(params["ffn"], y, "gelu")
    new_cache = None
    if cache is not None:
        new_cache = {k: v.astype(cache[k].dtype)
                     for k, v in zip(("c", "n", "h", "m"), (c, n, h, m))}
    return resid + y, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

class XLSTM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = {"float32": jnp.float32,
                      "bfloat16": jnp.bfloat16}[cfg.param_dtype]
        every = cfg.slstm_every or (cfg.num_layers + 1)
        self.is_slstm = tuple((i % every) == every - 1
                              for i in range(cfg.num_layers))

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, cfg.num_layers + 2)
        layers = []
        for i in range(cfg.num_layers):
            init_fn = slstm_init if self.is_slstm[i] else mlstm_init
            layers.append(init_fn(cfg, ks[i], self.dtype))
        return {
            "embed": L.embed_init(ks[-2], cfg.vocab_size, cfg.d_model, self.dtype),
            "layers": layers,
            "final_norm": jnp.ones(cfg.d_model),
        }

    def init_cache(self, batch: int, max_seq: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.dtype
        H = cfg.num_heads
        di = 2 * cfg.d_model
        Dh_m = di // H
        Dh_s = cfg.d_model // H
        caches = []
        for i in range(cfg.num_layers):
            if self.is_slstm[i]:
                caches.append({k: jnp.zeros((batch, H, Dh_s), dtype)
                               for k in ("c", "n", "h", "m")})
            else:
                caches.append({
                    "C": jnp.zeros((batch, H, Dh_m, Dh_m), dtype),
                    "n": jnp.zeros((batch, H, Dh_m), dtype),
                    "m": jnp.zeros((batch, H), dtype),
                    "conv": jnp.zeros((batch, 3, di), dtype),
                })
        return {"pos": jnp.zeros((), jnp.int32), "layers": caches}

    def _trunk(self, params, x, cache=None):
        new_layers = []
        for i, lp in enumerate(params["layers"]):
            apply_fn = slstm_apply if self.is_slstm[i] else mlstm_apply
            c = cache["layers"][i] if cache is not None else None
            if self.cfg.remat and cache is None:
                fn = jax.checkpoint(
                    lambda p, h, _fn=apply_fn: _fn(self.cfg, p, h)[0])
                x, nc = fn(lp, x), None
            else:
                x, nc = apply_fn(self.cfg, lp, x, cache=c)
            new_layers.append(nc)
        new_cache = None
        if cache is not None:
            new_cache = {"pos": cache["pos"], "layers": new_layers}
        return x, new_cache

    def _logits(self, params, x):
        return jnp.einsum("bsd,vd->bsv", L.rmsnorm(x, params["final_norm"]),
                          params["embed"], preferred_element_type=jnp.float32)

    def forward_train(self, params, batch):
        x = params["embed"][batch["tokens"]]
        x, _ = self._trunk(params, x)
        return self._logits(params, x), 0.0

    def prefill(self, params, batch, cache):
        x = params["embed"][batch["tokens"]]
        S = x.shape[1]
        x, cache = self._trunk(params, x, cache=cache)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return self._logits(params, x[:, -1:]), cache

    def decode_step(self, params, token, cache):
        x = params["embed"][token]
        x, cache = self._trunk(params, x, cache=cache)
        cache["pos"] = cache["pos"] + 1
        return self._logits(params, x), cache

    def loss_fn(self, params, batch):
        logits, _ = self.forward_train(params, batch)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
        loss = -jnp.mean(ll)
        return loss, {"ce": loss, "aux": 0.0}
