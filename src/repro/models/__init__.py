from repro.models.config import ModelConfig, reduce_for_smoke
from repro.models.model import build_model

__all__ = ["ModelConfig", "build_model", "reduce_for_smoke"]
