"""Batched serving driver: SLO-class request routing + KV-cache decode.

The serving-side counterpart of launch/train.py and the reason the paper's
SLO table exists: requests arrive tagged with an SLO class; SPTLB has
already placed each model replica on a tier that supports that class
(constraint 4), so admission is a table lookup; the engine then runs
continuous batched greedy decoding against a shared KV cache.

Components:
  * RequestQueue  — per-SLO-class FIFO with deadline bookkeeping,
  * ServeEngine   — slot-based continuous batcher (prefill on admit,
                    batched decode_step, eviction on EOS/length),
  * latency report per SLO class (the p99s the paper's tiers are sized for).

Run (reduced config, CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --requests 24 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, reduce_for_smoke
from repro.train.serve_step import make_decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # i32[prompt_len]
    slo: int                      # latency class (paper SLO1..4)
    max_new_tokens: int
    arrival_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    tokens: list = dataclasses.field(default_factory=list)


class RequestQueue:
    """Per-SLO FIFO; lower class id = tighter latency target."""

    def __init__(self, num_classes: int = 4):
        self.queues = [deque() for _ in range(num_classes)]

    def push(self, req: Request):
        self.queues[req.slo].append(req)

    def pop(self) -> Optional[Request]:
        for q in self.queues:               # strict priority by SLO class
            if q:
                return q.popleft()
        return None

    def __len__(self):
        return sum(map(len, self.queues))


class ServeEngine:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, model, params, *, slots: int, max_seq: int,
                 eos_token: int = 0):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos = eos_token
        self.cache = model.init_cache(slots, max_seq)
        self.decode = jax.jit(make_decode_step(model))
        # NOTE: a shared cache with per-slot positions requires per-slot
        # pos tracking; this engine admits waves of equal-length prompts
        # (left-padded otherwise) — the standard static-batch TPU pattern.
        self.active: list[Optional[Request]] = [None] * slots
        self.tokens = jnp.zeros((slots, 1), jnp.int32)

    def admit_wave(self, reqs: list[Request]):
        """Prefill a wave of requests (padded to a common length)."""
        assert len(reqs) <= self.slots
        maxlen = max(len(r.prompt) for r in reqs)
        batch = np.zeros((self.slots, maxlen), np.int32)
        for i, r in enumerate(reqs):
            batch[i, maxlen - len(r.prompt):] = r.prompt   # left-pad
            self.active[i] = r
        self.cache = self.model.init_cache(self.slots, self.max_seq)
        prefill = jax.jit(self.model.prefill)
        logits, self.cache = prefill(self.params,
                                     {"tokens": jnp.asarray(batch)},
                                     self.cache)
        self.tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            r.first_token_s = now
            r.tokens.append(int(self.tokens[i, 0]))

    def step(self) -> int:
        """One batched decode step; returns #still-active requests."""
        self.tokens, self.cache = self.decode(self.params, self.tokens,
                                              self.cache)
        now = time.perf_counter()
        alive = 0
        for i, r in enumerate(self.active):
            if r is None or r.done_s is not None:
                continue
            tok = int(self.tokens[i, 0])
            r.tokens.append(tok)
            if len(r.tokens) >= r.max_new_tokens:
                r.done_s = now
            else:
                alive += 1
        return alive


def latency_report(requests: list[Request]) -> dict:
    by_slo: dict = {}
    for r in requests:
        if r.done_s is None:
            continue
        d = by_slo.setdefault(r.slo, {"ttft_ms": [], "total_ms": []})
        d["ttft_ms"].append((r.first_token_s - r.arrival_s) * 1e3)
        d["total_ms"].append((r.done_s - r.arrival_s) * 1e3)
    out = {}
    for slo, d in sorted(by_slo.items()):
        out[slo] = {
            "n": len(d["ttft_ms"]),
            "ttft_p50_ms": float(np.percentile(d["ttft_ms"], 50)),
            "ttft_p99_ms": float(np.percentile(d["ttft_ms"], 99)),
            "total_p99_ms": float(np.percentile(d["total_ms"], 99)),
        }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduce_for_smoke(get_config(args.arch))
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only")
    model = build_model(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))

    queue = RequestQueue()
    t0 = time.perf_counter()
    for i in range(args.requests):
        queue.push(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                rng.integers(4, args.prompt_len + 1)
                                ).astype(np.int32),
            slo=int(rng.choice(4, p=[0.2, 0.2, 0.45, 0.15])),
            max_new_tokens=args.max_new,
            arrival_s=t0,
        ))

    engine = ServeEngine(model, params, slots=args.slots,
                         max_seq=args.prompt_len + args.max_new + 8)
    finished: list[Request] = []
    while len(queue) or any(r and r.done_s is None for r in engine.active):
        wave = []
        while len(wave) < args.slots and len(queue):
            wave.append(queue.pop())
        if wave:
            engine.admit_wave(wave)
        while engine.step():
            pass
        finished.extend(r for r in engine.active if r is not None)
        engine.active = [None] * engine.slots

    report = latency_report(finished)
    print(f"served {len(finished)} requests on arch={cfg.arch_id} (reduced)")
    for slo, stats in report.items():
        print(f"  SLO{slo + 1}: n={stats['n']:3d} "
              f"ttft p50 {stats['ttft_p50_ms']:8.1f} ms  "
              f"p99 {stats['ttft_p99_ms']:8.1f} ms  "
              f"total p99 {stats['total_p99_ms']:8.1f} ms")
    return report


if __name__ == "__main__":
    main()
