"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh, derive the three terms from
the compiled artifact (all quantities PER DEVICE, so dividing by per-chip
peaks matches the spec's total/(chips x peak) formula for balanced SPMD):

    compute term    = HLO_FLOPs_corrected / 197 TFLOP/s (bf16)
    memory term     = HLO_bytes_corrected / 819 GB/s
    collective term = collective_wire_bytes / 50 GB/s   (1 ICI link,
                      conservative; v5e has multiple links per chip)

plus MODEL_FLOPS (6 N_eff D for training, 2 N_eff D for prefill/decode),
the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, the dominant bottleneck,
and an MFU bound = (MODEL_FLOPS / peak) / max(term) — the fraction of the
chip's peak the step could reach if it ran exactly at the dominant-resource
bound.  This MFU bound is the §Perf score that the hillclimb drives up.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.models import build_model

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link
CHIPS = 256                # single-pod (16 data x 16 model) mesh
# MODEL_FLOPS is the *useful* work and divides over ALL chips (data x model
# parallelism); a cell whose HLO per-device FLOPs is ~16x the per-chip
# useful share has its tensor parallelism silently broken (XLA replicated
# the compute) — exactly what the useful-ratio column is for.


def count_params(cfg) -> dict:
    """Exact parameter counts from the abstract tree (no allocation)."""
    model = build_model(cfg)
    abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(abs_params)[0]
    total = 0
    routed_expert = 0
    embed = 0
    shared_block = 0
    for path, leaf in flat:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n = int(np.prod(leaf.shape))
        total += n
        if "moe" in keys and "shared" not in keys and any(
                k in keys for k in ("w_gate", "w_up", "w_down")):
            routed_expert += n
        if "embed" in keys and "mask_embed" not in keys or "lm_head" in keys:
            embed += n
        if cfg.family == "hybrid" and "shared" in keys and "out_proj" not in keys:
            shared_block += n
    return {"total": total, "routed_expert": routed_expert, "embed": embed,
            "shared_block": shared_block}


def model_flops_per_token(cfg) -> float:
    """Active matmul params x 2 (the 6ND/2ND convention's N)."""
    counts = count_params(cfg)
    n = counts["total"] - counts["embed"]          # embeddings are gathers
    if cfg.num_experts:
        n -= counts["routed_expert"] * (1 - cfg.top_k / cfg.num_experts)
    if cfg.family == "hybrid" and cfg.attn_every:
        apps = cfg.num_layers // cfg.attn_every
        n += counts["shared_block"] * (apps - 1)   # shared block reused
    # lm head matmul is real compute (tied or not)
    n += cfg.d_model * cfg.vocab_size
    return 2.0 * n


def model_flops(cfg, shape_name: str) -> float:
    """Per-device useful FLOPs for this cell (6ND train, 2ND serve)."""
    s = SHAPES[shape_name]
    per_tok = model_flops_per_token(cfg)
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len / CHIPS
        return 3.0 * per_tok * tokens              # fwd + bwd = 3 x fwd
    if s.kind == "prefill":
        tokens = s.global_batch * s.seq_len / CHIPS
        return per_tok * tokens
    tokens = s.global_batch / CHIPS        # decode: 1 token/seq
    return per_tok * tokens


def analyze(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    cost = rec.get("cost_corrected") or rec["cost"]
    flops = cost["flops"]
    bytes_ = cost["bytes_accessed"]
    coll = cost.get("collective_bytes",
                    rec["collectives"]["total_bytes"])
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, rec["shape"])
    mfu_bound = (mf / PEAK_FLOPS) / max(max(terms.values()), 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "tag": rec.get("tag", ""),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / max(flops, 1e-30),
        "mfu_bound": mfu_bound,
        "hbm_gib_per_dev": (rec["memory"]["argument_bytes"]
                            + rec["memory"]["output_bytes"]
                            + rec["memory"]["temp_bytes"]) / 2**30
        if rec.get("memory") else float("nan"),
    }


def action_note(row: dict) -> str:
    d = row["dominant"]
    if d == "compute" and row["useful_ratio"] < 0.5:
        return ("compute-bound with low useful ratio — cut remat/recompute "
                "or attention waste to move HLO FLOPs toward model FLOPs")
    if d == "compute":
        return "compute-bound near useful peak — healthy; only kernel-level wins left"
    if d == "memory":
        return ("memory-bound — shrink bytes/step: fuse elementwise chains, "
                "bf16 intermediates, smaller KV cache (windowed layers), or "
                "re-shard to cut per-device working set")
    return ("collective-bound — re-shard to reduce wire bytes (2D sharding, "
            "overlap collectives with compute, hierarchical all-reduce)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="", help="analyze a perf-variant tag")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()

    rows = []
    for path in sorted(Path(args.dir).glob("*--single*.json")):
        rec = json.loads(path.read_text())
        if rec.get("skipped") or not rec.get("ok"):
            continue
        if rec.get("tag", "") != args.tag:
            continue
        rows.append(analyze(rec))

    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant "
        "| useful FLOP ratio | MFU bound | HBM GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:9.2f} "
            f"| {r['t_memory_s']*1e3:9.2f} | {r['t_collective_s']*1e3:9.2f} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['mfu_bound']:.3f} | {r['hbm_gib_per_dev']:.1f} |")
    table = "\n".join(lines)
    print(table)

    print("\n### per-cell action notes")
    for r in rows:
        print(f"- **{r['arch']} / {r['shape']}** ({r['dominant']}-bound, "
              f"MFU bound {r['mfu_bound']:.2f}): {action_note(r)}")

    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(table + "\n")
    # machine-readable dump for EXPERIMENTS.md generation
    Path(args.out).with_suffix(".json").write_text(
        json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
