"""End-to-end training driver: SPTLB-routed streams -> pjit train loop with
checkpoint/restart and failure-driven rebalancing.

This is the integration point of the whole framework (DESIGN.md §2):

  1. stream apps + pod slices are assembled into the paper's tier model,
  2. SPTLB (manual_cnst co-operation) produces the app->tier routing,
  3. the local mesh trains its slice's stream partitions,
  4. failures (simulated here; device-health callbacks in production) shrink
     tier capacity, SPTLB re-balances with bounded movement, and training
     resumes from the latest checkpoint.

Runs on CPU with ``--smoke`` (reduced config); production shapes lower via
launch/dryrun.py on the 256/512-chip meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 30 --global-batch 8 --seq-len 128 --inject-failure-at 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import CapacityEvent, rebalance
from repro.launch.mesh import make_host_mesh
from repro.models import build_model, reduce_for_smoke
from repro.streams import (PodSlice, StreamConfig, StreamRouter, TokenStream,
                           build_cluster, demo_apps)
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def default_slices() -> list[PodSlice]:
    """A 5-tier cluster matching the paper's experiment setup."""
    return [
        PodSlice("tier_1", pod=0, num_hosts=64, flops_capacity=900.0,
                 hbm_capacity=2048.0, task_slots=1500, regions=(0, 1)),
        PodSlice("tier_2", pod=0, num_hosts=48, flops_capacity=700.0,
                 hbm_capacity=1536.0, task_slots=1200, regions=(1, 2)),
        PodSlice("tier_3", pod=0, num_hosts=32, flops_capacity=400.0,
                 hbm_capacity=1024.0, task_slots=800, regions=(2, 3)),
        PodSlice("tier_4", pod=1, num_hosts=48, flops_capacity=700.0,
                 hbm_capacity=1536.0, task_slots=1200, regions=(3, 4)),
        PodSlice("tier_5", pod=1, num_hosts=64, flops_capacity=900.0,
                 hbm_capacity=2048.0, task_slots=1500, regions=(4, 5)),
    ]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/run0")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a host failure at this step")
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "int8"],
                    help="compress gradients (DCN stage) w/ error feedback")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # ---- 1+2: SPTLB routing over the stream cluster -----------------------
    apps = demo_apps(48, seed=args.seed)
    cluster = build_cluster(apps, default_slices(), seed=args.seed)
    router = StreamRouter(cluster)
    decision = router.route(engine="local", variant="manual_cnst")
    print(f"[sptlb] routed {len(apps)} stream apps: moved "
          f"{decision.projected.num_moved}, d2b "
          f"{decision.difference_to_balance:.3f}, net p99 "
          f"{decision.network_p99_ms:.0f} ms, constraints ok: "
          f"{decision.violations.ok}")

    # ---- 3: local slice trains its partitions -----------------------------
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)
    mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)

    stream = TokenStream(StreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed))

    from repro.distributed.compress import GradCompressor
    compressor = (GradCompressor(mode=args.grad_compress)
                  if args.grad_compress != "none" else None)
    step_fn = make_train_step(model, AdamWConfig(lr=args.lr,
                                                 total_steps=args.steps),
                              compressor=compressor)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    with mesh:
        state = init_train_state(model, jax.random.PRNGKey(args.seed),
                                 compressor=compressor)
        start_step = 0
        if args.resume and ckpt.latest_step() is not None:
            state, start_step = ckpt.restore(state)
            print(f"[ckpt] resumed from step {start_step}")

        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        t_last = time.perf_counter()
        for step in range(start_step, args.steps):
            if step == args.inject_failure_at:
                print(f"[fault] host failure injected at step {step}")
                event = CapacityEvent("host_failure", tier=2, fraction=0.2,
                                      step=step)
                new_cluster, dec = rebalance(cluster, event)
                router.cluster = new_cluster
                router.assignment = np.asarray(dec.assignment)
                print(f"[sptlb] rebalanced: moved {dec.projected.num_moved} "
                      f"apps, d2b {dec.difference_to_balance:.3f}, "
                      f"constraints ok: {dec.violations.ok}")
                # restart path: restore latest checkpoint (idempotent replay)
                if ckpt.latest_step() is not None:
                    state, restored = ckpt.restore(state)
                    print(f"[ckpt] restarted from step {restored}")
                    step = restored

            batch = stream.batch(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = jit_step(state, batch)
            if step % 5 == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t_last
                t_last = time.perf_counter()
                print(f"[train] step {step:5d} loss {loss:8.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):8.3f} ({dt:.1f}s)")
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state, blocking=False)
        ckpt.wait()
        final_loss = float(metrics["loss"])
        print(f"[done] {args.steps} steps, final loss {final_loss:.4f}")
        return final_loss


if __name__ == "__main__":
    main()
