import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede any other import (jax locks the device
# count at first init).  This module is the multi-pod dry-run driver: it AOT
# lowers + compiles every (architecture x input-shape x mesh) cell with
# ShapeDtypeStruct inputs (no allocation), records memory/cost analyses and
# the collective schedule, and caches per-cell JSON for the roofline report.

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import SHAPES, cells, input_specs, shape_applicable
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.serve_step import abstract_cache, make_decode_step, make_prefill
from repro.train.train_step import abstract_train_state, make_train_step

# ---------------------------------------------------------------------------
# collective-schedule parsing (HLO text -> per-device bytes on the wire)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device wire bytes per collective kind (ring-algorithm estimates).

    Sizes in partitioned HLO are already per-device shards.  Ring costs:
      all-reduce     2 (g-1)/g * result
      all-gather       (g-1)/g * result      (result = gathered size)
      reduce-scatter   (g-1)   * result      (result = scattered shard)
      all-to-all       (g-1)/g * result
      collective-permute         result
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.groups()
        size = _shape_bytes(shape_str)
        g_m = _GROUPS_RE.search(line)
        g = len(g_m.group(1).split(",")) if g_m else 2
        g = max(g, 2)
        if op == "all-reduce":
            size = 2 * (g - 1) / g * size
        elif op == "all-gather":
            size = (g - 1) / g * size
        elif op == "reduce-scatter":
            size = (g - 1) * size
        elif op == "all-to-all":
            size = (g - 1) / g * size
        out[op] += size
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 1,
               cfg_override=None, overrides: dict | None = None,
               kv_shard: str = "heads", zero1: bool = False):
    """-> (jitted_fn, abstract_args) ready to .lower(*args)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    s = SHAPES[shape_name]
    key = jax.random.PRNGKey(0)
    batch_abs = input_specs(cfg, shape_name)

    if s.kind == "train":
        state_abs = abstract_train_state(model, key)
        # Calibration compiles (unroll_layers=True) unroll the microbatch
        # loop as well, so HloCostAnalysis counts every microbatch.
        step = make_train_step(model, AdamWConfig(), microbatches=microbatches,
                               unroll=cfg.unroll_layers)
        state_sh = jax.tree.map(
            lambda _: None, state_abs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        state_sh = type(state_abs)(
            params=SH.params_shardings(mesh, state_abs.params),
            opt=type(state_abs.opt)(
                count=SH.replicated(mesh),
                m=SH.opt_state_shardings(mesh, state_abs.opt.m,
                                         zero1=zero1),
                v=SH.opt_state_shardings(mesh, state_abs.opt.v,
                                         zero1=zero1)),
            step=SH.replicated(mesh))
        batch_sh = SH.batch_shardings(mesh, batch_abs)
        metrics_sh = jax.tree.map(lambda _: SH.replicated(mesh),
                                  {"loss": 0, "ce": 0, "aux": 0,
                                   "grad_norm": 0, "lr": 0})
        fn = jax.jit(step,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh),
                     donate_argnums=(0,))
        return fn, (state_abs, batch_abs)

    # serving cells
    params_abs = jax.eval_shape(model.init, key)
    params_sh = SH.params_shardings(mesh, params_abs)
    B = s.global_batch

    if s.kind == "prefill":
        cache_abs = abstract_cache(model, B, s.seq_len)
        cache_sh = (SH.cache_shardings(mesh, cache_abs, kv_shard=kv_shard)
                    if cache_abs is not None else None)
        prefill = make_prefill(model)
        tok_sh = SH.batch_shardings(
            mesh, jax.ShapeDtypeStruct((B, 1), jnp.int32))
        batch_sh = SH.batch_shardings(mesh, batch_abs)
        if cfg.family == "audio":
            # encoder-only: prefill = full encode, returns logits
            def enc(params, batch):
                logits, _ = model.prefill(params, batch, None)
                return logits
            out_shape = (B, s.seq_len, cfg.vocab_size)
            fn = jax.jit(enc, in_shardings=(params_sh, batch_sh),
                         out_shardings=SH.logits_sharding(mesh, out_shape))
            return fn, (params_abs, batch_abs)
        fn = jax.jit(prefill,
                     in_shardings=(params_sh, batch_sh, cache_sh),
                     out_shardings=(tok_sh, cache_sh),
                     donate_argnums=(2,))
        return fn, (params_abs, batch_abs, cache_abs)

    assert s.kind == "decode"
    cache_abs = abstract_cache(model, B, s.seq_len)
    cache_sh = SH.cache_shardings(mesh, cache_abs, kv_shard=kv_shard)
    serve = make_decode_step(model)
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = SH.batch_shardings(mesh, tok_abs)
    fn = jax.jit(serve,
                 in_shardings=(params_sh, tok_sh, cache_sh),
                 out_shardings=(tok_sh, cache_sh),
                 donate_argnums=(2,))
    return fn, (params_abs, tok_abs, cache_abs)


# ---------------------------------------------------------------------------
# scan-trip-count calibration
#
# XLA's HloCostAnalysis counts a while-loop body ONCE (trip counts are not
# statically applied), so the scan-over-layers models under-report FLOPs /
# bytes / collective traffic by ~num_layers.  We recover exact totals with a
# two-point fit: compile the same cell with g=1 and g=2 layer groups
# *unrolled* (identical math, python loop), then
#     X(G) = X(1) + (X(2) - X(1)) * (G - 1).
# Exact for uniform groups (all our scans are).  xlstm has no layer scan
# (layers are a python loop) but scans over TIME; its recurrence cost is
# added analytically below.
# ---------------------------------------------------------------------------

def _calib_plan(cfg):
    """-> (n_layers_for_g, G_full) or None if no layer scan to calibrate."""
    if cfg.family == "ssm":
        return None
    if cfg.family == "hybrid":
        return (lambda g: g * cfg.attn_every), cfg.num_layers // cfg.attn_every
    group = 2 if cfg.local_global_pattern else 1
    prefix = cfg.first_dense_layers
    G_full = (cfg.num_layers - prefix) // group
    return (lambda g: prefix + g * group), G_full


def _xlstm_time_correction(cfg, shape):
    """Analytic per-step recurrence cost x (S-1) missed by the time scan."""
    s = SHAPES[shape]
    if s.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    B_local = s.global_batch            # cost_analysis is per-device; batch
    # is sharded over data axes — caller divides by dp size.
    S = s.seq_len
    H = cfg.num_heads
    d = cfg.d_model
    every = cfg.slstm_every or (cfg.num_layers + 1)
    n_slstm = sum(1 for i in range(cfg.num_layers) if (i % every) == every - 1)
    n_mlstm = cfg.num_layers - n_slstm
    Dh_m = (2 * d) // H
    Dh_s = d // H
    f_m = 5.0 * B_local * H * Dh_m ** 2 + 10.0 * B_local * H * Dh_m
    f_s = 8.0 * B_local * H * Dh_s ** 2 + 24.0 * B_local * H * Dh_s
    flops = (S - 1) * (n_mlstm * f_m + n_slstm * f_s)
    bytes_ = (S - 1) * 2 * 4 * (n_mlstm * B_local * H * (Dh_m ** 2 + 2 * Dh_m + 1)
                                + n_slstm * 4 * B_local * H * Dh_s)
    return {"flops": float(flops), "bytes": float(bytes_)}


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))}


def calibrate(arch: str, shape_name: str, mesh, *, microbatches: int = 1,
              overrides: dict | None = None, kv_shard: str = "heads"):
    """-> dict with corrected per-device cost + collectives (or None)."""
    cfg = get_config(arch)
    plan = _calib_plan(cfg)
    if plan is None:
        return None
    n_of_g, G_full = plan
    points = {}
    for g in (1, 2):
        cfg_g = dataclasses.replace(cfg, num_layers=n_of_g(g),
                                    unroll_layers=True, **(overrides or {}))
        fn, args = build_cell(arch, shape_name, mesh,
                              microbatches=microbatches, cfg_override=cfg_g,
                              kv_shard=kv_shard)
        compiled = fn.lower(*args).compile()
        points[g] = {"cost": _cost_of(compiled),
                     "collectives": collective_stats(compiled.as_text())}

    def fit(x1, x2):
        return x1 + (x2 - x1) * (G_full - 1)

    c1, c2 = points[1]["cost"], points[2]["cost"]
    col1 = points[1]["collectives"], points[2]["collectives"]
    col1, col2 = col1[0], col1[1]
    corrected = {
        "flops": fit(c1["flops"], c2["flops"]),
        "bytes_accessed": fit(c1["bytes_accessed"], c2["bytes_accessed"]),
        "collective_bytes": fit(col1["total_bytes"], col2["total_bytes"]),
        "collective_bytes_by_op": {
            k: fit(col1["bytes"][k], col2["bytes"][k])
            for k in col1["bytes"]},
        "G_full": G_full,
        "points": points,
    }
    return corrected


# ---------------------------------------------------------------------------
# run + record
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Path, force: bool = False,
             microbatches: int = 1, tag: str = "",
             overrides: dict | None = None, kv_shard: str = "heads",
             zero1: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    suffix = f"-{tag}" if tag else ""
    out_path = out_dir / f"{arch}--{shape_name}--{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": True, "reason": reason}
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "devices": int(mesh.devices.size), "skipped": False,
           "microbatches": microbatches, "tag": tag,
           "overrides": overrides or {}, "kv_shard": kv_shard}
    try:
        with mesh:
            fn, args = build_cell(arch, shape_name, mesh,
                                  microbatches=microbatches,
                                  overrides=overrides, kv_shard=kv_shard,
                                  zero1=zero1)
            lowered = fn.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            }
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            rec["cost"] = {
                "flops": float(cost.get("flops", -1)),
                "bytes_accessed": float(cost.get("bytes accessed", -1)),
                "transcendentals": float(cost.get("transcendentals", -1)),
            }
            hlo = compiled.as_text()
            rec["collectives"] = collective_stats(hlo)
            rec["timings"] = {"lower_s": t_lower - t0,
                              "compile_s": t_compile - t_lower}

            # Roofline-grade corrected costs (single-pod mesh only).
            if not multi_pod:
                corr = calibrate(arch, shape_name, mesh,
                                 microbatches=microbatches,
                                 overrides=overrides, kv_shard=kv_shard)
                if corr is None:                      # xlstm: layers unrolled
                    tc = _xlstm_time_correction(cfg, shape_name)
                    dp = mesh.devices.shape[0]        # batch shard factor
                    corr = {
                        "flops": rec["cost"]["flops"] + tc["flops"] / dp,
                        "bytes_accessed": (rec["cost"]["bytes_accessed"]
                                           + tc["bytes"] / dp),
                        "collective_bytes":
                            rec["collectives"]["total_bytes"],
                        "collective_bytes_by_op":
                            rec["collectives"]["bytes"],
                        "G_full": 1,
                        "note": "layers unrolled natively; analytic time-scan"
                                " correction added",
                    }
                rec["cost_corrected"] = {
                    k: corr[k] for k in
                    ("flops", "bytes_accessed", "collective_bytes",
                     "collective_bytes_by_op", "G_full")}
                rec["calib_note"] = corr.get("note", "2-point unrolled fit")
            rec["ok"] = True
    except Exception as e:  # record failures — they are dry-run bugs
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--kv-shard", default="heads", choices=["heads", "seq", "auto"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.lstrip("-").isdigit() else v)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    grid = cells(args.arch)
    if args.shape:
        grid = [(a, s) for a, s in grid if s == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch, shape_name in grid:
        for multi_pod in meshes:
            t0 = time.time()
            rec = run_cell(arch, shape_name, multi_pod=multi_pod,
                           out_dir=out_dir, force=args.force,
                           microbatches=args.microbatches, tag=args.tag,
                           overrides=overrides, kv_shard=args.kv_shard,
                           zero1=args.zero1)
            status = ("SKIP " + rec.get("reason", "") if rec.get("skipped")
                      else "OK" if rec.get("ok") else
                      "FAIL " + rec.get("error", "")[:120])
            mesh_name = "multi" if multi_pod else "single"
            print(f"[{time.strftime('%H:%M:%S')}] {arch:22s} {shape_name:12s} "
                  f"{mesh_name:6s} {time.time()-t0:7.1f}s  {status}", flush=True)


if __name__ == "__main__":
    main()
