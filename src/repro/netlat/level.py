"""The latency-SLO scheduler level: measured per-pair budgets on the bus.

``LatencySLOScheduler`` replaces the static-constant region level in the
measured stack (levels ``("netlat", "host")``).  Where ``RegionScheduler``
vets every placement against the one hard-coded
``REGION_LATENCY_BUDGET_MS`` constant, this level reads the live per-pair
p99 estimates from a ``LinkSketchBank`` (``repro.netlat.sketches``) and:

* **budgets per pair** — at calibration the bank freezes its baseline p99
  matrix; the budget for pair (g, h) becomes
  ``clip(headroom x baseline_p99[g, h], min_ms, cap_ms)``.  Measurement
  only ever *tightens* the static contract: ``cap_ms`` is the old global
  constant (a far pair never earns a looser budget than the SLO), while a
  close pair's budget shrinks to just above its own healthy tail — so a
  degraded link masks exactly the tiers it reaches, including pairs whose
  mean still sneaks under the global constant while their measured p99
  breaches it.  A placement into a tier is feasible iff *every* pair from
  the app's source region to the tier's regions currently measures within
  its own budget.

* **measured relax** — the maintenance relax factor is no longer the fixed
  1.5x: it is the fleet-median measured p999/p99 ratio (how much worse the
  extreme tail actually is than the SLO percentile), clipped to
  ``[1, max_relax]``.

* **graceful inertness** — with no bank installed, or before the bank is
  calibrated, the level behaves exactly like the static region level
  (scalar ``floor_ms`` budget against the cluster's declared latency
  matrix), so early ticks keep latency protection and the parity suite can
  pin stack-equivalence.

The level is stateless across cooperation passes (the bus re-binds levels
from the registry each pass); all persistent measurement state lives in
the bank, installed process-wide via ``repro.netlat.install_bank``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.levels import (
    Proposal,
    REGION_LATENCY_BUDGET_MS,
    RELAX_LATENCY_FACTOR,
    SchedulerLevel,
)
from repro.netlat.sketches import LinkSketchBank


@dataclasses.dataclass(frozen=True)
class NetlatConfig:
    """Budget-derivation knobs for the latency-SLO level.

    ``headroom`` is the slack multiplier over the calibrated baseline p99
    (budgets must tolerate normal jitter without vetoing); ``cap_ms`` is
    the static contract the measured budgets tighten — no pair's budget
    ever exceeds it; ``min_ms`` keeps budgets from collapsing on very
    fast links (a 2 ms link does not deserve a 2.6 ms budget);
    ``max_relax`` caps the measured p999/p99 relax factor.
    """

    headroom: float = 1.25
    cap_ms: float = REGION_LATENCY_BUDGET_MS
    min_ms: float = 5.0
    max_relax: float = 2.5


class LatencySLOScheduler(SchedulerLevel):
    """Measured-latency placement vetting (the "netlat" level)."""

    name = "netlat"

    def __init__(
        self,
        cluster,
        bank: Optional[LinkSketchBank] = None,
        config: NetlatConfig = NetlatConfig(),
        now: Optional[int] = None,
    ):
        self.cluster = cluster
        self.bank = bank
        self.config = config
        self._relax_apps: Optional[np.ndarray] = None  # bool[N] relaxed apps
        self._relax_factor = RELAX_LATENCY_FACTOR
        self._rejections = 0
        live = bank is not None and bank.calibrated
        self._measured = bool(live)
        if live:
            baseline = np.asarray(bank.calibrated_p99, np.float64)  # [G, G]
            self._budget = np.clip(config.headroom * baseline, config.min_ms, config.cap_ms)
            tick = now if now is not None else int(bank.calibrated_at or 0)
            self._live_p99 = np.asarray(bank.p99(tick), np.float64)
            self._relax_factor = bank.relax_factor(
                cap=config.max_relax, default=RELAX_LATENCY_FACTOR
            )
        else:
            # Inert fallback: the static region contract — the cluster's
            # declared latency matrix against the scalar cap budget.
            self._budget = np.full_like(
                np.asarray(cluster.region_latency, np.float64), config.cap_ms
            )
            self._live_p99 = np.asarray(cluster.region_latency, np.float64)

    # -- feasibility ----------------------------------------------------------
    def _tier_bad(self, factor: float = 1.0) -> np.ndarray:
        """bool[G, T]: tier t unreachable from source region g — some pair
        (g, r), r in tier t, measures above ``factor x`` its budget.  A
        tier with no regions is unreachable outright (same contract as the
        region level)."""
        c = self.cluster
        bad_pair = self._live_p99 > factor * self._budget  # [G, G]
        tier_bad = bad_pair.astype(np.float64) @ c.tier_regions.T.astype(np.float64) > 0.0
        tier_bad[:, ~c.tier_regions.any(axis=1)] = True
        return tier_bad

    def feasibility_matrix(self) -> np.ndarray:
        """bool[N, T] per-app feasibility under the live measured budgets
        (relaxed apps, if any, get the relaxed variant)."""
        c = self.cluster
        strict = ~self._tier_bad()[c.app_region]  # [N, T]
        if self._relax_apps is None or not self._relax_apps.any():
            return strict
        relaxed = ~self._tier_bad(self._relax_factor)[c.app_region]
        return np.where(self._relax_apps[:, None], relaxed, strict)

    def check_many(self, apps: np.ndarray, tiers: np.ndarray) -> np.ndarray:
        apps = np.asarray(apps, np.int64)
        tiers = np.asarray(tiers, np.int64)
        return self.feasibility_matrix()[apps, tiers]

    # -- SchedulerLevel protocol ----------------------------------------------
    def premask(self, problem) -> np.ndarray:
        return ~self.feasibility_matrix()

    def vet(self, proposal: Proposal) -> np.ndarray:
        c = proposal.candidates
        if c.size == 0:
            return np.asarray(c, np.int64)
        ok = self.check_many(c, proposal.x[c])
        rejected = np.asarray(c[~ok], np.int64)
        self._rejections += int(rejected.size)
        return rejected

    def relax(self, plan, cluster) -> None:
        """Maintenance placement mode, measured edition: residents of a
        declared deep drain may exceed their pair budgets by the *measured*
        tail ratio (p999/p99) instead of the fixed 1.5x."""
        relax_tiers = getattr(plan, "relax_home_tiers", None)
        if relax_tiers is None or not np.asarray(relax_tiers).any():
            return
        if not self._measured:
            # Uncalibrated: honor the plan's declared factor (static parity).
            self._relax_factor = float(getattr(plan, "relax_latency_factor", RELAX_LATENCY_FACTOR))
        x0 = np.asarray(self.cluster.problem.assignment0)
        self._relax_apps = np.asarray(relax_tiers)[x0]

    def counters(self) -> dict:
        out = {
            "rejections": self._rejections,
            "measured": int(self._measured),
            "relax_factor": round(float(self._relax_factor), 4),
        }
        if self.bank is not None:
            out["quarantined_total"] = int(self.bank.quarantined_total)
        return out
