"""Measured-latency control plane: streaming sketches + the netlat level.

Importing this package registers the ``"netlat"`` scheduler level with the
cooperation-bus registry (``core.levels.level_factory`` lazy-imports it on
first use, same contract as the shard locality level).  Because levels are
re-bound from the registry each cooperation pass while the measurement
state must persist across ticks, the persistent ``LinkSketchBank`` is
installed process-wide with ``install_bank``; the factory closes over it.
With no bank installed the level is constructed inert (static-budget
behavior, pinned by the parity suite).
"""

from __future__ import annotations

from typing import Optional

from repro.core.levels import register_level
from repro.netlat.level import LatencySLOScheduler, NetlatConfig
from repro.netlat.sketches import (
    LinkMeasurementSource,
    LinkSketchBank,
    P2QuantileBank,
    SourceConfig,
)

_ACTIVE_BANK: Optional[LinkSketchBank] = None
_ACTIVE_CONFIG: NetlatConfig = NetlatConfig()
_ACTIVE_NOW: Optional[int] = None


def install_bank(
    bank: Optional[LinkSketchBank],
    config: Optional[NetlatConfig] = None,
    now: Optional[int] = None,
) -> None:
    """Install (or clear, with ``None``) the process-wide sketch bank the
    ``"netlat"`` level factory binds against.  ``now`` is the current tick
    (for staleness inflation of the live estimates); callers advance it
    with ``set_now`` each tick."""
    global _ACTIVE_BANK, _ACTIVE_CONFIG, _ACTIVE_NOW
    _ACTIVE_BANK = bank
    if config is not None:
        _ACTIVE_CONFIG = config
    if now is not None:
        _ACTIVE_NOW = int(now)


def set_now(now: int) -> None:
    """Advance the tick the bound level evaluates staleness at."""
    global _ACTIVE_NOW
    _ACTIVE_NOW = int(now)


def active_bank() -> Optional[LinkSketchBank]:
    return _ACTIVE_BANK


def _make_level(cluster) -> LatencySLOScheduler:
    return LatencySLOScheduler(cluster, bank=_ACTIVE_BANK, config=_ACTIVE_CONFIG, now=_ACTIVE_NOW)


register_level("netlat", _make_level)

__all__ = [
    "LatencySLOScheduler",
    "LinkMeasurementSource",
    "LinkSketchBank",
    "NetlatConfig",
    "P2QuantileBank",
    "SourceConfig",
    "active_bank",
    "install_bank",
    "set_now",
]
