"""Streaming per-link-pair latency percentiles: batched P² quantile sketches.

The paper's Fig. 4 headline metric is worst-case (p99) network latency of
app movements, yet until this subsystem the control plane vetted moves
against a hard-coded 36 ms constant.  Henge (arXiv 1802.00082) argues
latency SLOs must be driven by *measured* per-tenant behavior; this module
is the measurement half of that loop:

* ``P2QuantileBank`` — the P² algorithm (Jain & Chlamtac, CACM 1985) run
  simultaneously over every region pair and every tracked quantile.  P² is
  the classic fixed-size streaming estimator: five markers per quantile,
  O(1) state per stream, no sample retention.  The bank keeps the marker
  state as ``[Q, G*G, 5]`` numpy arrays so one tick's ``[G, G]`` latency
  observation updates *all* pairs with a handful of vectorized ops — no
  per-pair Python loop on the hot path.  Sketches are mergeable: two banks
  combine by inverting the count-weighted mixture of their piecewise-linear
  CDFs (exact for the empirical phase, tolerance-bounded afterwards), so
  per-shard probers can aggregate into a fleet view.

* ``LinkSketchBank`` — the operational wrapper the scheduler level
  (``repro.netlat.level``) reads: plausibility quarantine and staleness
  inflation in the spirit of ``core.health.TelemetryMonitor`` (corrupt or
  stale link readings inflate uncertainty instead of poisoning budgets),
  a calibration snapshot that freezes per-pair budgets from the observed
  baseline, and a ``SignalHealth`` record that folds link-latency health
  into the controller's composite score via
  ``TelemetryMonitor.note_signal``.

* ``LinkMeasurementSource`` — the simulated per-tick prober: noisy
  (lognormal body + occasional heavy tail) samples around the fleet's true
  effective latency matrix, deterministic per (seed, tick) so twin
  trajectory runs observe identical measurements.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.health import HealthConfig, SignalHealth

# Marker probabilities of a P² sketch tracking quantile p, in marker order:
# min, p/2, p, (1+p)/2, max.
_MARKERS = 5


def _marker_probs(p: float) -> np.ndarray:
    return np.array([0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0], np.float64)


class P2QuantileBank:
    """P² streaming quantile estimation, batched over parallel streams.

    ``shape`` is the stream grid (e.g. ``(G, G)`` region pairs); one
    ``update`` consumes a full-grid observation.  ``quantiles`` are the
    tracked targets; state is ``[Q, M, 5]`` marker heights/positions plus a
    per-stream count — fixed-size whatever the stream length.
    """

    def __init__(self, shape, quantiles=(0.5, 0.99, 0.999)):
        self.shape = tuple(int(s) for s in shape)
        self.quantiles = tuple(float(p) for p in quantiles)
        m = int(np.prod(self.shape))
        q = len(self.quantiles)
        self._m = m
        self.count = np.zeros(m, np.int64)
        # Empirical phase: the first five observations per stream, sorted
        # into the marker heights when the sketch proper starts.
        self._buf = np.zeros((m, _MARKERS), np.float64)
        # Sketch phase: heights, integer positions, desired positions.
        self.heights = np.zeros((q, m, _MARKERS), np.float64)
        self.pos = np.zeros((q, m, _MARKERS), np.float64)
        self.desired = np.zeros((q, m, _MARKERS), np.float64)
        self._probs = np.stack([_marker_probs(p) for p in self.quantiles])
        self._dn = self._probs.copy()  # desired-position increments per obs

    # -- updates --------------------------------------------------------------
    def update(self, samples: np.ndarray, mask: Optional[np.ndarray] = None) -> None:
        """Fold one grid observation (or a ``[..., S]`` batch) into every
        stream.  ``mask`` (broadcastable to the grid) marks streams whose
        sample this round should be *dropped* (quarantine)."""
        samples = np.asarray(samples, np.float64)
        if samples.shape == self.shape:
            samples = samples[..., None]
        flat = samples.reshape(self._m, -1)
        keep = None
        if mask is not None:
            keep = ~np.broadcast_to(np.asarray(mask, bool), samples.shape).reshape(self._m, -1)
        for s in range(flat.shape[1]):
            self._update_one(flat[:, s], keep[:, s] if keep is not None else None)

    def _update_one(self, x: np.ndarray, keep: Optional[np.ndarray]) -> None:
        upd = np.ones(self._m, bool) if keep is None else keep.copy()
        if not upd.any():
            return
        # Empirical phase: buffer the first five observations.
        fresh = upd & (self.count < _MARKERS)
        if fresh.any():
            idx = np.where(fresh)[0]
            self._buf[idx, self.count[idx]] = x[idx]
            self.count[idx] += 1
            done = idx[self.count[idx] == _MARKERS]
            if done.size:
                self._seed_markers(done)
            upd = upd & ~fresh
        if not upd.any():
            return
        self.count[upd] += 1
        self._p2_step(x, upd)

    def _seed_markers(self, streams: np.ndarray) -> None:
        """Streams that just collected five observations enter the sketch
        phase: sorted buffer becomes the marker heights, positions reset to
        the canonical 1..5."""
        seed = np.sort(self._buf[streams], axis=1)
        self.heights[:, streams] = seed[None]
        self.pos[:, streams] = np.arange(1, _MARKERS + 1, dtype=np.float64)
        self.desired[:, streams] = 1.0 + 4.0 * self._probs[:, None, :]

    def _p2_step(self, x: np.ndarray, upd: np.ndarray) -> None:
        """One vectorized P² marker adjustment over [Q, M] streams."""
        q, n, nd = self.heights, self.pos, self.desired
        xs = x[None, :]  # [1, M] broadcast over quantiles
        # Locate the cell, clamping x into the observed range.
        k = (xs[..., None] >= q).sum(axis=-1)  # [Q, M] markers <= x
        below = upd[None, :] & (k == 0)
        above = upd[None, :] & (k == _MARKERS)
        q[..., 0] = np.where(below, xs, q[..., 0])
        q[..., -1] = np.where(above, xs, q[..., -1])
        cell = np.clip(k, 1, _MARKERS - 1) - 1  # [Q, M] in 0..3
        bump = (np.arange(_MARKERS)[None, None, :] > cell[..., None]) & upd[None, :, None]
        n += bump
        nd += np.where(upd[None, :, None], self._dn[:, None, :], 0.0)
        # Adjust the three interior markers toward their desired positions.
        with np.errstate(divide="ignore", invalid="ignore"):
            for i in range(1, _MARKERS - 1):
                d = nd[..., i] - n[..., i]
                up = (d >= 1.0) & (n[..., i + 1] - n[..., i] > 1.0)
                dn = (d <= -1.0) & (n[..., i - 1] - n[..., i] < -1.0)
                s = np.where(up, 1.0, np.where(dn, -1.0, 0.0))
                act = upd[None, :] & (s != 0.0)
                if not act.any():
                    continue
                gap = n[..., i + 1] - n[..., i - 1]
                para = q[..., i] + (s / gap) * (
                    (n[..., i] - n[..., i - 1] + s)
                    * (q[..., i + 1] - q[..., i])
                    / (n[..., i + 1] - n[..., i])
                    + (n[..., i + 1] - n[..., i] - s)
                    * (q[..., i] - q[..., i - 1])
                    / (n[..., i] - n[..., i - 1])
                )
                ok = (q[..., i - 1] < para) & (para < q[..., i + 1])
                lin_up = q[..., i] + (q[..., i + 1] - q[..., i]) / (n[..., i + 1] - n[..., i])
                lin_dn = q[..., i] - (q[..., i - 1] - q[..., i]) / (n[..., i - 1] - n[..., i])
                lin = np.where(s > 0, lin_up, lin_dn)
                new_q = np.where(ok, para, lin)
                q[..., i] = np.where(act, new_q, q[..., i])
                n[..., i] = n[..., i] + np.where(act, s, 0.0)

    # -- estimates ------------------------------------------------------------
    def quantile(self, p: float) -> np.ndarray:
        """Current estimate of tracked quantile ``p``, shaped like the
        stream grid.  Streams still in the empirical phase answer from
        their buffer; streams with no observations answer NaN."""
        try:
            qi = self.quantiles.index(float(p))
        except ValueError:
            raise KeyError(f"quantile {p} not tracked; have {self.quantiles}")
        out = np.full(self._m, np.nan)
        sketch = self.count >= _MARKERS
        out[sketch] = self.heights[qi, sketch, 2]
        part = ~sketch & (self.count > 0)
        for m in np.where(part)[0]:
            out[m] = np.quantile(self._buf[m, : self.count[m]], p)
        return out.reshape(self.shape)

    # -- merge ----------------------------------------------------------------
    def _cdf_points(self, qi: int, m: int):
        """(xs, probs) piecewise-linear CDF of stream ``m`` for tracked
        quantile index ``qi`` — marker heights in the sketch phase, the
        sorted buffer in the empirical phase."""
        c = int(self.count[m])
        if c >= _MARKERS:
            return self.heights[qi, m], self._probs[qi]
        xs = np.sort(self._buf[m, :c])
        if c == 1:
            return np.array([xs[0], xs[0]]), np.array([0.0, 1.0])
        return xs, np.linspace(0.0, 1.0, c)

    def merge(self, other: "P2QuantileBank") -> "P2QuantileBank":
        """Count-weighted merge: invert the mixture of both sketches'
        piecewise-linear CDFs at the canonical marker probabilities.
        Commutative by construction; associative to within the sketches'
        own approximation error (the unit tests bound it)."""
        if self.shape != other.shape or self.quantiles != other.quantiles:
            raise ValueError("merge requires identical grid and quantiles")
        out = P2QuantileBank(self.shape, self.quantiles)
        for m in range(self._m):
            ca, cb = int(self.count[m]), int(other.count[m])
            c = ca + cb
            out.count[m] = c
            if c == 0:
                continue
            if c < _MARKERS:  # still empirical: concatenate the buffers
                out._buf[m, :c] = np.concatenate([self._buf[m, :ca], other._buf[m, :cb]])
                continue
            for qi in range(len(self.quantiles)):
                xa, pa = self._cdf_points(qi, m)
                xb, pb = other._cdf_points(qi, m)
                grid = np.unique(np.concatenate([xa, xb]))
                fa = np.interp(grid, xa, pa)
                fb = np.interp(grid, xb, pb)
                f = (ca * fa + cb * fb) / c
                heights = np.interp(self._probs[qi], f, grid)
                heights = np.maximum.accumulate(heights)
                out.heights[qi, m] = heights
                out.pos[qi, m] = np.maximum(
                    np.arange(1, _MARKERS + 1),
                    np.round(1.0 + (c - 1) * self._probs[qi]),
                )
                out.pos[qi, m] = np.maximum.accumulate(out.pos[qi, m])
                out.pos[qi, m, -1] = max(out.pos[qi, m, -1], float(c))
                out.desired[qi, m] = 1.0 + (c - 1) * self._probs[qi]
        return out


# ---------------------------------------------------------------------------
# operational wrapper: quarantine, staleness, calibration, health
# ---------------------------------------------------------------------------


class LinkSketchBank:
    """Per-region-pair latency sketches with telemetry-health semantics.

    ``ingest(samples, now)`` quarantines implausible readings (non-finite,
    negative, or jumping more than ``max_jump_factor`` x the stream's
    current median) before they reach the sketch, mirroring the
    ``TelemetryMonitor`` plausibility contract; ``p99(now)`` inflates the
    live estimate by the staleness uncertainty factor so budgets derived
    from old measurements over-protect instead of over-trusting.
    ``calibrate(now)`` freezes the per-pair p99 baseline the scheduler
    level turns into budgets.
    """

    def __init__(self, num_regions: int, config: HealthConfig = HealthConfig()):
        self.num_regions = int(num_regions)
        self.config = config
        self.sketches = P2QuantileBank((num_regions, num_regions))
        self.last_update = np.full((num_regions, num_regions), -(10**9), np.int64)
        self.quarantined_total = 0
        self._quarantined_last = 0
        self.calibrated_p99: Optional[np.ndarray] = None
        self.calibrated_at: Optional[int] = None

    # -- ingestion ------------------------------------------------------------
    def ingest(self, samples: np.ndarray, now: int) -> int:
        """Fold a ``[G, G]`` or ``[G, G, S]`` latency observation collected
        at tick ``now``; returns the number of quarantined samples."""
        cfg = self.config
        samples = np.asarray(samples, np.float64)
        if samples.ndim == 2:
            samples = samples[..., None]
        bad = ~np.isfinite(samples) | (samples < 0.0)
        med = self.sketches.quantile(0.5)
        seen = np.isfinite(med)
        if seen.any():
            ref = np.abs(np.where(seen, med, 0.0)) + cfg.jump_floor
            jump = np.abs(samples - med[..., None]) > (
                (cfg.max_jump_factor - 1.0) * ref[..., None]
            )
            bad = bad | (jump & seen[..., None])
        n_bad = int(bad.sum())
        self.quarantined_total += n_bad
        self._quarantined_last = n_bad
        self.sketches.update(np.where(bad, 0.0, samples), mask=bad)
        accepted = (~bad).any(axis=-1)
        self.last_update[accepted] = int(now)
        return n_bad

    # -- staleness ------------------------------------------------------------
    def staleness(self, now: int) -> np.ndarray:
        return np.maximum(0, int(now) - self.last_update)

    def inflation(self, now: int) -> np.ndarray:
        """Per-pair uncertainty factor: 1.0 while fresh, widening by
        ``uncertainty_growth`` per tick past ``stale_after`` (capped)."""
        cfg = self.config
        over = np.maximum(0, self.staleness(now) - cfg.stale_after)
        return np.minimum(cfg.max_inflation, (1.0 + cfg.uncertainty_growth) ** over)

    # -- estimates ------------------------------------------------------------
    @property
    def observed(self) -> bool:
        """Every pair has left the empirical phase (>= 5 samples)."""
        return bool((self.sketches.count >= _MARKERS).all())

    def p99(self, now: Optional[int] = None) -> np.ndarray:
        """Live per-pair p99 estimate, staleness-inflated when ``now`` is
        given (the conservative view budgets should be checked against)."""
        est = self.sketches.quantile(0.99)
        if now is None:
            return est
        return est * self.inflation(now)

    def relax_factor(self, floor: float = 1.0, cap: float = 2.5, default: float = 1.5) -> float:
        """The maintenance relax factor, derived from the measured tail:
        the fleet-median p999/p99 ratio (how much worse the extreme tail
        is than the SLO percentile), clipped to [floor, cap].  Falls back
        to ``default`` until every pair has real sketch state."""
        if not self.observed:
            return float(default)
        p99 = self.sketches.quantile(0.99)
        p999 = self.sketches.quantile(0.999)
        with np.errstate(divide="ignore", invalid="ignore"):
            ratio = np.where(p99 > 0.0, p999 / p99, 1.0)
        ratio = ratio[np.isfinite(ratio)]
        if ratio.size == 0:
            return float(default)
        return float(np.clip(np.median(ratio), floor, cap))

    # -- calibration ----------------------------------------------------------
    def calibrate(self, now: int) -> bool:
        """Freeze the current p99 estimate as the budget baseline.  Returns
        False (and stays uncalibrated) until every pair has sketch state —
        calibrating from a half-empty bank would write NaN budgets."""
        if not self.observed:
            return False
        self.calibrated_p99 = self.sketches.quantile(0.99).copy()
        self.calibrated_at = int(now)
        return True

    @property
    def calibrated(self) -> bool:
        return self.calibrated_p99 is not None

    # -- health integration ---------------------------------------------------
    def signal_health(self, now: int) -> SignalHealth:
        """Link-latency health in ``TelemetryMonitor`` scoring terms: the
        worst pair's staleness x the quarantined fraction of the last
        ingest.  Feed to ``TelemetryMonitor.note_signal`` so blind or
        corrupt link probes degrade the composite score."""
        cfg = self.config
        staleness = int(self.staleness(now).max()) if self.last_update.size else 0
        if staleness <= cfg.stale_after:
            stale_score = 1.0
        elif staleness >= cfg.blind_after:
            stale_score = 0.0
        else:
            span = max(1, cfg.blind_after - cfg.stale_after)
            stale_score = 1.0 - (staleness - cfg.stale_after) / span
        pairs = self.num_regions * self.num_regions
        frac = self._quarantined_last / max(1, pairs)
        plaus = (
            max(0.0, 1.0 - frac / cfg.quarantine_blind_frac)
            if cfg.quarantine_blind_frac > 0
            else float(frac == 0)
        )
        return SignalHealth(
            "link_latency",
            staleness,
            self._quarantined_last,
            pairs,
            round(stale_score * plaus, 4),
        )


# ---------------------------------------------------------------------------
# simulated measurement source
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SourceConfig:
    """The simulated prober's noise model: a lognormal body around the true
    link latency plus an occasional heavy-tail straggler, so the measured
    distribution has a real p999/p99 gap to calibrate the relax factor
    from."""

    samples_per_tick: int = 4
    sigma: float = 0.08
    tail_prob: float = 0.01
    tail_factor: float = 2.0


class LinkMeasurementSource:
    """Deterministic per-tick link prober over the fleet's true latency.

    Draws from ``default_rng([seed, tick])`` — a pure function of (seed,
    tick), so oracle-twin runs that replay the same trajectory observe
    bit-identical measurements regardless of how many times each run
    refreshes its fleet state.
    """

    def __init__(self, seed: int = 0, config: SourceConfig = SourceConfig()):
        self.seed = int(seed)
        self.config = config

    def measure(self, region_latency: np.ndarray, tick: int) -> np.ndarray:
        """[G, G, S] noisy samples of the true effective latency matrix."""
        cfg = self.config
        lat = np.asarray(region_latency, np.float64)
        rng = np.random.default_rng([self.seed, int(tick)])
        shape = lat.shape + (cfg.samples_per_tick,)
        # Mean-corrected lognormal body: E[factor] == 1.
        body = rng.lognormal(-0.5 * cfg.sigma**2, cfg.sigma, size=shape)
        tail = rng.random(shape) < cfg.tail_prob
        factor = np.where(tail, cfg.tail_factor, 1.0) * body
        return lat[..., None] * factor
