"""FleetCoordinator: the cross-shard scheduler level atop the shard split.

Rataj et al.'s taxonomy frames a fleet coordinator as one more level in the
scheduler hierarchy, not a bolt-on — so the coordinator rides the PR-5
cooperation bus as a ``SchedulerLevel`` (``register_level("fleet", ...)``):

  * ``premask`` folds the shard partition into the solver's avoid mask —
    tiers outside an app's home shard are off-limits unless the coordinator
    has granted that (app, tier) migration;
  * ``vet`` rejects any proposal that crosses a shard boundary without a
    grant (counted per level like every other rejection);
  * saturation detection reads per-shard utilization and strand telemetry
    from a merged assignment, and ``plan_migrations`` rebalances shard
    boundaries by granting donor apps from saturated shards to the
    least-loaded shards' feasible tiers — every move priced against the
    PR-4 movement budget (Madsen-style ``core.planner.move_costs`` units).

``shard.fleet.solve_fleet`` drives the host-side half (saturation ->
migrations) directly after each batched pass; the bus half makes the same
policy available to the global cooperate() stack via
``CoopConfig(levels=(..., "fleet"))``.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.levels import BusState, Proposal, SchedulerLevel, register_level
from repro.core.problem import tier_loads
from repro.shard.partition import ShardPlan, plan_shards

# A shard is saturated when its worst resource runs above this fraction of
# the shard's aggregate capacity (the ideal_frac default is 0.70; 0.85
# leaves headroom before the hard limit binds).
SATURATION_FRAC = 0.85


def shard_utilization(plan: ShardPlan, problem, assignment) -> np.ndarray:
    """f32[S]: worst-resource utilization fraction per shard."""
    util, _ = tier_loads(problem, np.asarray(assignment))
    util = np.asarray(util, np.float64)
    cap = np.asarray(problem.capacity, np.float64)
    out = np.zeros(plan.num_shards)
    for s, tiers in enumerate(plan.shard_tiers):
        total = cap[tiers].sum(axis=0)
        out[s] = float((util[tiers].sum(axis=0) / np.maximum(total, 1e-9)).max())
    return out


class FleetCoordinator(SchedulerLevel):
    """Cross-shard migration vetting + shard-boundary rebalancing."""

    name = "fleet"

    def __init__(
        self,
        cluster,
        num_shards: int = 4,
        saturation: float = SATURATION_FRAC,
        migration_frac: float = 0.05,
        plan: Optional[ShardPlan] = None,
    ):
        self.cluster = cluster
        self.plan = plan if plan is not None else plan_shards(cluster, num_shards)
        self.saturation = float(saturation)
        self.migration_frac = float(migration_frac)
        p = cluster.problem
        self._granted = np.zeros((p.num_apps, p.num_tiers), bool)
        self._counters = {
            "granted": 0,
            "rejected_cross_shard": 0,
            "saturated_shards": 0,
        }

    # -- bus protocol -----------------------------------------------------

    def premask(self, problem) -> np.ndarray:
        """Avoid every tier outside the app's shard, minus standing grants.

        The home column stays open by construction (an app's home tier is
        in its own shard), so the mask never strands an incumbent.
        """
        cross = (
            self.plan.tier_shard[None, :] != self.plan.app_shard[:, None]
        ) & ~self._granted
        return cross

    def vet(self, proposal: Proposal) -> np.ndarray:
        c = proposal.candidates
        if c.size == 0:
            return c
        dest = proposal.x[c]
        ok = (self.plan.tier_shard[dest] == self.plan.app_shard[c]) | self._granted[
            c, dest
        ]
        rejected = c[~ok]
        self._counters["rejected_cross_shard"] += int(rejected.size)
        return rejected

    def feedback(self, state: BusState) -> Optional[np.ndarray]:
        return None  # the premask is already the full shard constraint

    def counters(self) -> dict:
        return dict(self._counters)

    # -- saturation + boundary rebalancing --------------------------------

    def saturated_shards(self, problem, assignment) -> np.ndarray:
        """bool[S]: shards running above the saturation threshold."""
        util = shard_utilization(self.plan, problem, assignment)
        sat = util > self.saturation
        self._counters["saturated_shards"] = int(sat.sum())
        return sat

    def plan_migrations(
        self,
        problem,
        assignment,
        *,
        move_cost: Optional[np.ndarray] = None,
        cost_budget: float = float("inf"),
        max_moves: Optional[int] = None,
    ) -> list[tuple[int, int]]:
        """Grant boundary migrations out of saturated shards.

        Donors leave in descending demand-mass x (1 - criticality) order —
        big, non-critical apps buy the most relief per priced move.  Each
        donor goes to the least-loaded shard's best-headroom feasible tier;
        grants stop when the shard drops below the threshold, the movement
        budget is spent, or ``max_moves`` is hit.  Returns the granted
        (app, tier) moves; the same pairs are recorded so the bus hooks
        accept them on the next cooperate round.
        """
        x = np.asarray(assignment).copy()
        util = shard_utilization(self.plan, problem, x)
        sat = util > self.saturation
        self._counters["saturated_shards"] = int(sat.sum())
        if not sat.any():
            return []

        demand = np.asarray(problem.demand, np.float64)
        tasks = np.asarray(problem.tasks, np.float64)
        valid = np.asarray(problem.valid)
        feas = np.asarray(problem.feasible_mask())
        cap = np.asarray(problem.capacity, np.float64)
        klim = np.asarray(problem.task_limit, np.float64)
        tier_util, tier_tasks = tier_loads(problem, x)
        tier_util = np.asarray(tier_util, np.float64).copy()
        tier_tasks = np.asarray(tier_tasks, np.float64).copy()
        per_cost = (
            np.ones(x.size) if move_cost is None else np.asarray(move_cost, np.float64)
        )
        cap_frac = self.saturation
        budget = float(cost_budget)
        limit = int(max_moves) if max_moves is not None else max(
            1, int(round(self.migration_frac * int(valid.sum())))
        )

        # Incremental shard-level accounting: aggregate once, update per
        # move — the grant loop never re-runs an O(N) reduction.
        shard_cap = np.stack(
            [cap[tiers].sum(axis=0) for tiers in self.plan.shard_tiers]
        )
        shard_util = np.stack(
            [tier_util[tiers].sum(axis=0) for tiers in self.plan.shard_tiers]
        )

        def shard_frac(s):
            return float((shard_util[s] / np.maximum(shard_cap[s], 1e-9)).max())

        moves: list[tuple[int, int]] = []
        order = np.argsort(-util)
        for s in order:
            if not sat[s] or len(moves) >= limit or budget <= 0:
                continue
            donors = np.where((self.plan.app_shard == s) & valid)[0]
            rank = demand[donors].sum(axis=1) * (
                1.0 - np.asarray(problem.criticality)[donors]
            )
            for a in donors[np.argsort(-rank)]:
                if len(moves) >= limit or budget < per_cost[a]:
                    break
                if shard_frac(s) <= cap_frac:
                    break
                targets = np.argsort(
                    [shard_frac(t_shard) for t_shard in range(self.plan.num_shards)]
                )
                dest = -1
                for t_shard in targets:
                    if t_shard == s:
                        continue
                    for t in self.plan.shard_tiers[t_shard]:
                        if not feas[a, t]:
                            continue
                        fits = (
                            tier_util[t] + demand[a] <= cap_frac * cap[t]
                        ).all() and tier_tasks[t] + tasks[a] <= cap_frac * klim[t]
                        if fits:
                            dest = int(t)
                            break
                    if dest >= 0:
                        break
                if dest < 0:
                    continue
                src = int(x[a])
                dest_shard = int(self.plan.tier_shard[dest])
                tier_util[src] -= demand[a]
                tier_tasks[src] -= tasks[a]
                tier_util[dest] += demand[a]
                tier_tasks[dest] += tasks[a]
                shard_util[s] -= demand[a]
                shard_util[dest_shard] += demand[a]
                x[a] = dest
                budget -= per_cost[a]
                moves.append((int(a), dest))
                self._granted[a, dest] = True
        self._counters["granted"] += len(moves)
        return moves


register_level("fleet", FleetCoordinator)
