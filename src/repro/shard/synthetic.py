"""Vectorized synthetic fleets for 100k-1M-app scale runs.

``telemetry.generate_cluster`` is paper-calibrated but builds its initial
placement and app-region draw with O(N) Python loops and its ResourceMonitor
p99 sampling allocates a (samples, N, R) block — both fine at N=400, fatal
at N=1M.  This builder produces a statistically matching fleet (lognormal
demand, Poisson tasks, the generic SLO table, contiguous tier region arcs,
capacity scaled to an initial utilization target) with every draw
vectorized, so the ``shard_scale`` benchmarks can stand up a million-app
cluster in seconds.  It intentionally skips the monitor/p99 stage: demand
IS the collected p99.
"""

from __future__ import annotations

import numpy as np

from repro.core.problem import NUM_RESOURCES, make_problem
from repro.core.telemetry import ClusterState


def synthetic_fleet(
    num_apps: int,
    num_tiers: int = 16,
    num_regions: int = 8,
    *,
    seed: int = 0,
    util_target: float = 0.55,
    move_frac: float = 0.10,
) -> ClusterState:
    """A generated fleet with every per-app draw vectorized.

    Capacity is sized so initial worst-resource utilization sits near
    ``util_target`` per tier — busy enough that balancing matters, slack
    enough that the incumbent mapping is feasible.
    """
    rng = np.random.default_rng(seed)
    N, T, G = int(num_apps), int(num_tiers), int(num_regions)
    R = NUM_RESOURCES

    demand = np.empty((N, R), np.float32)
    demand[:, 0] = rng.lognormal(1.2, 0.9, N)
    demand[:, 1] = rng.lognormal(1.8, 0.9, N)
    tasks = (1.0 + rng.poisson(6.0, N)).astype(np.float32)
    n_slo = 5
    slo = rng.choice(n_slo, size=N, p=[0.2, 0.2, 0.3, 0.15, 0.15]).astype(np.int32)
    criticality = rng.beta(2.0, 5.0, N).astype(np.float32)

    # Generic SLO support table (the T != 5 fallback of generate_cluster):
    # each class lands on ~70% of tiers, class 2 everywhere so no class is
    # ever placement-starved.
    slo_allowed = rng.random((T, n_slo)) < 0.7
    slo_allowed[:, 2] = True
    for c in range(n_slo):
        if not slo_allowed[:, c].any():
            slo_allowed[rng.integers(0, T), c] = True

    # Initial placement: one vectorized choice per SLO class over its
    # allowed tiers (uniform — capacity is sized to the result afterwards).
    assignment0 = np.zeros(N, np.int32)
    for c in range(n_slo):
        apps = np.where(slo == c)[0]
        ok = np.where(slo_allowed[:, c])[0]
        assignment0[apps] = rng.choice(ok, size=apps.size)

    # Contiguous region arcs per tier (the ring geometry plan_shards keys
    # on), and app regions drawn from the home tier's arc.
    tier_regions = np.zeros((T, G), bool)
    for t in range(T):
        start = int(round(t * G / T)) % G
        arc = int(rng.integers(2, min(4, G) + 1))
        tier_regions[t, (start + np.arange(arc)) % G] = True
    app_region = np.zeros(N, np.int32)
    for t in range(T):
        apps = np.where(assignment0 == t)[0]
        if apps.size:
            app_region[apps] = rng.choice(np.where(tier_regions[t])[0], size=apps.size)

    # Capacity from the placement: worst-resource utilization ~ util_target.
    util = np.zeros((T, R), np.float64)
    np.add.at(util, assignment0, demand)
    tier_tasks = np.zeros(T, np.float64)
    np.add.at(tier_tasks, assignment0, tasks)
    capacity = np.maximum(util / util_target, demand.max() * 1.5).astype(np.float32)
    task_limit = np.maximum(tier_tasks / util_target, tasks.max() * 2).astype(
        np.float32
    )

    ring = np.abs(np.arange(G)[:, None] - np.arange(G)[None, :])
    ring = np.minimum(ring, G - ring)
    region_latency = (4.0 + 14.0 * ring + rng.uniform(0, 3, (G, G))).astype(np.float32)
    region_latency = ((region_latency + region_latency.T) / 2).astype(np.float32)
    np.fill_diagonal(region_latency, 0.0)

    hosts_per_tier = rng.integers(40, 120, T).astype(np.int32)
    host_capacity = (capacity.sum(axis=0) / hosts_per_tier.sum() * 1.6).astype(
        np.float32
    )

    problem = make_problem(
        demand=demand,
        tasks=tasks,
        slo=slo,
        criticality=criticality,
        assignment0=assignment0,
        capacity=capacity,
        task_limit=task_limit,
        slo_allowed=slo_allowed,
        move_frac=move_frac,
    )
    return ClusterState(
        problem=problem,
        app_names=[f"app_{i:07d}" for i in range(N)],
        tier_names=[f"tier_{t + 1}" for t in range(T)],
        app_region=app_region,
        tier_regions=tier_regions,
        region_latency=region_latency,
        hosts_per_tier=hosts_per_tier,
        host_capacity=host_capacity,
    )
