"""Sharded fleet solver: partitioned cooperation to 100k-1M apps.

Partitions the fleet into S region-affine subproblems (``partition``),
solves them as one batched vmapped LocalSearch pass (``solve``), merges the
result back into a feasible global assignment, and layers a
``FleetCoordinator`` scheduler level on top (``coordinator``) that vets
cross-shard migrations and rebalances shard boundaries against the
movement budget.  ``fleet.solve_fleet`` / ``fleet.balance_fleet`` are the
end-to-end entry points; ``synthetic.synthetic_fleet`` stands up the
million-app benchmark clusters.  See docs/fleet_sharding.md.
"""

from repro.shard.coordinator import (
    SATURATION_FRAC,
    FleetCoordinator,
    shard_utilization,
)
from repro.shard.fleet import FleetConfig, FleetDecision, balance_fleet, solve_fleet
from repro.shard.partition import (
    ShardedProblem,
    ShardPlan,
    merge_assignment,
    partition_problem,
    plan_shards,
    stranded_apps,
    tier_anchors,
)
from repro.shard.solve import (
    ShardSolveConfig,
    ShardSolveResult,
    shard_batch_trace_count,
    solve_shards,
)
from repro.shard.synthetic import synthetic_fleet

__all__ = [
    "SATURATION_FRAC",
    "FleetConfig",
    "FleetCoordinator",
    "FleetDecision",
    "ShardPlan",
    "ShardSolveConfig",
    "ShardSolveResult",
    "ShardedProblem",
    "balance_fleet",
    "merge_assignment",
    "partition_problem",
    "plan_shards",
    "shard_batch_trace_count",
    "shard_utilization",
    "solve_fleet",
    "solve_shards",
    "stranded_apps",
    "synthetic_fleet",
    "tier_anchors",
]
