"""Batched shard solving: S subproblems under one vmapped LocalSearch.

One executable solves every shard: the stacked ``ShardedProblem`` (uniform
(Nb, Tb) shapes from ``shard.partition``) runs through
``vmap(_solve_local_jit)`` under a single outer ``jit``, so a fleet of any
size costs one compilation per (S, Nb, Tb) shape triple — the same
shape-bucketed caching contract as the global solver, observable through
``shard_batch_trace_count``.

At ``temperature=0`` the batched top-k LocalSearch never consumes its PRNG
key, so the batched pass is deterministic and bit-reproducible per shard
regardless of the split.  Device placement of the stacked batch goes
through ``distributed.place_shard_batch`` (a no-op off-mesh).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.solver_local import _solve_local_jit
from repro.distributed.sharding import place_shard_batch
from repro.shard.partition import ShardedProblem

_TRACE_COUNTS = {"shard_batch": 0}
_CACHE: dict = {}


def shard_batch_trace_count() -> int:
    """How many times the batched shard solver has been (re)traced."""
    return _TRACE_COUNTS["shard_batch"]


@dataclasses.dataclass(frozen=True)
class ShardSolveConfig:
    """Knobs for the batched pass (mirrors ``LocalSearchConfig``)."""

    max_iters: int = 256
    tol: float = 1e-7
    batch_moves: int = 16
    batch_quality: float = 0.9
    seed: int = 0


@dataclasses.dataclass
class ShardSolveResult:
    """Per-shard outputs of one batched pass (leading [S] axis).

    Under a ``dirty`` mask (delta solve) the unsolved shards report their
    incumbent assignment with 0 iterations / 0 committed moves and a NaN
    objective; ``solved`` records which shards actually ran.
    """

    x: jax.Array  # i32[S, Nb] local assignments
    iterations: np.ndarray  # i32[S]
    converged: np.ndarray  # bool[S]
    committed: np.ndarray  # i32[S] committed moves per shard
    objective: np.ndarray  # f32[S] final per-shard objective
    solve_time_s: float
    trace_count: int
    solved: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, bool)
    )  # bool[S]


def _batched_solver(config: ShardSolveConfig):
    """jit(vmap(LocalSearch-core)), cached per static-knob tuple.

    The jit cache keys executables by the (S, Nb, Tb) leaf shapes on top of
    this per-knob cache, so drifting shard counts reuse compilations the
    same way drifting app counts reuse app buckets.
    """
    key = (config.max_iters, config.tol, config.batch_moves, config.batch_quality)
    fn = _CACHE.get(key)
    if fn is None:

        def one(p, k, x0):
            return _solve_local_jit(
                p,
                k,
                x0,
                max_iters=config.max_iters,
                temperature=0.0,
                tol=config.tol,
                batch_moves=config.batch_moves,
                batch_quality=config.batch_quality,
            )

        def batched(problems, keys, x0):
            _TRACE_COUNTS["shard_batch"] += 1
            return jax.vmap(one)(problems, keys, x0)

        fn = jax.jit(batched)
        _CACHE[key] = fn
    return fn


def solve_shards(
    sharded: ShardedProblem,
    config: ShardSolveConfig | None = None,
    *,
    dirty=None,
) -> ShardSolveResult:
    """Solve all shards (or only the ``dirty`` ones) as one batched pass.

    ``dirty`` is an optional bool[S] mask (or iterable of shard indices):
    the *delta-solve* path.  The dirty subproblems are gathered out of the
    stacked pytree with an index select — every leaf keeps the exact values
    it holds in the full stack, and the per-shard PRNG keys are gathered
    from the same ``split`` the full pass uses — so an all-dirty delta
    solve runs the identical executable on identical inputs and is
    bit-identical to the full solve (property-tested in
    tests/test_service.py).  A strict subset pays one extra compilation per
    new (S', Nb, Tb) shape triple and leaves unsolved shards at their
    incumbent assignment.
    """
    cfg = config if config is not None else ShardSolveConfig()
    S = sharded.num_shards
    problems = place_shard_batch(sharded.problems)
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), S)
    x0 = problems.assignment0
    fn = _batched_solver(cfg)

    if dirty is None:
        idx = np.arange(S)
    else:
        mask = np.asarray(dirty)
        idx = (
            np.where(mask)[0]
            if mask.dtype == bool
            else np.unique(mask.astype(np.int64))
        )
    solved = np.zeros(S, bool)
    solved[idx] = True
    if idx.size == 0:
        return ShardSolveResult(
            x=x0,
            iterations=np.zeros(S, np.int32),
            converged=np.ones(S, bool),
            committed=np.zeros(S, np.int32),
            objective=np.full(S, np.nan, np.float32),
            solve_time_s=0.0,
            trace_count=shard_batch_trace_count(),
            solved=solved,
        )

    gather = idx
    sub_problems = jax.tree_util.tree_map(lambda a: a[gather], problems)
    sub_keys = keys[gather]
    sub_x0 = x0[gather]
    t0 = time.perf_counter()
    x_sub, it, done, committed, obj = fn(sub_problems, sub_keys, sub_x0)
    x_sub = jax.block_until_ready(x_sub)
    if idx.size == S:
        return ShardSolveResult(
            x=x_sub,
            iterations=np.asarray(it),
            converged=np.asarray(done),
            committed=np.asarray(committed),
            objective=np.asarray(obj),
            solve_time_s=time.perf_counter() - t0,
            trace_count=shard_batch_trace_count(),
            solved=solved,
        )
    # Scatter the solved shards back; the rest keep their incumbents.
    x = np.asarray(x0).copy()
    x[idx] = np.asarray(x_sub)
    iterations = np.zeros(S, np.int32)
    iterations[idx] = np.asarray(it)
    converged = np.ones(S, bool)
    converged[idx] = np.asarray(done)
    committed_full = np.zeros(S, np.int32)
    committed_full[idx] = np.asarray(committed)
    objective = np.full(S, np.nan, np.float32)
    objective[idx] = np.asarray(obj)
    return ShardSolveResult(
        x=x,
        iterations=iterations,
        converged=converged,
        committed=committed_full,
        objective=objective,
        solve_time_s=time.perf_counter() - t0,
        trace_count=shard_batch_trace_count(),
        solved=solved,
    )
