"""Batched shard solving: S subproblems under one vmapped LocalSearch.

One executable solves every shard: the stacked ``ShardedProblem`` (uniform
(Nb, Tb) shapes from ``shard.partition``) runs through
``vmap(_solve_local_jit)`` under a single outer ``jit``, so a fleet of any
size costs one compilation per (S, Nb, Tb) shape triple — the same
shape-bucketed caching contract as the global solver, observable through
``shard_batch_trace_count``.

At ``temperature=0`` the batched top-k LocalSearch never consumes its PRNG
key, so the batched pass is deterministic and bit-reproducible per shard
regardless of the split.  Device placement of the stacked batch goes
through ``distributed.place_shard_batch`` (a no-op off-mesh).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.solver_local import _solve_local_jit
from repro.distributed.sharding import place_shard_batch
from repro.shard.partition import ShardedProblem

_TRACE_COUNTS = {"shard_batch": 0}
_CACHE: dict = {}


def shard_batch_trace_count() -> int:
    """How many times the batched shard solver has been (re)traced."""
    return _TRACE_COUNTS["shard_batch"]


@dataclasses.dataclass(frozen=True)
class ShardSolveConfig:
    """Knobs for the batched pass (mirrors ``LocalSearchConfig``)."""

    max_iters: int = 256
    tol: float = 1e-7
    batch_moves: int = 16
    batch_quality: float = 0.9
    seed: int = 0


@dataclasses.dataclass
class ShardSolveResult:
    """Per-shard outputs of one batched pass (leading [S] axis)."""

    x: jax.Array  # i32[S, Nb] local assignments
    iterations: np.ndarray  # i32[S]
    converged: np.ndarray  # bool[S]
    committed: np.ndarray  # i32[S] committed moves per shard
    objective: np.ndarray  # f32[S] final per-shard objective
    solve_time_s: float
    trace_count: int


def _batched_solver(config: ShardSolveConfig):
    """jit(vmap(LocalSearch-core)), cached per static-knob tuple.

    The jit cache keys executables by the (S, Nb, Tb) leaf shapes on top of
    this per-knob cache, so drifting shard counts reuse compilations the
    same way drifting app counts reuse app buckets.
    """
    key = (config.max_iters, config.tol, config.batch_moves, config.batch_quality)
    fn = _CACHE.get(key)
    if fn is None:

        def one(p, k, x0):
            return _solve_local_jit(
                p,
                k,
                x0,
                max_iters=config.max_iters,
                temperature=0.0,
                tol=config.tol,
                batch_moves=config.batch_moves,
                batch_quality=config.batch_quality,
            )

        def batched(problems, keys, x0):
            _TRACE_COUNTS["shard_batch"] += 1
            return jax.vmap(one)(problems, keys, x0)

        fn = jax.jit(batched)
        _CACHE[key] = fn
    return fn


def solve_shards(
    sharded: ShardedProblem, config: ShardSolveConfig | None = None
) -> ShardSolveResult:
    """Solve all shards as one batched pass; returns per-shard results."""
    cfg = config if config is not None else ShardSolveConfig()
    S = sharded.num_shards
    problems = place_shard_batch(sharded.problems)
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), S)
    x0 = problems.assignment0
    fn = _batched_solver(cfg)
    t0 = time.perf_counter()
    x, it, done, committed, obj = fn(problems, keys, x0)
    x = jax.block_until_ready(x)
    return ShardSolveResult(
        x=x,
        iterations=np.asarray(it),
        converged=np.asarray(done),
        committed=np.asarray(committed),
        objective=np.asarray(obj),
        solve_time_s=time.perf_counter() - t0,
        trace_count=shard_batch_trace_count(),
    )
