"""End-to-end sharded fleet pass: partition -> batched solve -> merge ->
coordinate.

``solve_fleet`` is the scale path the global solver cannot reach: S
subproblems solve as one vmapped executable (``shard.solve``), the merged
assignment is globally feasible by construction (``shard.partition``), and
the ``FleetCoordinator`` then vets saturation and grants priced boundary
migrations.  ``balance_fleet`` wraps the same pass in the controller's
``BalanceDecision`` contract — shed caps scale the served problem, a
``PlanOutlook`` steers only the solver, the PR-4 movement budget trims the
merged mapping (``enforce_cost_budget``), and the decision is evaluated
against the real collected problem exactly like ``Sptlb.balance``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import constraints, metrics
from repro.core.goals import objective as global_objective
from repro.core.hierarchy import enforce_cost_budget
from repro.core.levels import CoopConfig
from repro.core.planner import movement_cost_of
from repro.core.solver_local import SolveResult
from repro.core.sptlb import TIMEOUT_BUDGETS, BalanceDecision
from repro.shard.coordinator import SATURATION_FRAC, FleetCoordinator
from repro.shard.partition import (
    ShardedProblem,
    merge_assignment,
    partition_problem,
    plan_shards,
    stranded_apps,
)
from repro.shard.solve import ShardSolveConfig, ShardSolveResult, solve_shards


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs for one sharded rebalance pass."""

    num_shards: int = 8
    # Deterministic iteration budget via the paper's timeout knobs (same
    # TIMEOUT_BUDGETS table as the global engines).
    timeout_s: int = 30
    batch_moves: int = 16
    batch_quality: float = 0.9
    tol: float = 1e-7
    seed: int = 0
    # Coordinator: detect saturated shards and grant boundary migrations.
    rebalance: bool = True
    saturation: float = SATURATION_FRAC
    migration_frac: float = 0.05

    @property
    def max_iters(self) -> int:
        return TIMEOUT_BUDGETS.get(self.timeout_s, max(64, int(self.timeout_s * 8)))


@dataclasses.dataclass
class FleetDecision:
    """Outputs of one partition -> solve -> merge -> coordinate pass."""

    assignment: np.ndarray  # i32[N] merged global mapping
    objective: float  # global objective of the merged mapping
    shard_objectives: np.ndarray  # f32[S] per-shard (padded-problem) objectives
    stranded: int  # valid apps on infeasible tiers (must be 0)
    migrations: int  # coordinator-granted boundary moves
    saturated: int  # shards over the saturation threshold
    apps_per_s: float  # valid apps / end-to-end wall-clock
    coordinator_overhead_frac: float  # coordinator share of the pass
    timings: dict
    sharded: ShardedProblem
    solve: ShardSolveResult
    coordinator: FleetCoordinator


def solve_fleet(
    cluster,
    config: FleetConfig | None = None,
    *,
    move_cost: Optional[np.ndarray] = None,
    migration_budget: float = float("inf"),
    dirty_shards=None,
) -> FleetDecision:
    """One sharded rebalance pass over the cluster's current problem.

    ``dirty_shards`` (optional bool[S] mask or shard-index iterable) is the
    delta-solve path: only the named shards re-solve, the rest keep their
    incumbent mapping (``shard.solve``).  An all-dirty mask is bit-identical
    to the full pass.  For a *strict* subset the merged mapping carries a
    never-worse guard: the global objective is not shard-separable (the
    balance terms couple through the fleet mean), so a locally-improving
    delta that worsens the global objective reverts to the incumbent —
    observable as ``timings["delta_reverted"]``, never silent.
    """
    cfg = config if config is not None else FleetConfig()
    problem = cluster.problem
    t0 = time.perf_counter()
    plan = plan_shards(cluster, cfg.num_shards)
    sharded = partition_problem(problem, plan)
    t_partition = time.perf_counter()

    dirty = None
    if dirty_shards is not None:
        mask = np.zeros(plan.num_shards, bool)
        arr = np.asarray(dirty_shards)
        if arr.dtype == bool:
            mask[: arr.size] = arr[: plan.num_shards]
        else:
            ids = arr.astype(np.int64)
            mask[ids[(ids >= 0) & (ids < plan.num_shards)]] = True
        dirty = mask

    res = solve_shards(
        sharded,
        ShardSolveConfig(
            max_iters=cfg.max_iters,
            tol=cfg.tol,
            batch_moves=cfg.batch_moves,
            batch_quality=cfg.batch_quality,
            seed=cfg.seed,
        ),
        dirty=dirty,
    )
    t_solve = time.perf_counter()

    merged = merge_assignment(problem, sharded, res.x)
    delta_reverted = False
    if dirty is not None and not dirty.all():
        x0 = np.asarray(problem.assignment0)
        obj0 = float(global_objective(problem, jnp.asarray(x0)))
        obj1 = float(global_objective(problem, jnp.asarray(merged)))
        if obj1 > obj0 + 1e-9:
            merged = x0.copy()
            delta_reverted = True
    t_merge = time.perf_counter()

    coordinator = FleetCoordinator(
        cluster,
        num_shards=plan.num_shards,
        saturation=cfg.saturation,
        migration_frac=cfg.migration_frac,
        plan=plan,
    )
    moves: list = []
    if cfg.rebalance:
        moves = coordinator.plan_migrations(
            problem, merged, move_cost=move_cost, cost_budget=migration_budget
        )
        for a, t in moves:
            merged[a] = t
    t_coord = time.perf_counter()

    total_s = max(t_coord - t0, 1e-9)
    counters = coordinator.counters()
    timings = {
        "partition_s": t_partition - t0,
        "solve_s": t_solve - t_partition,
        "merge_s": t_merge - t_solve,
        "coordinator_s": t_coord - t_merge,
        "total_s": total_s,
        "solved_shards": int(res.solved.sum()) if res.solved.size else plan.num_shards,
        "delta_reverted": delta_reverted,
    }
    return FleetDecision(
        assignment=merged,
        objective=float(global_objective(problem, jnp.asarray(merged))),
        shard_objectives=res.objective,
        stranded=stranded_apps(problem, merged),
        migrations=len(moves),
        saturated=int(counters["saturated_shards"]),
        apps_per_s=float(int(np.asarray(problem.valid).sum()) / total_s),
        coordinator_overhead_frac=(t_coord - t_merge) / total_s,
        timings=timings,
        sharded=sharded,
        solve=res,
        coordinator=coordinator,
    )


def balance_fleet(
    cluster,
    *,
    fleet: FleetConfig | None = None,
    coop: CoopConfig | None = None,
    dirty_shards=None,
) -> BalanceDecision:
    """The sharded pass under the controller's ``BalanceDecision`` contract.

    Mirrors ``Sptlb.balance``'s served/steered split: an active shed plan
    scales what the fleet really serves (solve AND evaluation), a plan
    outlook only steers the solver, and the movement budget prices + trims
    the merged mapping via the same ``enforce_cost_budget`` the engines
    share.  ``cooperation`` is None — the coordinator, not the bus, vetted
    this pass (its counters ride ``solve.extra``).
    """
    cfg = fleet if fleet is not None else FleetConfig()
    knobs = coop if coop is not None else CoopConfig()
    base_cluster = cluster
    shed = knobs.shed
    if shed is not None and shed.active:
        base_cluster = dataclasses.replace(
            cluster, problem=shed.apply(cluster.problem)
        )
    solve_cluster = base_cluster
    plan = knobs.plan
    if plan is not None and plan.active:
        solve_cluster = dataclasses.replace(
            base_cluster, problem=plan.apply(base_cluster.problem)
        )

    t0 = time.perf_counter()
    budget = knobs.cost_budget if knobs.cost_budget is not None else float("inf")
    fd = solve_fleet(
        solve_cluster,
        cfg,
        move_cost=knobs.move_cost,
        migration_budget=budget,
        dirty_shards=dirty_shards,
    )
    problem = base_cluster.problem
    res = SolveResult(
        assignment=jnp.asarray(fd.assignment),
        iterations=int(max(int(fd.solve.iterations.max()), 1)),
        converged=bool(fd.solve.converged.all()),
        objective=float(global_objective(problem, jnp.asarray(fd.assignment))),
        num_moved=int(
            np.sum(fd.assignment != np.asarray(problem.assignment0))
        ),
        solve_time_s=fd.timings["total_s"],
        extra={
            "sharded": {
                "num_shards": fd.sharded.num_shards,
                "app_bucket": fd.sharded.app_bucket,
                "tier_bucket": fd.sharded.tier_bucket,
                "stranded": fd.stranded,
                "migrations": fd.migrations,
                "saturated": fd.saturated,
                "apps_per_s": fd.apps_per_s,
                "coordinator_overhead_frac": fd.coordinator_overhead_frac,
                **fd.timings,
            }
        },
    )
    timings: dict = {}
    res = enforce_cost_budget(
        base_cluster,
        res,
        np.asarray(base_cluster.problem.assignment0),
        knobs.move_cost,
        budget,
        (),
        timings,
    )
    t_solve = time.perf_counter()
    movement = timings.get(
        "movement_cost",
        movement_cost_of(res.assignment, problem.assignment0, knobs.move_cost),
    )
    decision = BalanceDecision(
        assignment=res.assignment,
        projected=metrics.projected_metrics(problem, res.assignment),
        violations=constraints.validate(problem, res.assignment),
        difference_to_balance=metrics.difference_to_balance(problem, res.assignment),
        network_p99_ms=metrics.network_p99_ms(cluster, res.assignment),
        solve=res,
        cooperation=None,
        movement_cost=movement,
        budget_trimmed=int(timings.get("budget_trimmed", 0)),
    )
    res.extra["balance_timings"] = {
        "solve_s": t_solve - t0,
        "evaluate_s": time.perf_counter() - t_solve,
    }
    return decision
