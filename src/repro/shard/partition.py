"""Fleet partitioning: split one global ``Problem`` into S shard subproblems.

The fleet problem is nearly decomposable by region/pod: tiers occupy
contiguous region arcs on the latency ring (``telemetry.generate_cluster``)
and apps live near their home tier, so partitioning *tiers* by ring anchor
and assigning every app to the shard that owns its ``assignment0`` tier
yields subproblems with no hard cross-shard coupling — each shard's solve
moves its apps only among its own tiers, which keeps the reassembled global
mapping feasible by construction (cross-shard migrations are a separate,
coordinator-granted step; see ``shard.coordinator``).

Uniform shapes make the S subproblems one executable: the app axis is
padded to a shared power-of-two bucket via the existing
``problem.pad_problem`` (inert valid=False rows) and the tier axis to the
widest shard with *inert tiers* — unit capacity, no SLO class allowed,
avoided by every app — which no valid app can ever be placed on.  The
stacked pytree then runs under one ``vmap`` (``shard.solve``).

``app_ids``/``tier_ids`` are the slot->global index maps (-1 for padding);
``merge_assignment`` scatters a batched local assignment back into a global
one.  Partition -> merge is a bijection over apps: every app appears in
exactly one shard slot, and merging the per-shard ``assignment0`` returns
the global ``assignment0`` bit-for-bit (property-tested in
tests/test_shard.py and fuzzed in tests/test_fuzz_scenarios.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import Problem, bucket_size, pad_problem

# Inert padded tiers carry a unit capacity so utilization fractions stay
# finite; nothing can be placed on them (slo_allowed False + avoid True).
INERT_CAPACITY = 1.0


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Static fleet partition: which shard owns each tier (and each app).

    An app belongs to the shard owning its ``assignment0`` tier, so every
    shard subproblem starts from a locally feasible incumbent mapping.
    """

    num_shards: int
    tier_shard: np.ndarray  # i32[T] owning shard per tier
    app_shard: np.ndarray  # i32[N] owning shard per app (home tier's shard)
    shard_tiers: tuple  # per shard: ascending global tier ids


def tier_anchors(tier_regions) -> np.ndarray:
    """Ring-arc start region per tier (the region/pod affinity key).

    Tiers occupy contiguous arcs on the region ring; the arc's first region
    orders tiers by locality, so contiguous groups of the anchor-sorted
    order share regions — the partition that minimizes cross-shard
    affinity.  Degenerate rows (all or no regions) anchor at 0.
    """
    tr = np.asarray(tier_regions, bool)
    T, _ = tr.shape
    anchors = np.zeros(T, np.int64)
    for t in range(T):
        row = tr[t]
        if row.all() or not row.any():
            continue
        starts = np.where(row & ~np.roll(row, 1))[0]
        anchors[t] = int(starts[0]) if starts.size else 0
    return anchors


def plan_shards(cluster, num_shards: int) -> ShardPlan:
    """Partition the fleet into ``num_shards`` region-affine tier groups.

    Tiers are sorted by ring anchor and split into S contiguous groups with
    balanced *valid-app* counts (each group keeps >= 1 tier; S clamps to
    [1, T]).  Apps follow their home tier.
    """
    p = cluster.problem
    T = p.num_tiers
    S = max(1, min(int(num_shards), T))
    anchors = tier_anchors(cluster.tier_regions)
    order = np.lexsort((np.arange(T), anchors))
    x0 = np.asarray(p.assignment0)
    valid = np.asarray(p.valid)
    counts = np.bincount(x0[valid], minlength=T).astype(np.float64)
    total = max(float(counts.sum()), 1.0)

    groups: list[list[int]] = [[] for _ in range(S)]
    g, cum = 0, 0.0
    for i, t in enumerate(order):
        tiers_left = T - i
        if groups[g] and g < S - 1 and (
            S - 1 - g >= tiers_left or cum >= (g + 1) * total / S
        ):
            g += 1
        groups[g].append(int(t))
        cum += counts[t]

    tier_shard = np.zeros(T, np.int32)
    for s, grp in enumerate(groups):
        tier_shard[grp] = s
    shard_tiers = tuple(np.sort(np.asarray(grp, np.int64)) for grp in groups)
    return ShardPlan(
        num_shards=S,
        tier_shard=tier_shard,
        app_shard=tier_shard[x0],
        shard_tiers=shard_tiers,
    )


@dataclasses.dataclass
class ShardedProblem:
    """S stacked subproblems sharing one shape, plus the slot->global maps."""

    plan: ShardPlan
    problems: Problem  # every leaf carries a leading [S] axis
    app_ids: np.ndarray  # i32[S, Nb] global app id per slot, -1 padding
    tier_ids: np.ndarray  # i32[S, Tb] global tier id per slot, -1 padding
    app_bucket: int
    tier_bucket: int

    @property
    def num_shards(self) -> int:
        return self.plan.num_shards


def partition_problem(
    problem: Problem, plan: ShardPlan, *, app_bucket: Optional[int] = None
) -> ShardedProblem:
    """Slice the global problem into the plan's shards and stack them.

    All shards share one (Nb, Tb) shape: Nb is the power-of-two bucket of
    the largest shard population (``pad_problem`` inert rows), Tb the
    widest shard's tier count (inert tiers).  The result solves under a
    single vmapped executable whatever the per-shard sizes.
    """
    S = plan.num_shards
    T = problem.num_tiers
    x0 = np.asarray(problem.assignment0)
    valid = np.asarray(problem.valid)
    demand = np.asarray(problem.demand)
    tasks = np.asarray(problem.tasks)
    slo = np.asarray(problem.slo)
    crit = np.asarray(problem.criticality)
    avoid = np.asarray(problem.avoid)
    capacity = np.asarray(problem.capacity)
    task_limit = np.asarray(problem.task_limit)
    ideal_frac = np.asarray(problem.ideal_frac)
    ideal_task = np.asarray(problem.ideal_task_frac)
    slo_allowed = np.asarray(problem.slo_allowed)
    R = capacity.shape[1]
    n_slo = slo_allowed.shape[1]

    app_lists = [np.where(plan.app_shard == s)[0] for s in range(S)]
    Tb = max(len(ts) for ts in plan.shard_tiers)
    widest = max(max(len(a) for a in app_lists), 1)
    Nb = bucket_size(widest) if app_bucket is None else int(app_bucket)
    if Nb < widest:
        raise ValueError(f"app_bucket {Nb} smaller than widest shard {widest}")

    app_ids = np.full((S, Nb), -1, np.int32)
    tier_ids = np.full((S, Tb), -1, np.int32)
    shards = []
    for s in range(S):
        tiers = plan.shard_tiers[s]
        Ts = len(tiers)
        apps = app_lists[s]
        inv = np.full(T, -1, np.int32)
        inv[tiers] = np.arange(Ts, dtype=np.int32)
        pad_t = Tb - Ts

        def pad_tiers(rows, fill):
            if not pad_t:
                return rows
            shape = (pad_t,) + rows.shape[1:]
            return np.concatenate([rows, np.full(shape, fill, rows.dtype)])

        extra = {}
        if problem.has_utility:
            extra = dict(
                util_knee=jnp.asarray(np.asarray(problem.util_knee)[apps]),
                util_slope=jnp.asarray(np.asarray(problem.util_slope)[apps]),
                util_weight=jnp.asarray(np.asarray(problem.util_weight)[apps]),
            )
        avoid_local = avoid[np.ix_(apps, tiers)]
        if pad_t:
            pad_cols = np.ones((len(apps), pad_t), bool)
            avoid_local = np.concatenate([avoid_local, pad_cols], axis=1)
        sub = dataclasses.replace(
            problem,
            demand=jnp.asarray(demand[apps]),
            tasks=jnp.asarray(tasks[apps]),
            slo=jnp.asarray(slo[apps]),
            criticality=jnp.asarray(crit[apps]),
            assignment0=jnp.asarray(inv[x0[apps]]),
            valid=jnp.asarray(valid[apps]),
            avoid=jnp.asarray(avoid_local),
            capacity=jnp.asarray(
                pad_tiers(capacity[tiers], np.float32(INERT_CAPACITY))
            ),
            task_limit=jnp.asarray(
                pad_tiers(task_limit[tiers], np.float32(INERT_CAPACITY))
            ),
            ideal_frac=jnp.asarray(pad_tiers(ideal_frac[tiers], np.float32(0.70))),
            ideal_task_frac=jnp.asarray(
                pad_tiers(ideal_task[tiers], np.float32(0.80))
            ),
            slo_allowed=jnp.asarray(
                pad_tiers(slo_allowed[tiers].reshape(Ts, n_slo), False)
            ),
            **extra,
        )
        shards.append(pad_problem(sub, Nb))
        app_ids[s, : len(apps)] = apps
        tier_ids[s, :Ts] = tiers

    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *shards)
    return ShardedProblem(
        plan=plan,
        problems=stacked,
        app_ids=app_ids,
        tier_ids=tier_ids,
        app_bucket=Nb,
        tier_bucket=Tb,
    )


def merge_assignment(problem: Problem, sharded: ShardedProblem, x) -> np.ndarray:
    """Reassemble a batched local assignment [S, Nb] into a global i32[N].

    Padding slots (app id -1) and any local tier outside the shard's real
    tier set (defensive; the inert-tier masks make it unreachable for valid
    apps) fall back to the incumbent ``assignment0``.
    """
    x = np.asarray(x)
    S = sharded.app_ids.shape[0]
    dest = sharded.tier_ids[np.arange(S)[:, None], x]
    mask = (sharded.app_ids >= 0) & (dest >= 0)
    merged = np.asarray(problem.assignment0).copy()
    merged[sharded.app_ids[mask]] = dest[mask]
    return merged


def stranded_apps(problem: Problem, assignment) -> int:
    """Valid apps parked on tiers their SLO/avoid feasibility forbids.

    Zero after every partition -> solve -> merge pass is a hard invariant
    (gated in CI via the ``shard_scale`` bench section).
    """
    feas = np.asarray(problem.feasible_mask())
    a = np.asarray(assignment)
    valid = np.asarray(problem.valid)
    return int(np.sum(valid & ~feas[np.arange(a.size), a]))
