from repro.distributed import sharding
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compress import GradCompressor
from repro.distributed.fault import (CapacityEvent, FaultInjector, Recovery,
                                     degrade, rebalance)

__all__ = ["sharding", "CheckpointManager", "GradCompressor", "CapacityEvent",
           "FaultInjector", "Recovery", "degrade", "rebalance"]
