from repro.distributed import sharding
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compress import GradCompressor
from repro.distributed.fault import (CapacityEvent, FaultInjector, Recovery,
                                     apply_event, degrade, rebalance,
                                     rebalance_after)

__all__ = ["sharding", "CheckpointManager", "GradCompressor", "CapacityEvent",
           "FaultInjector", "Recovery", "apply_event", "degrade", "rebalance",
           "rebalance_after"]
