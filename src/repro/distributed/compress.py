"""Gradient compression for the data-parallel all-reduce.

At 1000+ nodes the inter-pod (DCN) gradient reduction is the scaling wall;
standard mitigations implemented here:

  * bf16 compression — halve the wire for the all-reduce with an f32
    *error-feedback accumulator* (the rounding residual is carried into the
    next step, so compression introduces no bias drift),
  * int8 block-quantized compression — 4x wire: per-block (128) max-abs
    scale, symmetric int8 payload, same error feedback.

Both are pure pytree transforms around the optimizer step:

    comp = GradCompressor(mode="bf16")
    grads_c, state = comp.compress(grads, state)       # before all-reduce
    grads_d = comp.decompress(grads_c)                 # after all-reduce

In pjit the all-reduce is implicit (sharding propagation); compressing the
tensors that cross the data axis makes XLA move the compressed
representation.  `wire_bytes` reports the measured payload for EXPERIMENTS.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclasses.dataclass(frozen=True)
class GradCompressor:
    mode: Literal["none", "bf16", "int8"] = "bf16"

    # -- state -------------------------------------------------------------
    def init_state(self, grads: Any) -> Any:
        """Error-feedback residuals (f32, zero-initialized)."""
        if self.mode == "none":
            return None
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    # -- compress / decompress ----------------------------------------------
    def compress(self, grads: Any, state: Any) -> tuple[Any, Any]:
        """-> (compressed pytree, new error-feedback state)."""
        if self.mode == "none":
            return grads, state

        def one(g, e):
            gf = g.astype(jnp.float32) + e                 # apply feedback
            if self.mode == "bf16":
                c = gf.astype(jnp.bfloat16)
                err = gf - c.astype(jnp.float32)
                return c, err
            # int8 block quantization over the flattened tensor
            flat = gf.reshape(-1)
            pad = (-flat.shape[0]) % BLOCK
            fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
            scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
            scale = jnp.maximum(scale, 1e-12)
            q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
            deq = (q.astype(jnp.float32) * scale).reshape(-1)[
                :flat.shape[0]].reshape(gf.shape)
            return {"q": q, "scale": scale.astype(jnp.float32),
                    "shape": gf.shape}, gf - deq

        flat, treedef = jax.tree.flatten(grads)
        errs = treedef.flatten_up_to(state)
        outs = [one(g, e) for g, e in zip(flat, errs)]
        comp = treedef.unflatten([o[0] for o in outs])
        new_state = treedef.unflatten([o[1] for o in outs])
        return comp, new_state

    def decompress(self, comp: Any) -> Any:
        if self.mode == "none":
            return comp
        if self.mode == "bf16":
            return jax.tree.map(lambda c: c.astype(jnp.float32), comp)

        def one(c):
            n = 1
            for d in c["shape"]:
                n *= d
            deq = (c["q"].astype(jnp.float32) * c["scale"]).reshape(-1)[:n]
            return deq.reshape(c["shape"])
        return jax.tree.map(one, comp,
                            is_leaf=lambda x: isinstance(x, dict)
                            and "q" in x)

    # -- accounting ----------------------------------------------------------
    def wire_bytes(self, grads: Any) -> int:
        n = sum(int(g.size) for g in jax.tree.leaves(grads))
        return {"none": 4 * n, "bf16": 2 * n,
                "int8": n + 4 * (n // BLOCK + 1)}[self.mode]
