"""Checkpointing: atomic, versioned, async-capable save/restore.

Design for 1000+ nodes (see DESIGN.md):
  * per-host shard files — each host serializes only the addressable shards
    of its process (here: one process, full tree),
  * atomic publish — write to ``step_XXXX.tmp/``, fsync, rename; readers only
    ever see complete checkpoints,
  * async save — the train loop hands off a jax.device_get'd copy to a
    background thread so the TPUs keep stepping,
  * manifest with step/config/tree structure for restore-time validation,
  * retention policy (keep last K).

Serialization is msgpack + raw little-endian buffers (no pickle: checkpoint
files may cross trust boundaries on a shared filesystem).
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import msgpack
import numpy as np

_MANIFEST = "manifest.json"
_DATA = "shard_00000.msgpack"


def _flatten(tree) -> tuple[list[tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        named.append((key, np.asarray(leaf)))
    return named, treedef


def _pack_array(a: np.ndarray) -> dict:
    return {"dtype": a.dtype.str, "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])
                         ).reshape(d["shape"]).copy()


@dataclasses.dataclass
class CheckpointManager:
    directory: str | Path
    keep: int = 3

    def __post_init__(self):
        self.dir = Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: Optional[dict] = None) -> Path:
        """Snapshot (device_get) then serialize; async if blocking=False."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            return self._write(step, host_tree, extra or {})
        self.wait()                                # one in-flight save max
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}),
            daemon=True)
        self._thread.start()
        return self.dir / f"step_{step:08d}"

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: dict) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        named, _ = _flatten(host_tree)
        payload = {key: _pack_array(a) for key, a in named}
        (tmp / _DATA).write_bytes(msgpack.packb(payload, use_bin_type=True))
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: {"dtype": a.dtype.str, "shape": list(a.shape)}
                       for k, a in named},
            "extra": extra,
        }
        (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=2))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                           # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if p.is_dir() and not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> tuple[Any, int]:
        """Restore into the structure of ``template`` (validates shapes)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        payload = msgpack.unpackb((path / _DATA).read_bytes(), raw=False)
        named, treedef = _flatten(template)
        leaves = []
        for key, tmpl in named:
            if key not in payload:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            a = _unpack_array(payload[key])
            if list(a.shape) != list(tmpl.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {a.shape} vs "
                    f"template {tmpl.shape}")
            leaves.append(a.astype(tmpl.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, step
