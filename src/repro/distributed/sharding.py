"""PartitionSpec rules: params, optimizer state, batches, caches.

2D "megatron" layout on the ("data", "model") mesh, with an optional leading
"pod" axis that composes with "data" for batch/gradient parallelism:
  * column-parallel up-projections  (d_model -> hidden): shard out-dim
  * row-parallel   down-projections (hidden -> d_model): shard in-dim
  * embeddings / lm_head: vocab-sharded
  * MoE expert stacks: expert-parallel on axis 0 (the "model" axis)
  * everything else (norms, biases, scalars): replicated

Rules are *name-based* with a divisibility sanitizer: if a proposed sharded
dim is not divisible by the mesh axis size (e.g. kv-head counts smaller than
the model axis, odd vocab sizes), the axis is dropped for that dim —
correctness first, and the dry-run/roofline shows the cost honestly.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    """Batch-parallel axes: ("pod", "data") on multi-pod, else ("data",)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# Name-based rules: (names, core_rank, spec-for-the-core-dims).  A leaf may
# carry extra *leading* stack dims (scanned layer stacks, zamba's
# per-application out_proj stack); they are padded with None by rank, which
# makes the rules independent of whether a family stacks its layers.
_RULES: tuple[tuple[tuple[str, ...], int, tuple], ...] = (
    # MoE expert stacks [E, d, f] — expert-parallel on the model axis
    (("moe::w_gate", "moe::w_up", "moe::w_down"), 3, ("model", None, None)),
    # embeddings [V, d] — vocab-sharded
    (("embed",), 2, ("model", None)),
    # xlstm block-diagonal recurrent mats [H, Dh, Dh]
    (("r_z", "r_i", "r_f", "r_o"), 3, (None, "model", None)),
    # row-parallel (hidden -> d_model)
    (("wo", "w_down", "out_proj"), 2, ("model", None)),
    # column-parallel (d_model -> hidden)
    (("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "wk_up", "wv_up",
      "wkv_down", "w_gate_up", "w_in", "w_if", "wk_rope", "head", "lm_head",
      "conv_w", "pos_conv_w"), 2, (None, "model")),
    # replicated small projections
    (("router",), 2, (None, None)),
    # hidden-dim vectors (sharded with their producing projection)
    (("bq", "bk", "bv", "conv_b", "gate_norm"), 1, ("model",)),
    # per-head / d_model vectors and norms — replicated
    (("A_log", "D", "dt_bias", "kv_norm", "mask_embed", "norm", "ln1", "ln2",
      "ln1_post", "ln2_post", "final_norm", "out_norm", "scale", "bias",
      "ffn"), 1, (None,)),
)


def _match(path: str, last: str, names: tuple[str, ...]) -> bool:
    for name in names:
        if "::" in name:                 # context::leafname
            ctx, leafname = name.split("::")
            if ctx in path and last == leafname and "shared" not in path:
                return True
        elif last == name or (len(name) > 2 and name in last):
            return True
    return False


def param_spec(path_parts: tuple, leaf) -> P:
    path = "/".join(str(p) for p in path_parts)
    last = str(path_parts[-1]) if path_parts else ""
    ndim = leaf.ndim
    for names, core_rank, spec in _RULES:
        if _match(path, last, names):
            if ndim < core_rank:         # scalarized / degenerate leaf
                return P(*((None,) * ndim))
            lead = ndim - core_rank
            return P(*((None,) * lead + tuple(spec)))
    return P(*((None,) * ndim))


def sanitize(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, axis in enumerate(spec):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        total = int(np.prod([sizes[a] for a in axes]))
        if i < len(shape) and shape[i] % total == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def params_shardings(mesh: Mesh, params_shape) -> Any:
    """NamedShardings for a params pytree (of ShapeDtypeStructs or arrays)."""
    def one(path, leaf):
        spec = param_spec(tuple(p.key if hasattr(p, "key") else
                                getattr(p, "idx", p) for p in path), leaf)
        spec = sanitize(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_state_shardings(mesh: Mesh, opt_shape, *, zero1: bool = False) -> Any:
    """Optimizer state mirrors the params tree (count is replicated).

    ``zero1``: additionally shard each moment tensor over the data axis
    (ZeRO-1).  The optimizer math then runs data-sharded and XLA inserts a
    reduce-scatter(grads) / all-gather(updates) pair — trading a little
    wire for an 8x cut in f32 moment memory.  See EXPERIMENTS.md §Perf B3.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_size = sizes.get("data", 1)

    def one(path, leaf):
        keys = tuple(p.key if hasattr(p, "key") else getattr(p, "idx", p)
                     for p in path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = param_spec(keys, leaf)
        spec = sanitize(spec, leaf.shape, mesh)
        if zero1 and data_size > 1:
            entries = list(spec) + [None] * (leaf.ndim - len(spec))
            # shard the largest still-unsharded dim over "data"
            cands = [(leaf.shape[i], i) for i, a in enumerate(entries)
                     if a is None and leaf.shape[i] % data_size == 0]
            if cands:
                _, i = max(cands)
                entries[i] = "data"
                spec = P(*entries)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, opt_shape)


def batch_shardings(mesh: Mesh, batch_shape) -> Any:
    """Model inputs: batch dim over ("pod","data"), rest replicated."""
    dp = dp_axes(mesh)

    def one(leaf):
        spec = P(dp, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, sanitize(spec, leaf.shape, mesh))
    return jax.tree.map(one, batch_shape)


def _ambient_mesh() -> Optional[Mesh]:
    """The mesh in scope, if any (explicit-sharding or legacy context)."""
    try:                                   # explicit-sharding world
        m = jax.sharding.get_abstract_mesh()
        if getattr(m, "axis_names", None):
            return m
    except Exception:
        pass
    try:                                   # legacy `with mesh:` context
        m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if getattr(m, "axis_names", None):
            return m
    except Exception:
        pass
    return None


def place_shard_batch(tree: Any) -> Any:
    """Place a stacked [S, ...] shard batch over the mesh's batch axes.

    The sharded fleet solver stacks S subproblems on a leading axis and
    vmaps over it — embarrassingly parallel, so the leading axis shards
    over ("pod","data") exactly like a model input batch and each device
    solves its slice of the shards.  Correctness-first like everything
    here: without an ambient mesh (single-host CPU runs, tests) or when S
    does not divide the axis, leaves pass through untouched.
    """
    mesh = _ambient_mesh()
    try:
        multi = mesh is not None and int(np.prod(mesh.devices.shape)) > 1
    except Exception:                      # abstract mesh: no devices array
        multi = False
    if not multi:
        return tree
    dp = dp_axes(mesh)

    def one(leaf):
        if getattr(leaf, "ndim", 0) < 1:
            return leaf
        spec = sanitize(P(dp, *([None] * (leaf.ndim - 1))), leaf.shape, mesh)
        try:
            return jax.device_put(leaf, NamedSharding(mesh, spec))
        except Exception:
            return leaf
    return jax.tree.map(one, tree)


def cache_shardings(mesh: Mesh, cache_shape, *, kv_shard: str = "heads") -> Any:
    """KV/state caches: batch over dp axes, heads/feature over "model".

    Handles the layouts used by the models:
      [L, B, S, KV, D] stacked attention kv, [B, S, KV, D] unstacked,
      [B, S, lora] MLA, [L, B, H, P, N] mamba states, xlstm states, scalars.

    ``kv_shard``:
      "heads" — kv-head dim on "model" (baseline; silently replicates when
                the head count does not divide the axis),
      "seq"   — sequence dim on "model" (flash-decoding style: every chip
                owns a slice of the context; softmax combines via small
                partial reductions).  See EXPERIMENTS.md §Perf A.
      "auto"  — heads when the kv-head count divides the model axis
                (measured best there), else seq (11-12x better when it
                doesn't).  The production default for launch/serve paths.
    """
    dp = dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)

    def one(path, leaf):
        path_s = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path)
        nd = leaf.ndim
        if nd == 0:
            spec = P()
        elif "pos" in path_s:
            spec = P()
        else:
            # Identify the batch dim: stacked caches have it second.
            stacked = ("layers" in path_s or "mamba" in path_s
                       or "attn_k" in path_s or "attn_v" in path_s)
            spec_list: list = [None] * nd
            b_dim = 1 if (stacked and nd >= 2) else 0
            spec_list[b_dim] = dp
            # Shard the "model"-parallel dim where one exists.
            is_attn_kv = (("k" in path_s.split("/")[-1]
                           or "v" in path_s.split("/")[-1])
                          and nd >= 4 and "ssm" not in path_s
                          and "conv" not in path_s)
            if "c_kv" in path_s:
                spec_list[-1] = "model"              # MLA latent dim
            elif "k_pe" in path_s:
                pass                                 # tiny; replicate
            elif "ssm" in path_s and nd >= 3:
                spec_list[b_dim + 1] = "model"       # mamba heads
            elif is_attn_kv and (
                    kv_shard == "seq"
                    or (kv_shard == "auto"
                        and leaf.shape[nd - 2] % model_size != 0)):
                spec_list[b_dim + 1] = "model"       # sequence slice
            elif nd >= 4:
                spec_list[nd - 2] = "model"          # kv heads (baseline)
            spec = P(*spec_list)
        return NamedSharding(mesh, sanitize(spec, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def logits_sharding(mesh: Mesh, shape: Optional[tuple] = None,
                    ndim: int = 3) -> NamedSharding:
    dp = dp_axes(mesh)
    spec = P(dp, *([None] * (ndim - 2)), "model")
    if shape is not None:
        spec = sanitize(spec, shape, mesh)
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# in-model activation constraints (MaxText-style explicit activation sharding)
# ---------------------------------------------------------------------------

def constrain(x, dims: tuple):
    """with_sharding_constraint using logical dims, safe without a mesh.

    dims entries: "dp" (batch axes), "model", or None.  Axes that do not
    exist in the ambient mesh, or that do not divide the dim, are dropped —
    the same correctness-first policy as ``sanitize``.
    """
    import jax

    mesh = None
    try:                                   # explicit-sharding world
        m = jax.sharding.get_abstract_mesh()
        if getattr(m, "axis_names", None):
            mesh = m
    except Exception:
        pass
    if mesh is None:
        try:                               # legacy `with mesh:` context
            m = jax.interpreters.pxla.thread_resources.env.physical_mesh
            if getattr(m, "axis_names", None):
                mesh = m
        except Exception:
            pass
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names,
                     getattr(mesh, "axis_sizes", None)
                     or mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in names)

    spec = []
    for d, dim in zip(dims, x.shape):
        if d is None:
            spec.append(None)
            continue
        if d == "dp":
            axes = dp
        elif d == "dpm":                   # batch over data AND model axes
            axes = dp + (("model",) if "model" in names else ())
        else:
            axes = (d,) if d in names else ()
        total = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if axes and dim % total == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
