"""Fault tolerance & elasticity — where the framework meets the paper.

The cluster is organized exactly like the paper's tiers: pod slices with
capacity headroom in three dimensions (compute FLOP/s, HBM bytes, stream-task
slots).  Failures and stragglers are *capacity events*:

  * host failure      -> the tier's capacity shrinks; jobs whose demand no
                         longer fits must move.  SPTLB re-solves with the
                         movement-minimizing objective (paper goal 8) so only
                         the displaced work moves (checkpoint/restore cost
                         is the "downtime" the paper's task-count movement
                         cost models).
  * straggler host    -> detected from step-time telemetry; modeled as a
                         fractional capacity reduction, which biases SPTLB
                         away from the slow tier without hard eviction.
  * elastic scale-up  -> new hosts extend a tier's capacity; rebalancing is
                         again bounded by the movement budget, so scale-up
                         does not thrash placements.

``FaultInjector`` drives simulated events for tests/examples; ``Recovery``
implements the restart path: restore latest checkpoint -> rebuild mesh over
the surviving devices -> re-route streams via SPTLB.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import ClusterState, CoopConfig, Sptlb
from repro.core.solver_local import SolveResult


@dataclasses.dataclass
class CapacityEvent:
    kind: str                  # "host_failure" | "straggler" | "scale_up"
    tier: int
    fraction: float            # capacity delta as a fraction of the tier
    step: int = 0


class FaultInjector:
    """Deterministic, seeded failure scenario generator."""

    def __init__(self, num_tiers: int, seed: int = 0,
                 failure_rate: float = 0.02, straggler_rate: float = 0.05):
        self.rng = np.random.default_rng(seed)
        self.num_tiers = num_tiers
        self.failure_rate = failure_rate
        self.straggler_rate = straggler_rate

    def sample(self, step: int) -> list[CapacityEvent]:
        events = []
        if self.rng.random() < self.failure_rate:
            events.append(CapacityEvent(
                "host_failure", int(self.rng.integers(self.num_tiers)),
                fraction=float(self.rng.uniform(0.05, 0.25)), step=step))
        if self.rng.random() < self.straggler_rate:
            events.append(CapacityEvent(
                "straggler", int(self.rng.integers(self.num_tiers)),
                fraction=float(self.rng.uniform(0.05, 0.15)), step=step))
        return events


def apply_event(cluster: ClusterState, event: CapacityEvent) -> ClusterState:
    """Shrink/extend tier capacity (and host count for hard failures)."""
    problem = cluster.problem
    cap = np.asarray(problem.capacity).copy()
    klim = np.asarray(problem.task_limit).copy()
    hosts = cluster.hosts_per_tier.copy()
    t = event.tier
    if event.kind in ("host_failure", "straggler"):
        scale = 1.0 - event.fraction
    else:                                           # scale_up
        scale = 1.0 + event.fraction
    cap[t] *= scale
    klim[t] *= scale
    if event.kind in ("host_failure", "scale_up"):
        hosts[t] = max(1, int(round(hosts[t] * scale)))

    new_problem = dataclasses.replace(
        problem,
        capacity=jnp.asarray(cap),
        task_limit=jnp.asarray(klim))
    return dataclasses.replace(cluster, problem=new_problem,
                               hosts_per_tier=hosts)


def rebalance_after(cluster: ClusterState, event: CapacityEvent,
                    *, engine: str = "local",
                    variant: str = "manual_cnst") -> tuple[ClusterState, SolveResult]:
    """The paper's loop, triggered by infrastructure: capacity change ->
    SPTLB re-solve (movement-bounded) -> new app->tier mapping."""
    degraded = apply_event(cluster, event)
    decision = Sptlb(degraded).balance(
        engine, config=CoopConfig(variant=variant))
    new_problem = degraded.problem.with_assignment0(
        jnp.asarray(decision.assignment))
    rebalanced = dataclasses.replace(degraded, problem=new_problem)
    return rebalanced, decision


@dataclasses.dataclass
class Recovery:
    """Checkpoint-restart path used by launch/train.py."""

    ckpt_manager: object                  # distributed.checkpoint.CheckpointManager
    rebuild_mesh: Callable[[], object]    # () -> Mesh over surviving devices
    on_rebalance: Optional[Callable] = None

    def recover(self, template_state):
        """-> (state, step): restore the latest complete checkpoint."""
        state, step = self.ckpt_manager.restore(template_state)
        mesh = self.rebuild_mesh()
        if self.on_rebalance is not None:
            self.on_rebalance(mesh)
        return state, step, mesh
