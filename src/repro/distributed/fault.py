"""Fault tolerance & elasticity — where the framework meets the paper.

The cluster is organized exactly like the paper's tiers: pod slices with
capacity headroom in three dimensions (compute FLOP/s, HBM bytes, stream-task
slots).  Failures and stragglers are *capacity events*:

  * host failure      -> the tier's capacity shrinks; jobs whose demand no
                         longer fits must move.  SPTLB re-solves with the
                         movement-minimizing objective (paper goal 8) so only
                         the displaced work moves (checkpoint/restore cost
                         is the "downtime" the paper's task-count movement
                         cost models).
  * straggler host    -> detected from step-time telemetry; modeled as a
                         fractional capacity reduction, which biases SPTLB
                         away from the slow tier without hard eviction.
  * elastic scale-up  -> new hosts extend a tier's capacity; rebalancing is
                         again bounded by the movement budget, so scale-up
                         does not thrash placements.

Capacity events are **one representation away from the simulator**: every
``CapacityEvent`` converts (``to_timed``) into a ``sim.events.CapacityScale``,
and all cluster rewrites go through the sim's knob/refresh contract
(``sim.events.FleetState.refresh``) — one code path whether a tier degrades
inside a fleet trajectory or under the training loop's one-shot recovery.
Announced events (planned scale-ups, telemetry-detected stragglers) also
publish ``core.planner.Advisory`` records, so a ``BalanceController`` fed by
``FaultInjector.schedule`` anticipates them exactly like declared
maintenance; hard host failures stay surprises.

``FaultInjector`` drives simulated events for tests/examples; ``Recovery``
implements the restart path: restore latest checkpoint -> rebuild mesh over
the surviving devices -> re-route streams via SPTLB.

"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import ClusterState, CoopConfig, Sptlb
from repro.core.solver_local import SolveResult
from repro.sim.events import CapacityScale, FleetState, TimedEvent


@dataclasses.dataclass
class CapacityEvent:
    kind: str                  # "host_failure" | "straggler" | "scale_up"
    tier: int
    fraction: float            # capacity delta as a fraction of the tier
    step: int = 0

    @property
    def factor(self) -> float:
        """Multiplicative capacity factor this event applies to its tier."""
        if self.kind == "scale_up":
            return 1.0 + self.fraction
        return 1.0 - self.fraction

    def to_timed(self, *, base_scale: float = 1.0) -> CapacityScale:
        """The ``sim.events.CapacityScale`` equivalent of this event.

        ``CapacityScale.scale`` is absolute relative to as-built, so stacked
        events on one tier must compose: pass the tier's standing scale as
        ``base_scale`` (``FaultInjector.schedule`` does this bookkeeping).

        Scale-ups are planned elasticity and stragglers are detected from
        step-time telemetry before any re-solve runs, so both are
        ``announced`` (they declare a ``core.planner.Advisory``); a hard
        host failure is a surprise and declares nothing.
        """
        return CapacityScale(at=self.step, tier=self.tier,
                             scale=float(base_scale) * self.factor,
                             announced=self.kind != "host_failure")


class FaultInjector:
    """Deterministic, seeded failure scenario generator."""

    def __init__(self, num_tiers: int, seed: int = 0,
                 failure_rate: float = 0.02, straggler_rate: float = 0.05):
        self.rng = np.random.default_rng(seed)
        self.num_tiers = num_tiers
        self.failure_rate = failure_rate
        self.straggler_rate = straggler_rate

    def sample(self, step: int) -> list[CapacityEvent]:
        events = []
        if self.rng.random() < self.failure_rate:
            events.append(CapacityEvent(
                "host_failure", int(self.rng.integers(self.num_tiers)),
                fraction=float(self.rng.uniform(0.05, 0.25)), step=step))
        if self.rng.random() < self.straggler_rate:
            events.append(CapacityEvent(
                "straggler", int(self.rng.integers(self.num_tiers)),
                fraction=float(self.rng.uniform(0.05, 0.15)), step=step))
        return events

    def schedule(self, steps: int) -> tuple[tuple[CapacityScale, ...], tuple]:
        """Sample ``steps`` ticks and emit the unified representation:
        ``(timed_events, advisories)``.

        ``timed_events`` are ``sim.events.CapacityScale`` with per-tier
        scales composed cumulatively (two 20% failures on one tier leave it
        at 0.64x as-built), ready for a ``sim.Scenario``'s event list.
        ``advisories`` are the announced subset's ``core.planner.Advisory``
        records, ready for an ``AdvisoryBatch`` event (``ingest``) — the same
        channel declared maintenance rides (the PR-4 anticipation path).
        """
        scale = np.ones(self.num_tiers)
        timed: list[CapacityScale] = []
        for step in range(steps):
            for ev in self.sample(step):
                t = ev.to_timed(base_scale=float(scale[ev.tier]))
                scale[ev.tier] = t.scale
                timed.append(t)
        advisories = tuple(
            a for a in (t.declare() for t in timed) if a is not None)
        return tuple(timed), advisories


def _control_fleet(cluster: ClusterState) -> FleetState:
    """A workload-less ``FleetState`` over a standalone cluster: just enough
    world for the sim knob/refresh contract to rewrite capacity with."""
    problem = cluster.problem
    return FleetState(
        cluster=cluster, wl=None, wl_cfg=None,
        base_capacity=np.asarray(problem.capacity).copy(),
        base_task_limit=np.asarray(problem.task_limit).copy(),
        base_hosts=cluster.hosts_per_tier.copy(),
        base_slo_allowed=np.asarray(problem.slo_allowed).copy(),
        base_latency=cluster.region_latency.copy(),
        tier_scale=np.ones(problem.num_tiers, np.float32))


def degrade(cluster: ClusterState, *events: TimedEvent) -> ClusterState:
    """Apply cluster-plane timed events (``CapacityScale``, ``RegionOutage``,
    ``RegionRestore``) to a standalone cluster through the sim's
    knob/refresh contract.  Workload-plane events (flash crowds, churn)
    need a real fleet — the ``wl=None`` sentinel makes them fail fast."""
    fleet = _control_fleet(cluster)
    for ev in sorted(events, key=lambda e: e.at):
        ev.apply(fleet)
    return fleet.cluster


def rebalance(cluster: ClusterState, *events,
              engine: str = "local",
              config: Optional[CoopConfig] = None,
              ) -> tuple[ClusterState, SolveResult]:
    """The paper's loop, triggered by infrastructure: capacity change ->
    SPTLB re-solve (movement-bounded) -> new app->tier mapping.

    Accepts ``CapacityEvent``s (converted via ``to_timed``) and/or timed
    sim events directly; the degraded cluster is produced by ``degrade``,
    so this is the same rewrite the fleet simulator performs.
    """
    timed = tuple(e.to_timed() if isinstance(e, CapacityEvent) else e
                  for e in events)
    degraded = degrade(cluster, *timed)
    decision = Sptlb(degraded).balance(engine, config=config or CoopConfig())
    new_problem = degraded.problem.with_assignment0(
        jnp.asarray(decision.assignment))
    rebalanced = dataclasses.replace(degraded, problem=new_problem)
    return rebalanced, decision



@dataclasses.dataclass
class Recovery:
    """Checkpoint-restart path used by launch/train.py."""

    ckpt_manager: object                  # distributed.checkpoint.CheckpointManager
    rebuild_mesh: Callable[[], object]    # () -> Mesh over surviving devices
    on_rebalance: Optional[Callable] = None

    def recover(self, template_state):
        """-> (state, step): restore the latest complete checkpoint."""
        state, step = self.ckpt_manager.restore(template_state)
        mesh = self.rebuild_mesh()
        if self.on_rebalance is not None:
            self.on_rebalance(mesh)
        return state, step, mesh
