"""Maintenance-window anticipation and movement pricing (proactive §3.3).

The paper's thesis is that stream schedulers must become "more robust and
proactive to application load" — yet a controller that only reads current
telemetry is condemned to react *after* a maintenance drain or a region
outage has already stranded incumbents.  Real fleets know better: drains
are scheduled, outage windows are announced.  This module is the planning
half of the controller:

  * **Advisory** — a declared future event on the fleet's advisory channel.
    Scenarios publish the events that are known in advance (the tier_drain
    capacity staircase, the region_outage window — ``sim.events`` converts
    them via ``TimedEvent.declare``); surprises (flash crowds, churn
    re-rates) are never declared.
  * **MaintenancePlanner** — consumes the advisory schedule and, per tick,
    derives the *planning problem*: time-phased capacity targets (the worst
    declared capacity of each tier within the lookahead horizon) and tier
    eligibility (will-be-draining tiers and tiers about to lose a region
    are folded into the §3.4 premask as avoid columns).  The solver then
    evacuates ahead of the first ramp step through the existing
    cooperation path — anticipation reuses the reactive machinery, it only
    changes the problem the solver sees.
  * **move_costs** — Madsen-style reconfiguration pricing (arXiv
    1602.03770): moving an app costs a fixed detach/attach overhead plus a
    term proportional to its demand (state that must drain and re-warm at
    the destination), normalized so an average live app costs 1.0.  The
    controller charges every applied move against a trajectory-level
    downtime budget (Henge's intent-driven tradeoff: SLO recovered per
    unit of reconfiguration spent, arXiv 1802.00082).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.levels import RELAX_LATENCY_FACTOR
from repro.core.problem import Problem

# Advisory kinds.
CAPACITY = "capacity"
OUTAGE = "outage"
RESTORE = "restore"
# Demand-side advisory.  Two producers share the kind: load-shed cap
# transitions (core.shedding; ``scale`` <= 1 is the app's new delivery cap,
# published for audit/observability and ignored by the planner), and
# declared flash crowds (``sim.events.FlashCrowd(announced=True)``;
# ``scale`` > 1 is the offered-demand factor, which ``outlook`` phases into
# capacity headroom the way maintenance phases capacity out).
SHED = "shed"

# Fixed detach/attach overhead of one move, in units of the mean live app's
# demand-proportional cost (the Madsen reconfiguration curve's intercept).
MOVE_COST_BASE = 0.25


@dataclasses.dataclass(frozen=True)
class Advisory:
    """One declared future event on the advisory channel.

    ``kind`` is one of ``CAPACITY`` (a tier's capacity scale will be set to
    ``scale``, relative to as-built, at tick ``at``), ``OUTAGE`` / ``RESTORE``
    (a region goes dark / comes back at tick ``at``).
    """

    at: int
    kind: str
    tier: int = -1
    region: int = -1
    scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    # Lookahead window in ticks: the planner acts on advisories within
    # (now, now + horizon].  Wider horizons evacuate earlier but spend
    # movement budget sooner; 0 disables anticipation.
    horizon: int = 12
    # A tier whose declared capacity falls below this fraction of its
    # current capacity inside the horizon is premasked (no new placements).
    drain_threshold: float = 0.5
    # Floor on declared capacity scales, mirroring sim.events.MIN_TIER_SCALE:
    # utilization fractions divide by capacity, so targets never reach 0.
    scale_floor: float = 0.02
    # Maintenance placement mode: when a tier's declared *absolute* scale
    # inside the horizon falls below ``deep_drain_threshold``, residents
    # whose every SLO-eligible alternative breaches the region latency
    # budget would otherwise be unmovable and ride the drain into
    # over-capacity.  For those evacuations the region scheduler grants a
    # relaxed budget (``x relax_latency_factor``) — Madsen-style bounded
    # degradation during a declared window: locality is a priced
    # preference, the SLO class table stays a hard constraint, and the
    # refill after restore sends the apps home again.
    deep_drain_threshold: float = 0.25
    relax_latency_factor: float = RELAX_LATENCY_FACTOR


@dataclasses.dataclass(frozen=True)
class PlanOutlook:
    """The planner's per-tick view of the declared horizon.

    ``tier_factor`` is the worst declared capacity of each tier within the
    horizon as a fraction of its *current* capacity (<= 1: the plan only
    ever tightens — restores are left to the reactive path, which refills
    for free once capacity is actually back).  ``apply`` turns a problem
    into the planning problem the solver should balance against.
    """

    now: int
    horizon: int
    tier_factor: np.ndarray  # f32[T] future/current capacity, <= 1
    avoid_tiers: np.ndarray  # bool[T] premask: no new placements
    slo_off_tiers: np.ndarray  # bool[T] will lose SLO eligibility (outage)
    pending: int  # advisories within the horizon
    # Maintenance placement mode: tiers in a declared deep drain whose
    # residents may evacuate under a relaxed region latency budget.
    relax_home_tiers: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, bool)
    )
    relax_latency_factor: float = RELAX_LATENCY_FACTOR

    @property
    def active(self) -> bool:
        return bool(
            self.avoid_tiers.any()
            or (self.tier_factor < 1.0 - 1e-3).any()
            or self.relax_home_tiers.any()
        )

    def apply(self, problem: Problem) -> Problem:
        """The planning problem: declared capacity targets + eligibility.

        Capacity and task limits are scaled to their declared horizon
        minimum, so the §3.2.1 goal terms start evacuating *now* what the
        staircase will strand later; tiers about to lose a region also lose
        SLO eligibility.  ``avoid_tiers`` become avoid columns with the home
        column left open (staying is always legal — the §3.4 premask
        convention): anticipation steers new placements away and prices
        evacuation, it never forces an infeasible mapping.
        """
        if not self.active:
            return problem
        factor = jnp.asarray(self.tier_factor, problem.capacity.dtype)
        slo_allowed = jnp.where(
            jnp.asarray(self.slo_off_tiers)[:, None], False, problem.slo_allowed
        )
        planned = dataclasses.replace(
            problem,
            capacity=problem.capacity * factor[:, None],
            task_limit=problem.task_limit * factor,
            slo_allowed=slo_allowed,
        )
        if self.avoid_tiers.any():
            x0 = np.asarray(problem.assignment0)
            extra = np.broadcast_to(
                self.avoid_tiers[None, :], (x0.shape[0], self.avoid_tiers.shape[0])
            ).copy()
            extra[np.arange(x0.shape[0]), x0] = False
            planned = planned.with_avoid(jnp.asarray(extra))
        return planned


class MaintenancePlanner:
    """Derives per-tick capacity/eligibility targets from declared events.

    The advisory schedule is static for a trajectory (that is what
    "declared in advance" means); ``outlook(now, cluster)`` is cheap pure
    numpy, so the controller calls it every tick.
    """

    def __init__(self, advisories, config: PlannerConfig = PlannerConfig()):
        self.config = config
        self.advisories = tuple(sorted(advisories, key=lambda a: (a.at, a.kind)))

    def declared_scale(self, tier: int, tick: int) -> float:
        """The declared capacity scale of ``tier`` at ``tick`` (last
        capacity advisory at or before it; as-built 1.0 before any)."""
        scale = 1.0
        for a in self.advisories:
            if a.at > tick:
                break
            if a.kind == CAPACITY and a.tier == tier:
                scale = a.scale
        return scale

    def declared_down(self, tick: int) -> set:
        """Regions declared down at ``tick`` per the advisory schedule."""
        down = set()
        for a in self.advisories:
            if a.at > tick:
                break
            if a.kind == OUTAGE:
                down.add(a.region)
            elif a.kind == RESTORE:
                down.discard(a.region)
        return down

    def outlook(self, now: int, cluster) -> PlanOutlook:
        cfg = self.config
        tier_regions = np.asarray(cluster.tier_regions, bool)
        T = tier_regions.shape[0]
        factor = np.ones(T, np.float32)
        times = sorted({a.at for a in self.advisories if now < a.at <= now + cfg.horizon})
        pending = sum(1 for a in self.advisories if now < a.at <= now + cfg.horizon)

        # Capacity staircases: the declared scale is piecewise constant and
        # changes only at advisory times, so only those times matter.
        # Targets are *time-phased*: each declared step is approached
        # linearly over the horizon, reaching the declared scale as the
        # step fires.  Jumping straight to the horizon minimum evacuates
        # everything the moment a drain is declared — which shoves the
        # receiving tiers over ideal while the drained tier's real capacity
        # is still whole; pacing completes the evacuation just in time
        # instead.  Relative to the *current* declared scale — the live
        # cluster already reflects fired events.
        relax = np.zeros(T, bool)
        for tier in {a.tier for a in self.advisories if a.kind == CAPACITY}:
            s_now = max(self.declared_scale(tier, now), cfg.scale_floor)
            target = s_now
            for u in times:
                s_u = max(self.declared_scale(tier, u), cfg.scale_floor)
                if s_u >= s_now:
                    continue
                # weight -> 1 as the step arrives, ~1/horizon when it has
                # just entered the window.
                weight = (cfg.horizon - (u - now) + 1) / cfg.horizon
                target = min(target, s_now + (s_u - s_now) * weight)
            factor[tier] = min(1.0, target / s_now)
            # Maintenance placement mode holds for the whole deep-drain
            # window: armed when a declared scale inside the horizon drops
            # below the threshold, and kept on mid-drain (current declared
            # scale still deep) until the schedule climbs back — even when
            # no advisory happens to fall inside the lookahead window.
            deep = cfg.deep_drain_threshold
            if s_now < deep or any(
                self.declared_scale(tier, u) < deep for u in times
            ):
                relax[tier] = True

        # Declared outages: tiers overlapping a region that goes dark inside
        # the horizon lose that region's capacity share (the same live-share
        # formula FleetState.refresh applies when the event fires) and their
        # SLO eligibility.  Regions already down are the reactive path's
        # problem — the live cluster reflects them.
        down_now = self.declared_down(now)
        down_all = set(down_now)
        first_down_at: dict = {}
        for u in times:
            for r in self.declared_down(u) - down_all:
                first_down_at[r] = u
            down_all |= self.declared_down(u)
        future_down = down_all - down_now
        slo_off = np.zeros(T, bool)
        if future_down:
            mask_now = np.zeros(tier_regions.shape[1], bool)
            mask_now[list(down_now)] = True
            mask_all = np.zeros(tier_regions.shape[1], bool)
            mask_all[list(down_all)] = True
            total = np.maximum(1, tier_regions.sum(axis=1))
            share_now = (tier_regions & ~mask_now).sum(axis=1) / total
            share_all = (tier_regions & ~mask_all).sum(axis=1) / total
            affected = (tier_regions[:, list(future_down)]).any(axis=1)
            ratio = share_all / np.maximum(share_now, 1e-9)
            # Same time-phasing as capacity steps, paced to the earliest
            # declared outage inside the window.
            soonest = min(first_down_at.values())
            weight = (cfg.horizon - (soonest - now) + 1) / cfg.horizon
            ratio = 1.0 + (ratio - 1.0) * weight
            factor = factor * np.where(affected, ratio, 1.0).astype(np.float32)
            slo_off = affected

        factor = np.clip(factor, cfg.scale_floor, 1.0).astype(np.float32)
        # Draining is a *supply* signal: only maintenance/outage factors
        # decide which tiers to evacuate, before any demand headroom below.
        avoid = slo_off | (factor < cfg.drain_threshold)

        # Demand-side advisories: a declared flash crowd (SHED advisory
        # with an offered-demand factor > 1, ``sim.events.FlashCrowd``
        # with ``announced=True``) phases capacity *headroom* in exactly
        # like maintenance phases capacity out — the solver packs toward a
        # tighter target as the crowd approaches, so the spike lands on
        # slack instead of forcing a reactive scramble.  Shed-cap
        # transitions published by the load shedder reuse the same kind
        # with scale <= 1 and stay audit-only, as before.
        for a in self.advisories:
            if a.kind != SHED or a.scale <= 1.0 or not now < a.at <= now + cfg.horizon:
                continue
            weight = (cfg.horizon - (a.at - now) + 1) / cfg.horizon
            surge = 1.0 + (a.scale - 1.0) * weight
            if a.tier >= 0:
                factor[a.tier] = factor[a.tier] / surge
            else:
                factor = (factor / surge).astype(np.float32)
        factor = np.clip(factor, cfg.scale_floor, 1.0).astype(np.float32)
        return PlanOutlook(
            now=now,
            horizon=cfg.horizon,
            tier_factor=factor,
            avoid_tiers=avoid,
            slo_off_tiers=slo_off,
            pending=pending,
            relax_home_tiers=relax,
            relax_latency_factor=cfg.relax_latency_factor,
        )


def move_costs(problem: Problem) -> np.ndarray:
    """Per-app reconfiguration cost, f32[N] (Madsen-style pricing).

    ``base + demand / mean_live_demand``, normalized so the mean live app
    costs exactly 1.0 — a budget of ``k`` buys about ``k`` average moves.
    Invalid (standby / padding) rows cost 0: they carry no state and the
    solvers cannot move them anyway.
    """
    demand = np.asarray(problem.demand)
    valid = np.asarray(problem.valid, bool)
    load = demand.sum(axis=1)
    live = load[valid]
    mean = float(live.mean()) if live.size else 1.0
    rel = load / max(mean, 1e-9)
    cost = (MOVE_COST_BASE + rel) / (1.0 + MOVE_COST_BASE)
    return np.where(valid, cost, 0.0).astype(np.float32)


def movement_cost_of(assignment, assignment0, move_cost=None) -> float:
    """Total reconfiguration cost of a mapping vs the incumbent placement.

    With ``move_cost=None`` every move costs 1 (a plain move count), so
    callers without a pricing model still get a meaningful scalar.
    """
    moved = np.asarray(assignment) != np.asarray(assignment0)
    if move_cost is None:
        return float(np.sum(moved))
    return float(np.asarray(move_cost)[moved].sum())
