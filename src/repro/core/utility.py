"""Per-app utility curves over delivered capacity (Henge, arXiv 1802.00082).

The paper's binary SLO-class table can only *record* an overload (an app on
an ineligible or saturated tier ticks a violation); it cannot trade one
app's degradation against another's.  Henge's insight is to give every app
a monotone utility curve over its **delivered capacity fraction** d — the
share of its demanded capacity it actually receives — and let the
controller maximize *fleet* utility.  Overload then resolves by shedding
the cheapest utility first instead of stranding whoever happens to sit on
the saturated tier.

The curve family here is piecewise linear with a knee at the SLO point:

    u(d) = u_max * clip(1 - slope * max(0, knee - d), 0, 1)

* flat at ``u_max`` for d >= knee (meeting the SLO earns full utility;
  over-delivery earns nothing — monotone, never decreasing),
* linear loss below the knee with a **criticality-scaled slope** (critical
  apps fall off a cliff, best-effort apps degrade gently),
* ``slope = +inf`` is an exact **step curve**: u = u_max iff d >= knee,
  which recovers the old binary table as a special case (parity-tested in
  tests/test_overload.py).

Curves ride on ``Problem`` as the optional ``util_knee / util_slope /
util_weight`` arrays (``None`` = feature off, objective bit-identical) and
are scored by the fleet-utility goal term in ``core.goals``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import Problem

# Default curve shape: knee at full demanded capacity (the SLO point of the
# paper's table — an app is "meeting SLO" when fully served), base slope 2.0
# (utility hits 0 at half delivery for a criticality-0 app) scaled up to 8.0
# at criticality 1 (critical apps lose utility four times faster).
DEFAULT_KNEE = 1.0
BASE_SLOPE = 2.0
CRIT_SLOPE_SCALE = 3.0
# u_max floor so even zero-criticality apps carry utility worth serving.
BASE_WEIGHT = 0.5


def utility_of(delivered, knee, slope, weight):
    """Evaluate the curve family; jnp-traceable, broadcasts elementwise.

    ``slope = +inf`` yields the exact step curve (the deficit==0 branch is
    selected before the inf can poison anything).
    """
    deficit = jnp.maximum(knee - delivered, 0.0)
    loss = jnp.where(deficit > 0.0, slope * deficit, 0.0)
    return weight * jnp.clip(1.0 - loss, 0.0, 1.0)


def default_curves(
    criticality,
    *,
    knee: float = DEFAULT_KNEE,
    base_slope: float = BASE_SLOPE,
    crit_scale: float = CRIT_SLOPE_SCALE,
    base_weight: float = BASE_WEIGHT,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(knee, slope, weight) arrays from per-app criticality scores.

    Slope and u_max both scale with criticality: critical apps are worth
    more at full delivery *and* degrade faster below the knee, so the
    utility-optimal shed order puts best-effort headroom first.
    """
    crit = np.asarray(criticality, np.float32)
    knees = np.full(crit.shape, knee, np.float32)
    slopes = (base_slope * (1.0 + crit_scale * crit)).astype(np.float32)
    weights = (base_weight + crit).astype(np.float32)
    return knees, slopes, weights


def step_curves(
    criticality, *, knee: float = DEFAULT_KNEE, base_weight: float = BASE_WEIGHT
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The binary SLO table as a curve: full utility at the knee, none below."""
    crit = np.asarray(criticality, np.float32)
    knees = np.full(crit.shape, knee, np.float32)
    slopes = np.full(crit.shape, np.inf, np.float32)
    weights = (base_weight + crit).astype(np.float32)
    return knees, slopes, weights


def attach_curves(
    problem: Problem, knee=None, slope=None, weight=None, *, step: bool = False
) -> Problem:
    """A copy of ``problem`` with utility curves attached.

    With no explicit arrays, derives ``default_curves`` (or ``step_curves``
    when ``step=True``) from the problem's own criticality scores.
    """
    if knee is None:
        maker = step_curves if step else default_curves
        knee, slope, weight = maker(np.asarray(problem.criticality))
    return dataclasses.replace(
        problem,
        util_knee=jnp.asarray(knee, jnp.float32),
        util_slope=jnp.asarray(slope, jnp.float32),
        util_weight=jnp.asarray(weight, jnp.float32),
    )


def tier_delivery_factor(util_frac) -> jax.Array:
    """f32[T] fair-throttle factor per tier from utilization fractions.

    A tier loaded past capacity serves every resident the same fraction
    ``capacity / load`` (fair queueing across apps); an under-loaded tier
    serves in full.  The worst resource binds.
    """
    util_frac = jnp.asarray(util_frac)
    factor = jnp.where(util_frac > 1.0, 1.0 / jnp.maximum(util_frac, 1e-9), 1.0)
    return jnp.min(factor, axis=-1)


def delivered_fractions(
    problem: Problem, assignment, caps: Optional[jax.Array] = None
) -> jax.Array:
    """f32[N] delivered capacity fraction per app under an assignment.

    ``caps`` (delivery caps in [0, 1], e.g. the LoadShedder's throttles)
    scale each app's *served* demand at the source; the tier fair-throttle
    then applies to what is actually offered to the tier.  An app's
    delivered fraction is its own cap times its tier's throttle.
    """
    demand = problem.demand
    if caps is not None:
        demand = demand * jnp.asarray(caps, demand.dtype)[:, None]
    w = problem.valid.astype(demand.dtype)
    util = jax.ops.segment_sum(demand * w[:, None], assignment, num_segments=problem.num_tiers)
    factor = tier_delivery_factor(util / problem.capacity)
    delivered = factor[assignment]
    if caps is not None:
        delivered = delivered * jnp.asarray(caps, delivered.dtype)
    return jnp.where(problem.valid, delivered, 0.0)


def fleet_utility(
    problem: Problem, assignment, caps: Optional[jax.Array] = None
) -> tuple[jax.Array, jax.Array]:
    """(delivered utility, max achievable utility) over valid apps.

    Requires curves on the problem (``problem.has_utility``).
    """
    d = delivered_fractions(problem, assignment, caps)
    u = utility_of(d, problem.util_knee, problem.util_slope, problem.util_weight)
    w = problem.valid.astype(u.dtype)
    return jnp.sum(u * w), jnp.sum(problem.util_weight * w)


def oracle_utility(problem: Problem, caps: Optional[np.ndarray] = None) -> float:
    """Placement-free upper bound on delivered fleet utility (host numpy).

    Fractional-knapsack fill against *total* fleet capacity: apps are
    served in descending marginal-utility-density order (utility per unit
    demand), each up to its knee, until the scarcest resource runs out.
    Ignores tier boundaries, SLO eligibility, and movement budgets — no
    real controller can beat it, so delivered/oracle is a bounded score.
    """
    demand = np.asarray(problem.demand, np.float64)
    valid = np.asarray(problem.valid, bool)
    knee = np.asarray(problem.util_knee, np.float64)
    weight = np.asarray(problem.util_weight, np.float64)
    cap_total = np.asarray(problem.capacity, np.float64).sum(axis=0)
    if caps is not None:
        demand = demand * np.asarray(caps, np.float64)[:, None]
    # Serving app i at its knee costs knee_i * demand_i and earns weight_i.
    need = knee[:, None] * demand  # [N, R]
    load = need.sum(axis=1)
    density = weight / np.maximum(load, 1e-9)
    order = np.argsort(-density)
    remaining = cap_total.copy()
    total = 0.0
    slope = np.asarray(problem.util_slope, np.float64)
    for i in order:
        if not valid[i] or weight[i] <= 0.0:
            continue
        if load[i] <= 1e-12:
            total += weight[i]  # free to serve fully
            continue
        ratio = np.divide(
            remaining, need[i], out=np.full_like(remaining, np.inf), where=need[i] > 0
        )
        frac = min(1.0, float(np.min(ratio)))
        if frac <= 0.0:
            continue
        d = frac * knee[i]
        deficit = max(0.0, knee[i] - d)
        loss = slope[i] * deficit if deficit > 0 else 0.0
        earned = weight[i] * min(1.0, max(0.0, 1.0 - loss))
        if earned <= 0.0:
            # Partial service earns nothing (step curve / cliff slope):
            # don't burn capacity on it.
            continue
        total += earned
        remaining = remaining - frac * need[i]
        if np.all(remaining <= 1e-12):
            break
    return float(total)
