"""OptimalSearch engine (paper §3.2.1): LP-style relaxation for near-optimal
solutions.

"OptimalSearch: Provides a linear programming solver to search for
optimal/close-to-optimal solutions for the problem, this is usually both the
most time consuming solver and the best performing solver in terms of
solution quality."

Meta's Rebalancer wraps a commercial LP; we implement the relaxation
TPU-natively: the assignment is relaxed to a row-stochastic matrix
P = softmax(Z) (the simplex constraint becomes structural), the scalarized
goal objective is optimized in expectation together with smooth penalties for
the hard constraints, with Adam under ``lax.scan``.  A confidence-ordered
rounding pass (also a ``lax.scan``) then produces a hard assignment that is
feasible *by construction* — every accepted move re-checks capacity, task
limit, SLO/avoid and the movement budget, and infeasible roundings fall back
to the app's current tier.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import goals
from repro.core.problem import Problem, tier_loads
from repro.core.solver_local import SolveResult


@dataclasses.dataclass(frozen=True)
class OptimalSearchConfig:
    steps: int = 600              # gradient steps — the "timeout" knob
    lr: float = 5e-2
    penalty: float = 1e6          # hard-constraint penalty weight
    entropy: float = 1e-3         # annealed-to-zero entropy regularizer
    seed: int = 0
    batch_moves: int = 16         # top-k batch size of the rounding-refinement
                                  # LocalSearch pass (1 = legacy single-move)


def _penalized_objective(problem: Problem, logits: jax.Array,
                         penalty: float, entropy: float,
                         progress: jax.Array) -> jax.Array:
    feas = problem.feasible_mask()                       # [N, T] SLO + avoid
    masked = jnp.where(feas, logits, -jnp.inf)
    probs = jax.nn.softmax(masked, axis=-1)
    obj = goals.soft_objective(problem, probs)

    # Hard-constraint penalties (expected loads).
    util = probs.T @ problem.demand
    tasks = probs.T @ problem.tasks
    cap_over = jnp.maximum(util - problem.capacity, 0.0) / problem.capacity
    task_over = jnp.maximum(tasks - problem.task_limit, 0.0) / problem.task_limit
    stay = jnp.take_along_axis(probs, problem.assignment0[:, None], axis=1)[:, 0]
    exp_moves = jnp.sum(1.0 - stay)
    over_budget = jnp.maximum(exp_moves - problem.move_budget, 0.0)
    pen = (jnp.sum(cap_over ** 2) + jnp.sum(task_over ** 2)
           + (over_budget / jnp.maximum(problem.num_apps, 1)) ** 2)

    # Entropy annealed toward 0 sharpens P into a near-hard assignment.
    ent = -jnp.sum(jnp.where(probs > 0, probs * jnp.log(probs + 1e-20), 0.0))
    return obj + penalty * pen + entropy * (1.0 - progress) * ent


@partial(jax.jit, static_argnames=("steps", "lr", "penalty", "entropy"))
def _optimize(problem: Problem, key: jax.Array, *, steps: int, lr: float,
              penalty: float, entropy: float):
    N, T = problem.num_apps, problem.num_tiers
    # Warm-start at the current assignment (Rebalancer also starts from the
    # live state) with a little exploration noise.
    z0 = 4.0 * jax.nn.one_hot(problem.assignment0, T)
    z0 = z0 + 0.01 * jax.random.normal(key, (N, T))

    grad_fn = jax.grad(
        lambda z, p: _penalized_objective(problem, z, penalty, entropy, p))

    def step(carry, i):
        z, m, v = carry
        progress = i.astype(jnp.float32) / steps
        g = grad_fn(z, progress)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * (g * g)
        mhat = m / (1.0 - 0.9 ** (i + 1))
        vhat = v / (1.0 - 0.999 ** (i + 1))
        z = z - lr * mhat / (jnp.sqrt(vhat) + 1e-8)
        return (z, m, v), None

    (z, _, _), _ = jax.lax.scan(step, (z0, jnp.zeros_like(z0), jnp.zeros_like(z0)),
                                jnp.arange(steps))
    feas = problem.feasible_mask()
    probs = jax.nn.softmax(jnp.where(feas, z, -jnp.inf), axis=-1)
    return probs


@jax.jit
def _round(problem: Problem, probs: jax.Array):
    """Confidence-ordered rounding with feasibility repair (all-jit).

    Apps are visited in decreasing (p_target - p_stay) order; each proposed
    move is accepted only if destination capacity/task headroom, SLO/avoid
    and the movement budget allow it — otherwise the app stays home.
    """
    N, T = problem.num_apps, problem.num_tiers
    target = jnp.argmax(probs, axis=-1)                              # [N]
    p_target = jnp.max(probs, axis=-1)
    p_stay = jnp.take_along_axis(probs, problem.assignment0[:, None], axis=1)[:, 0]
    gain = p_target - p_stay
    order = jnp.argsort(-gain)                                       # most confident first

    feas = problem.feasible_mask()
    # Loads start from *stay-home* state and moves are applied incrementally;
    # apps staying home never change loads.
    util0, tasks0 = tier_loads(problem, problem.assignment0)

    def step(carry, n):
        x, util, tasks, budget = carry
        src = problem.assignment0[n]
        t = target[n]
        is_move = t != src
        fits = (jnp.all(util[t] + problem.demand[n] <= problem.capacity[t] + 1e-6)
                & (tasks[t] + problem.tasks[n] <= problem.task_limit[t] + 1e-6)
                & feas[n, t] & (budget > 0))
        accept = is_move & fits
        x = x.at[n].set(jnp.where(accept, t, src).astype(x.dtype))
        util = jnp.where(accept,
                         util.at[src].add(-problem.demand[n]).at[t].add(problem.demand[n]),
                         util)
        tasks = jnp.where(accept,
                          tasks.at[src].add(-problem.tasks[n]).at[t].add(problem.tasks[n]),
                          tasks)
        budget = budget - accept.astype(jnp.int32)
        return (x, util, tasks, budget), None

    init = (problem.assignment0, util0, tasks0, problem.move_budget)
    (x, _, _, _), _ = jax.lax.scan(step, init, order)
    return x


def solve_optimal(problem: Problem,
                  config: OptimalSearchConfig = OptimalSearchConfig()) -> SolveResult:
    """Relax -> optimize -> round -> local repair/refinement.

    The refinement pass (a budget-bounded LocalSearch warm-started from the
    rounded solution) is standard LP-rounding practice and is what realizes
    the paper's "usually ... the best performing solver in terms of solution
    quality" behaviour; at small step budgets it may still lose to pure
    LocalSearch — exactly the Fig. 5 observation.
    """
    from repro.core.solver_local import LocalSearchConfig, solve_local

    t0 = time.perf_counter()
    key = jax.random.PRNGKey(config.seed)
    probs = _optimize(problem, key, steps=config.steps, lr=config.lr,
                      penalty=config.penalty, entropy=config.entropy)
    x = _round(problem, probs)
    refine = solve_local(
        problem,
        LocalSearchConfig(max_iters=max(32, config.steps // 4),
                          seed=config.seed, batch_moves=config.batch_moves),
        init_assignment=x)
    x = jax.block_until_ready(refine.assignment)
    dt = time.perf_counter() - t0
    return SolveResult(
        assignment=x,
        iterations=config.steps + refine.iterations,
        converged=True,
        objective=float(goals.objective(problem, x)),
        num_moved=int(jnp.sum((x != problem.assignment0) & problem.valid)),
        solve_time_s=dt,
        extra={"refine": refine.extra},
    )
