"""Hierarchy co-operation (paper §3.4 + Fig. 2).

Three lower-level-scheduler integration variants for SPTLB:

  * ``no_cnst``     — solve once, ignore lower levels (best balance, worst
                      network latency; Fig. 4/5 baseline),
  * ``w_cnst``      — bake region-awareness into the solver: a tier->tier
                      transition is valid only if the tiers share a majority
                      (>50%) of regions.  Static constraints, "vastly
                      increasing its complexity",
  * ``manual_cnst`` — the paper's proposal: SPTLB proposes a mapping; the
                      lower-level schedulers accept or reject each placement;
                      rejections return to SPTLB as avoid constraints
                      ("similar to Constraint 3 in section 3.2.1") and it
                      re-solves.  "These iterations continue until SPTLB
                      times out or the number of iterations limit is
                      reached."

Since PR 5 the ``manual_cnst`` loop is a *generic cooperation bus* over an
ordered stack of ``core.levels.SchedulerLevel`` objects (see that module
for the protocol).  The bus:

  * folds every level's ``premask`` into the solver's avoid mask before the
    first solve (home column re-opened — staying put is always legal),
  * runs the solve -> vet -> feedback fixpoint: each round every level vets
    the proposal in stack order (a level only sees the candidates that
    survived the levels above it), rejections are scattered into the
    standing device-resident avoid mask, accepted moves are locked, and the
    solver re-solves warm-started,
  * offers each level a ``feedback`` escalation hook (extra standing avoid
    rows beyond the per-(app, dest) scatter),
  * reverts still-unvetted moves at the iteration/timeout limit through a
    stack-wide fixpoint (levels whose accept depends on whole-group state —
    host packing — are re-vetted with the ``returners`` each revert sends
    home),
  * aggregates per-level wall-clock and rejection counters into
    ``CoopTimings.levels`` (flat legacy keys like ``region_s`` /
    ``host_rejections`` keep resolving).

``RegionScheduler`` and ``HostScheduler`` are the paper's two lower levels
refactored into the protocol — the default ``Hierarchy`` stack reproduces
the pre-protocol two-level path bit-for-bit (tests/test_coop_parity.py
pins assignment hashes, objectives, rounds, and rejection counts captured
before the refactor).  A third level is a plugin, not a rewrite:
``core.levels.ShardLocalityScheduler`` vets data-shard co-location and
rides the same bus (``Hierarchy.from_names("region,host,shard")``).

Device-resident mechanics carried over from PR 1/2 (unchanged contracts):
region pre-masking kills the region-rejection class before the first
solve; all-tier batched FFD packing (``HostScheduler.check_tiers``) packs
every destination tier in one vmapped dispatch; the avoid/ack mask stays
on device across rounds and is updated with ``mode="drop"`` scatters.
``host_side_frac`` (everything that is neither the solver nor a level's
compiled device dispatches) stays <= ~0.03 at N=10_000, and the new
``bus_overhead_frac`` isolates the generic bus's own glue (unaccounted
wall-clock outside solver/levels/feedback) — gated <= ~5% in
``benchmarks/check_regression.py``.

Precomputes that depend only on cluster geometry (the region worst-latency
matrix, the region feasibility matrix, the w_cnst overlap mask, shard
affinity) are memoized on ``ClusterState._cache``; any
``dataclasses.replace`` of the cluster resets the cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.goals import objective as _objective
from repro.core.health import OPEN
from repro.core.levels import (BusState, CoopConfig, CoopTimings,
                               DEFAULT_LEVELS, Hierarchy, Proposal,
                               REGION_LATENCY_BUDGET_MS,
                               RELAX_LATENCY_FACTOR, SchedulerLevel,
                               register_level)
from repro.core.planner import movement_cost_of
from repro.core.problem import Problem, bucket_size
from repro.core.solver_local import SolveResult
from repro.core.telemetry import ClusterState
from repro.kernels.pack import DispatchStats, pack_ffd, pack_ffd_tiers

# The latency budget/relax constants are re-exported from ``core.levels``
# (the single source of truth) — historical importers read them from here.


class RegionScheduler(SchedulerLevel):
    """Region-preference placement (paper [4]-style shard placement).

    Accepts a placement iff the destination tier has hosts within a latency
    budget of the app's data-source region — "if it isn't possible to keep an
    app near its data source with the given tier, it returns false".

    ``latency_budget_ms`` may be a scalar (every app gets the same budget)
    or an f32[N] per-app array; the ``relax`` hook derives the per-app
    array itself from a declared maintenance plan (residents evacuating a
    declared deep drain get ``budget x relax_latency_factor``), and the
    relaxation binds proposal vetting, the premask, and the revert paths
    identically because they all read the same budget state.
    """

    name = "region"

    def __init__(self, cluster: ClusterState,
                 latency_budget_ms=REGION_LATENCY_BUDGET_MS):
        self.cluster = cluster
        if np.ndim(latency_budget_ms) == 0:
            self.budget = float(latency_budget_ms)
            self._budget_per_app = None
        else:
            self.budget = None
            self._budget_per_app = np.asarray(latency_budget_ms, np.float32)
        self._worst_ms = self._worst_ms_matrix(cluster)

    @staticmethod
    def _worst_ms_matrix(cluster: ClusterState) -> np.ndarray:
        """[G, T] worst-case latency from each source region to each tier,
        memoized on the cluster (it depends only on geometry, not on the
        assignment, so every scheduler instance over this cluster shares it).

        Host capacity is fungible across a tier's regions, so the guarantee
        must hold for the worst region the tier may place the app in (max),
        not the best.  One vectorized max replaces the per-(app, tier)
        Python rescans of ``region_latency``.
        """
        cache = cluster._cache
        if "region_worst_ms" not in cache:
            c = cluster
            worst = np.where(
                c.tier_regions.T[None, :, :],              # [1, G, T] region in tier?
                c.region_latency[:, :, None],              # [G, G, 1]
                -np.inf,
            ).max(axis=1)                                  # [G, T]
            # A tier with no regions has no hosts anywhere near any data
            # source: reject placements into it (the pre-vectorization code
            # raised on the empty reduction; -inf would silently *accept*).
            worst[:, ~c.tier_regions.any(axis=1)] = np.inf
            cache["region_worst_ms"] = worst
        return cache["region_worst_ms"]

    def _budget_of(self, apps) -> np.ndarray | float:
        if self._budget_per_app is None:
            return self.budget
        return self._budget_per_app[apps]

    def check(self, app: int, tier: int) -> bool:
        """Accept iff the tier's worst region stays within the budget."""
        return bool(self._worst_ms[self.cluster.app_region[app], tier]
                    <= self._budget_of(app))

    def check_many(self, apps: np.ndarray, tiers: np.ndarray) -> np.ndarray:
        """Vectorized ``check`` over (app, tier) pairs -> bool[len(apps)]."""
        apps = np.asarray(apps, np.int64)
        tiers = np.asarray(tiers, np.int64)
        return (self._worst_ms[self.cluster.app_region[apps], tiers]
                <= self._budget_of(apps))

    def feasibility_matrix(self) -> np.ndarray:
        """bool[N, T]: the full region-feasibility matrix for every app.

        Memoized per (cluster, budget) — this is what the premask folds
        into the solver's avoid mask every cooperation pass.  Per-app
        budget arrays (maintenance placement mode) skip the memo: they are
        derived per control round, and one cooperation pass reads the
        matrix once.
        """
        if self._budget_per_app is not None:
            return (self._worst_ms[self.cluster.app_region]
                    <= self._budget_per_app[:, None])
        key = ("region_feasibility", float(self.budget))
        cache = self.cluster._cache
        if key not in cache:
            cache[key] = self._worst_ms[self.cluster.app_region] <= self.budget
        return cache[key]

    # -- SchedulerLevel protocol ---------------------------------------------
    def premask(self, problem: Problem) -> np.ndarray:
        """Region infeasibility as an avoid contribution (home column is
        re-opened by the bus)."""
        return ~self.feasibility_matrix()

    def vet(self, proposal: Proposal) -> np.ndarray:
        c = proposal.candidates
        if c.size == 0:
            return np.asarray(c, np.int64)
        ok = self.check_many(c, proposal.x[c])
        return np.asarray(c[~ok], np.int64)

    def relax(self, plan, cluster) -> None:
        """Maintenance placement mode: residents of a declared deep drain
        may evacuate under a relaxed latency budget (bounded degradation
        beats riding the drain into over-capacity); everyone else keeps
        the strict budget."""
        relax_tiers = getattr(plan, "relax_home_tiers", None)
        if relax_tiers is None or not np.asarray(relax_tiers).any():
            return
        base = self.budget if self.budget is not None else REGION_LATENCY_BUDGET_MS
        factor = float(getattr(plan, "relax_latency_factor",
                               RELAX_LATENCY_FACTOR))
        x0 = np.asarray(self.cluster.problem.assignment0)
        self._budget_per_app = np.where(
            np.asarray(relax_tiers)[x0], base * factor, base).astype(np.float32)
        self.budget = None


class HostScheduler(SchedulerLevel):
    """Host allocation: first-fit-decreasing bin-packing into tier hosts.

    Accepts a placement iff every app mapped to the tier still fits after
    packing — "if there are available hosts to allocate the application to,
    it accepts the mapping".  Rejections name the specific apps that failed
    to pack (the ones whose placement SPTLB must avoid).

    Packing runs on device (``kernels.pack``): the sorted demand axis is
    bucket-padded to a power-of-two length and the host-bin axis is padded
    to one power-of-two for the whole cluster with the live count traced, so
    *all* tiers — whatever their host count — share one compiled executable
    per app bucket.  ``check_tiers`` packs every tier of a proposal in a
    single vmapped dispatch; ``check_tier`` is the legacy one-tier entry
    point with identical decisions.  The instance accumulates pack dispatch
    / retrace / wall-clock counters, surfaced through the level
    ``counters()`` hook into ``CoopTimings.levels["host"]``.
    """

    name = "host"

    def __init__(self, cluster: ClusterState):
        self.cluster = cluster
        self._hosts_pad = bucket_size(int(cluster.hosts_per_tier.max()),
                                      minimum=16)
        # Pack-side constants, memoized on the cluster like the region
        # matrices: the host-side demand copy (one device->host transfer
        # per cluster, not per tick) and the device-side capacity / host
        # count arrays (re-used by every dispatch instead of re-uploaded).
        cache = cluster._cache
        if "host_pack_consts" not in cache:
            cache["host_pack_consts"] = (
                np.asarray(cluster.problem.demand),            # [N, R]
                jnp.asarray(cluster.host_capacity),            # f32[R]
                jnp.asarray(cluster.hosts_per_tier.astype(np.int32)))
        self._demand, self._cap_dev, self._hosts_dev = cache["host_pack_consts"]
        self._stats = DispatchStats()
        # Residents (apps already home) of a *force-packed* tier that failed
        # to pack.  They have nowhere better to go — home is the fallback of
        # every revert path — but they must be observable instead of the
        # tier being silently trusted to absorb its returners.  A set of
        # ids, not a counter: revert fixpoints and restart re-vets can
        # force-pack the same tier repeatedly.
        self._resident_overflow_ids: set[int] = set()

    @property
    def resident_overflows(self) -> int:
        """Distinct residents that failed a force re-pack."""
        return len(self._resident_overflow_ids)

    # Legacy counter aliases (``kernels.pack.DispatchStats`` owns the
    # bookkeeping; these stay readable for existing callers/tests).
    @property
    def pack_s(self) -> float:
        return self._stats.seconds

    @property
    def pack_dispatches(self) -> int:
        return self._stats.dispatches

    @property
    def pack_retraces(self) -> int:
        return self._stats.retraces

    def _dispatch(self, fn, *args, **kw) -> np.ndarray:
        return self._stats.run(fn, *args, **kw)

    def check_tier(self, tier: int, apps: np.ndarray) -> list[int]:
        """Returns the app ids that could NOT be packed into this tier."""
        c = self.cluster
        apps = np.asarray(apps, np.int64)
        if apps.size == 0:
            return []
        # Canonical order: ascending id, then a *stable* decreasing sort —
        # ties on max demand resolve identically to ``check_tiers``'s
        # stable (tier, -dmax) lexsort, so the two paths stay bit-identical
        # whatever order the caller passed the membership in.
        apps = np.sort(apps)
        demand = self._demand[apps]                          # [M, R]
        order = np.argsort(-demand.max(axis=1), kind="stable")
        M = apps.size
        Mb = bucket_size(M, minimum=128)
        d_sorted = np.zeros((Mb, demand.shape[1]), demand.dtype)
        d_sorted[:M] = demand[order]
        rejected = self._dispatch(
            pack_ffd, jnp.asarray(d_sorted), self._cap_dev,
            jnp.int32(c.hosts_per_tier[tier]),
            num_hosts_pad=self._hosts_pad)[:M]
        return [int(a) for a in apps[order][rejected]]

    def check_tiers(self, x: np.ndarray, x0: np.ndarray,
                    newcomers: np.ndarray,
                    force_tiers: np.ndarray | None = None) -> np.ndarray:
        """Batched accept/reject for a whole proposal in one device call.

        Tier t's membership is its incumbents (``x == x0 == t``) plus the
        ``newcomers`` moved into t; only tiers receiving at least one
        newcomer are packed (identical tier set and per-tier membership to
        the per-tier loop this replaces).  The membership is segment-sorted
        by (destination tier, decreasing demand) and scattered into a padded
        [T, M_b, R] tensor for ``pack_ffd_tiers``.  Returns the *newcomer*
        app ids whose placement failed to pack, i64[K] (incumbents never
        bounce — their current placement was already accepted).

        ``force_tiers`` adds tiers to pack even when no newcomer targets
        them — the revert paths use it for home tiers whose only change is
        returning apps (FFD is not monotone under item removal, so a
        membership that *shrank* back toward the original can still fail to
        pack).  Residents of a forced tier that fail are counted in
        ``resident_overflows`` (their placement is already the fallback).
        """
        c = self.cluster
        T = len(c.hosts_per_tier)
        x = np.asarray(x, np.int64)
        x0 = np.asarray(x0, np.int64)
        newcomers = np.asarray(newcomers, np.int64)
        force = (np.asarray(force_tiers, np.int64)
                 if force_tiers is not None else np.empty(0, np.int64))
        if newcomers.size == 0 and force.size == 0:
            return newcomers
        is_new = np.zeros(x.shape[0], bool)
        is_new[newcomers] = True
        active = np.zeros(T, bool)
        active[x[newcomers]] = True
        active[force] = True
        member = active[x] & ((x == x0) | is_new)
        ids = np.where(member)[0]
        if ids.size == 0:
            return np.empty(0, np.int64)
        demand = self._demand                                # [N, R]
        dmax = demand[ids].max(axis=1)
        order = np.lexsort((-dmax, x[ids]))                  # tier, then FFD order
        ids = ids[order]
        tiers = x[ids]
        counts = np.bincount(tiers, minlength=T)
        Mb = bucket_size(int(counts.max()), minimum=128)
        pos = np.arange(ids.size) - (np.cumsum(counts) - counts)[tiers]
        dem = np.zeros((T, Mb, demand.shape[1]), demand.dtype)
        dem[tiers, pos] = demand[ids]
        slot_app = np.full((T, Mb), -1, np.int64)
        slot_app[tiers, pos] = ids
        rejected = self._dispatch(
            pack_ffd_tiers, jnp.asarray(dem), self._cap_dev, self._hosts_dev,
            num_hosts_pad=self._hosts_pad)
        rej = slot_app[rejected & (slot_app >= 0)]
        if force.size:
            # Only the force-packed tiers feed the overflow set: a hot
            # tier's incumbents failing a routine vet is the pre-existing
            # overload the seed already tolerates, not a returner gap.
            in_force = np.zeros(T, bool)
            in_force[force] = True
            self._resident_overflow_ids.update(
                rej[(x[rej] == x0[rej]) & in_force[x[rej]]].tolist())
        return rej[x[rej] != x0[rej]]                        # newcomers bounce

    # -- SchedulerLevel protocol ---------------------------------------------
    def vet(self, proposal: Proposal) -> np.ndarray:
        force = None
        if proposal.final:
            # Revert fixpoint: home tiers of the apps other levels (or this
            # one, last sweep) sent home must be re-packed even with no
            # newcomers left — FFD is not monotone under item removal.
            force = (np.unique(proposal.x0[proposal.returners])
                     if proposal.returners.size else np.empty(0, np.int64))
        return self.check_tiers(proposal.x, proposal.x0, proposal.candidates,
                                force_tiers=force)

    def counters(self) -> dict:
        return {"pack_s": self.pack_s,
                "pack_dispatches": self.pack_dispatches,
                "pack_retraces": self.pack_retraces,
                "resident_overflows": self.resident_overflows}

    def device_time_s(self) -> float:
        return self.pack_s


register_level("region", RegionScheduler)
register_level("host", HostScheduler)


@dataclasses.dataclass
class CooperationResult:
    result: SolveResult
    variant: str
    feedback_rounds: int
    num_rejections: int
    total_time_s: float
    accepted: bool
    # Typed per-phase observability (see core.levels.CoopTimings): scalar
    # phases (solve_s / feedback_s / total_s), per-level sub-dicts under
    # ``levels`` (glue wall-clock, rejections, pack counters), and the
    # legacy flat keys ("region_s", "host_rejections", "pack_retraces", ...)
    # still resolving through the mapping interface.
    timings: CoopTimings = dataclasses.field(default_factory=CoopTimings)


def region_overlap_avoid(cluster: ClusterState) -> np.ndarray:
    """w_cnst static constraint: avoid[n, t] unless >50% of the regions of
    app n's current tier overlap with tier t (paper §4.2.2 item 2).

    Memoized on the cluster — it depends on geometry and ``assignment0``,
    both of which only change through ``dataclasses.replace`` (which resets
    the cache).
    """
    cache = cluster._cache
    if "region_overlap_avoid" not in cache:
        c = cluster
        regions = c.tier_regions.astype(np.int64)
        shared = regions @ regions.T                         # [T, T]
        na = regions.sum(axis=1)
        overlap_ok = shared > 0.5 * na[:, None]
        x0 = np.asarray(c.problem.assignment0)
        cache["region_overlap_avoid"] = ~overlap_ok[x0]      # [N, T]
    return cache["region_overlap_avoid"]


@jax.jit
def _feedback_update(avoid, base_avoid, assignment, x0, rej, rej_dst,
                     acked, acked_dst, acked_home):
    """One compiled feedback step: scatter the round's rejections and
    acknowledgements into the standing avoid mask and build the warm-start
    assignment with the rejected moves sent home.

    ``rej``/``acked`` are id arrays bucket-padded with the out-of-range
    sentinel N, and every scatter uses ``mode="drop"`` so the padding rows
    vanish — one executable per (N-bucket, id-bucket) pair instead of a
    fresh eager dispatch chain for every distinct rejection count.
    """
    avoid = avoid.at[rej, rej_dst].set(True, mode="drop")
    avoid = avoid.at[acked, :].set(True, mode="drop")
    avoid = avoid.at[acked, acked_dst].set(False, mode="drop")
    avoid = avoid.at[acked, acked_home].set(False, mode="drop")
    # Caller avoids + the premask are OR-ed back so accumulated feedback can
    # never clear a standing constraint.
    avoid = avoid | base_avoid
    x_acc = assignment.at[rej].set(x0.at[rej].get(mode="clip"), mode="drop")
    return avoid, x_acc


def _pad_ids(ids: np.ndarray, sentinel: int, minimum: int = 32) -> np.ndarray:
    """Pad an id array to a power-of-two bucket with ``sentinel`` (== N,
    out of range) so ``_feedback_update`` sees O(log N) distinct shapes."""
    b = bucket_size(max(ids.size, 1), minimum=minimum)
    out = np.full(b, sentinel, np.int32)
    out[:ids.size] = ids
    return out


def _finish_timings(timings: CoopTimings, total_s: float) -> CoopTimings:
    # Device phases are the solver and the levels' compiled dispatches
    # (``device_time_s``, already split out of each level's glue by
    # ``_collect_level_counters``); everything else counts as host-side —
    # the per-phase counters plus untimed glue, so the fraction cannot
    # undercount host work.  ``bus_overhead_frac`` narrows further: the
    # wall-clock that belongs to no phase at all (the generic bus's own
    # routing), the number the PR-5 regression gate pins.
    timings.total_s = total_s
    device_s = timings.solve_s + sum(
        float(sub.get("device_s", 0.0)) for sub in timings.levels.values())
    timings.host_side_frac = (
        max(0.0, total_s - device_s) / total_s if total_s > 0 else 0.0)
    accounted = timings.solve_s + timings.feedback_s + sum(
        float(sub.get("level_s", 0.0)) + float(sub.get("device_s", 0.0))
        for sub in timings.levels.values())
    timings.bus_overhead_frac = (
        max(0.0, total_s - accounted) / total_s if total_s > 0 else 0.0)
    return timings


def _collect_level_counters(timings: CoopTimings, levels) -> None:
    """Merge each level's ``counters()`` into its timings sub-dict and
    split its compiled-dispatch time out of the level's glue wall-clock."""
    for lv in levels:
        sub = timings.levels.setdefault(lv.name,
                                        {"level_s": 0.0, "rejections": 0})
        sub.update(lv.counters())
        dev = float(lv.device_time_s())
        if dev:
            sub["device_s"] = dev
            sub["level_s"] = max(0.0, sub["level_s"] - dev)


class _BreakerPass:
    """Per-pass mediator between the bus and a ``core.health.BreakerBoard``.

    ``board=None`` (the default stack) keeps every hook on the exact
    pre-breaker code path — no try/except, no extra accounting — so the
    fault machinery costs nothing until a board is configured
    (tests/test_coop_parity.py pins the bit-identity).  With a board:

      * OPEN levels are *bypassed*: out of the vet/feedback/revert loops,
        but their conservative fallback premask (last successfully
        computed, cached on the board) still constrains the solver.
      * A level hook that raises fails *closed*: the vet rejects every
        candidate it was asked about (stay-home is always safe), the
        failure is recorded, and the pass continues without the answer.
      * ``end_pass`` (via ``finish``) runs each breaker's trip/probe
        bookkeeping and snapshots the board into ``timings.breakers``.
    """

    def __init__(self, board, levels):
        self.board = board
        self.bypassed: set[str] = set()
        if board is not None:
            for lv in levels:
                if board.breaker(lv.name).begin_pass() == OPEN:
                    self.bypassed.add(lv.name)

    def active(self, levels) -> list:
        if self.board is None:
            return list(levels)
        return [lv for lv in levels if lv.name not in self.bypassed]

    def vet(self, level, proposal: Proposal,
            timings: CoopTimings) -> np.ndarray:
        brk = self.board.breaker(level.name)
        t = time.perf_counter()
        try:
            rej = np.asarray(level.vet(proposal), np.int64)
        except Exception:
            brk.note_failure()
            rej = np.asarray(proposal.candidates, np.int64)  # fail closed
        elapsed = time.perf_counter() - t
        timings.add_level_time(level.name, elapsed)
        limit = self.board.config.level_timeout_s
        if limit is not None and elapsed > limit:
            brk.note_failure()
        brk.note_vet(int(np.asarray(proposal.candidates).size), int(rej.size))
        return rej

    def premask(self, level, problem):
        """Live premask, cached on success; the cached fallback when the
        level raises or its breaker is open."""
        if self.board is None:
            return level.premask(problem)
        if level.name in self.bypassed:
            pre = self.board.cached_premask(level.name)
            if pre is not None:
                return pre
            try:  # never premasked while healthy: one guarded live attempt
                return level.premask(problem)
            except Exception:
                return None
        try:
            pre = level.premask(problem)
            self.board.cache_premask(level.name, pre)
            return pre
        except Exception:
            self.board.breaker(level.name).note_failure()
            return self.board.cached_premask(level.name)

    def feedback(self, level, state: BusState):
        if self.board is None:
            return level.feedback(state)
        try:
            return level.feedback(state)
        except Exception:
            self.board.breaker(level.name).note_failure()
            return None

    def relax(self, level, plan, cluster) -> None:
        if self.board is None:
            level.relax(plan, cluster)
            return
        try:
            level.relax(plan, cluster)
        except Exception:
            self.board.breaker(level.name).note_failure()

    def finish(self, timings: CoopTimings) -> None:
        if self.board is None:
            return
        for brk in self.board.breakers.values():
            brk.end_pass()
        timings.breakers = {
            "bypassed": sorted(self.bypassed),
            "trips": self.board.trips,
            "levels": self.board.snapshot(),
        }


def _vet_timed(level, proposal: Proposal, timings: CoopTimings,
               breakers: Optional[_BreakerPass] = None) -> np.ndarray:
    if breakers is not None and breakers.board is not None:
        return breakers.vet(level, proposal, timings)
    t = time.perf_counter()
    rej = np.asarray(level.vet(proposal), np.int64)
    timings.add_level_time(level.name, time.perf_counter() - t)
    return rej


def _revert_fixpoint(levels, x_np: np.ndarray, x0_np: np.ndarray,
                     timings: CoopTimings,
                     seed_returners: np.ndarray | None = None,
                     breakers: Optional[_BreakerPass] = None) -> np.ndarray:
    """Drop unvetted moves (stay-home is safe — the original placement was
    accepted by every level) and re-vet the stack to a fixpoint.

    Every revert sends apps home, and a level's accept can depend on
    whole-group state (host packing is not monotone under item removal), so
    each level is re-vetted with the ``returners`` sent home since it last
    answered — home tiers whose only change is their returners get force
    re-packed through ``Proposal.final``.  Each sweep reverts at least one
    mover or terminates, so the fixpoint is finite.  ``seed_returners``
    pre-loads the returner set (budget trimming reverts moves before the
    fixpoint starts).
    """
    x_np = x_np.copy()
    empty = np.empty(0, np.int64)
    pending = {lv.name: (seed_returners if seed_returners is not None
                         else empty) for lv in levels}
    while True:
        rejected_any = False
        for lv in levels:
            movers = np.where(x_np != x0_np)[0]
            returners = pending[lv.name]
            if movers.size == 0 and returners.size == 0:
                continue
            rej = _vet_timed(lv, Proposal(x_np, x0_np, movers,
                                          returners=returners, final=True),
                             timings, breakers)
            pending[lv.name] = empty
            # Defensive protocol clamp: only movers can be rejected (the
            # incumbent placement is every revert's fallback).  A plugin
            # level that bounced a returner would otherwise no-op the
            # revert while keeping rejected_any set — an infinite fixpoint.
            rej = rej[x_np[rej] != x0_np[rej]]
            if rej.size:
                x_np[rej] = x0_np[rej]
                for other in levels:
                    prev = pending[other.name]
                    pending[other.name] = (rej if prev.size == 0
                                           else np.concatenate([prev, rej]))
                rejected_any = True
        if not rejected_any:
            return x_np


def enforce_cost_budget(cluster: ClusterState, res: SolveResult,
                        x0_np: np.ndarray, move_cost, cost_budget: float,
                        levels, timings,
                        breakers: Optional[_BreakerPass] = None) -> SolveResult:
    """Price the final mapping and trim it to the round's movement budget.

    Movement is the §3.2.1 goal-8 downtime the paper prices; Madsen et al.
    price live reconfiguration explicitly.  Every vetted mapping is priced
    (``timings["movement_cost"]``); when the caller hands down a finite
    ``cost_budget`` and the mapping exceeds it, moves are reverted until it
    fits.  Moves that rescue an SLO-stranded incumbent (home tier no longer
    eligible for the app's class) are kept first — their revert costs
    violation ticks, not just balance — then cheap moves before expensive
    ones, so the budget buys as much placement repair as possible.

    Reverting sends apps home, and home tiers can overflow on returners
    (FFD is not monotone under item removal), so trimmed mappings re-run
    the stack's revert fixpoint with the reverted apps as seed returners —
    the same contract as ``_revert_fixpoint``.  Trimming never *adds*
    moves, so the budget holds after the fixpoint too.  ``levels`` may be
    empty (hierarchy-unaware engines: no re-vet to run).
    """
    x_np = np.asarray(res.assignment)
    total = movement_cost_of(x_np, x0_np, move_cost)
    timings["movement_cost"] = total
    if total <= cost_budget + 1e-9:
        return res
    x_np = x_np.copy()
    moved = np.where(x_np != x0_np)[0]
    per = (np.ones(moved.size, np.float32) if move_cost is None
           else np.asarray(move_cost)[moved])
    p = cluster.problem
    slo_ok_home = np.asarray(p.slo_allowed)[
        x0_np[moved], np.asarray(p.slo)[moved]]
    # lexsort: last key is primary — strand-fixers (slo_ok_home False) first,
    # then ascending per-move cost within each class.
    order = np.lexsort((per, slo_ok_home))
    keep = np.zeros(moved.size, bool)
    spent = 0.0
    for i in order:
        if spent + per[i] <= cost_budget + 1e-9:
            spent += per[i]
            keep[i] = True
    reverted = moved[~keep]
    x_np[reverted] = x0_np[reverted]
    timings["budget_trimmed"] = (timings.get("budget_trimmed", 0)
                                 + int(reverted.size))
    if levels and reverted.size:
        x_np = _revert_fixpoint(levels, x_np, x0_np, timings,
                                seed_returners=reverted, breakers=breakers)
    x_final = jnp.asarray(x_np)
    timings["movement_cost"] = movement_cost_of(x_np, x0_np, move_cost)
    return dataclasses.replace(
        res, assignment=x_final,
        num_moved=int(np.sum(x_np != x0_np)),
        objective=float(_objective(cluster.problem, x_final)))


def _restart_phase(cluster: ClusterState, problem: Problem, res: SolveResult,
                   timed_solve, levels, timings: CoopTimings,
                   restart_rounds: int, deadline: float,
                   x0_np: np.ndarray,
                   breakers: Optional[_BreakerPass] = None) -> SolveResult:
    """Perturbation restarts after an accepted fixed point (ROADMAP knob).

    The unmasked feedback loop gets diversification for free: every
    rejection round re-solves from a perturbed warm start.  Pre-masking
    removes those rounds, so at small N it can land in a worse local
    optimum at a *better* wall-clock.  Each restart sends a random third of
    the current movers home, re-solves warm-started under the same standing
    avoid mask, re-vets the proposal against the whole stack (exactly like
    the exhausted-rounds path), and keeps the best vetted objective — so
    the result can never get worse, only cost extra solves.
    """
    x_best = np.asarray(res.assignment).copy()
    obj_best = float(_objective(cluster.problem, jnp.asarray(x_best)))
    rng = np.random.default_rng(x_best.size)     # deterministic per problem
    attempts = improved = 0
    for _ in range(restart_rounds):
        if time.perf_counter() >= deadline:
            break
        moved = np.where(x_best != x0_np)[0]
        if moved.size == 0:
            break
        sel = rng.choice(moved, size=max(1, moved.size // 3), replace=False)
        x_pert = x_best.copy()
        x_pert[sel] = x0_np[sel]
        attempts += 1
        r = timed_solve(problem, init_assignment=jnp.asarray(
            x_pert.astype(np.int32)))
        x_r = _revert_fixpoint(levels, np.asarray(r.assignment), x0_np,
                               timings, breakers=breakers)
        obj_r = float(_objective(cluster.problem, jnp.asarray(x_r)))
        if obj_r < obj_best - 1e-9:
            obj_best, x_best = obj_r, x_r
            improved += 1
    timings.restarts = attempts
    timings.restart_improved = improved
    if improved:
        res = dataclasses.replace(
            res, assignment=jnp.asarray(x_best), objective=obj_best,
            num_moved=int(np.sum(x_best != x0_np)))
    return res


def cooperate(
    cluster: ClusterState,
    solve_fn: Callable[[Problem], SolveResult],
    *,
    config: Optional[CoopConfig] = None,
    hierarchy: Optional[Hierarchy] = None,
) -> CooperationResult:
    """Run one SPTLB balancing pass: the generic cooperation bus.

    ``config`` (a ``core.levels.CoopConfig``) carries every knob — the
    PR-5 deprecated kwarg shims (variant / max_rounds / premask_region /
    restart_rounds / region_budget_ms / ...) have been removed.
    ``hierarchy`` overrides the scheduler stack (default: ``config.levels``
    names, else region+host).  The ``manual_cnst`` variant drives the stack
    through premask -> solve -> vet -> feedback rounds exactly as the
    module docstring describes; ``no_cnst`` / ``w_cnst`` never consult the
    stack.

    ``config.premask`` folds every level's feasibility into the avoid mask
    before the first solve — the solver stops proposing level-infeasible
    moves and the feedback loop converges in fewer rounds; the final
    mapping is vetted by exactly the same level checks either way, so the
    knob trades search-space pruning for rounds, never feasibility.
    ``config.restart_rounds`` adds fully re-vetted perturbation restarts
    after an accepted fixed point.  ``config.move_cost`` /
    ``config.cost_budget`` price movement and trim the final mapping to
    budget (``enforce_cost_budget``).  ``config.plan`` reaches each level's
    ``relax`` hook (maintenance placement mode).  ``config.breakers`` (a
    ``core.health.BreakerBoard``) arms per-level circuit breakers: OPEN
    levels are bypassed behind their cached fallback premask, raising hooks
    fail closed, a raising solver falls back to its warm start (or the
    identity mapping), and the board's trip/probe state lands in
    ``timings.breakers``; ``None`` keeps the exact pre-breaker code path.
    """
    cfg = config if config is not None else CoopConfig()
    wallclock = cfg.timeout_s if cfg.timeout_s is not None else float("inf")

    t0 = time.perf_counter()
    problem = cluster.problem
    use_variant = cfg.variant

    if use_variant in ("no_cnst", "w_cnst"):
        # Neither variant consults the stack, so don't pay its precomputes
        # (the host scheduler's demand transfer, the region matrices) just
        # to return early.  The legacy flat keys (region_s, host_rejections,
        # pack counters) stay resolvable at their historical zeros.
        timings = CoopTimings.for_levels(DEFAULT_LEVELS)

        def timed_solve0(p, **kw):
            t = time.perf_counter()
            r = solve_fn(p, **kw)
            timings.solve_s += time.perf_counter() - t
            return r

        if use_variant == "w_cnst":
            problem = problem.with_avoid(jnp.asarray(region_overlap_avoid(cluster)))
        res = timed_solve0(problem)
        res = enforce_cost_budget(cluster, res, np.asarray(problem.assignment0),
                                  cfg.move_cost, cfg.cost_budget, (), timings)
        total = time.perf_counter() - t0
        res.extra["coop_timings"] = _finish_timings(timings, total)
        return CooperationResult(res, use_variant, 1, 0, total, True,
                                 timings=timings)

    assert use_variant == "manual_cnst", use_variant
    levels = cfg.hierarchy(hierarchy).bind(cluster)
    bp = _BreakerPass(cfg.breakers, levels)
    active = bp.active(levels)
    timings = CoopTimings.for_levels(
        [lv.name for lv in levels],
        premask=any(cfg.premask_for(lv.name) for lv in levels),
        round_costs=[])
    if cfg.plan is not None:
        for lv in active:
            bp.relax(lv, cfg.plan, cluster)

    x0_np = np.asarray(problem.assignment0)
    x0_dev = problem.assignment0

    def timed_solve(p, **kw):
        t = time.perf_counter()
        try:
            r = solve_fn(p, **kw)
        except Exception:
            if bp.board is None:
                raise
            # Solver fault under an armed board: fall back to the best
            # mapping already in hand — the warm start when one was passed,
            # else the identity mapping (stay-home was vetted by every
            # level when it was committed).  The never-worse revert
            # fixpoint downstream treats it like any other proposal.
            init = kw.get("init_assignment")
            x_fb = jnp.asarray(init) if init is not None else x0_dev
            r = SolveResult(
                assignment=x_fb, iterations=0, converged=False,
                objective=float(_objective(cluster.problem, x_fb)),
                num_moved=int(np.sum(np.asarray(x_fb) != x0_np)),
                solve_time_s=0.0)
        timings.solve_s += time.perf_counter() - t
        return r

    home_open = np.arange(problem.num_apps)
    if any(cfg.premask_for(lv.name) for lv in levels) or bp.bypassed:
        # Commit every level's feasibility into the solver's mask so those
        # rejection classes never reach the feedback loop.  The home column
        # stays open — the current placement was already accepted by the
        # stack, so "stay" must remain legal even for apps whose data
        # source has since drifted out of budget.  ``cfg.premask`` is a
        # global bool or a per-level mapping (``premask_for``).  A bypassed
        # (OPEN) level folds its conservative fallback premask here even
        # with its premask off: its interactive vet is out of the loop, so
        # the premask is the only constraint it still exerts.
        for lv in levels:
            if not cfg.premask_for(lv.name) and lv.name not in bp.bypassed:
                continue
            t = time.perf_counter()
            pre = bp.premask(lv, problem)
            if pre is not None:
                pre = np.asarray(pre, bool).copy()
                pre[home_open, x0_np] = False
                problem = problem.with_avoid(jnp.asarray(pre))
            timings.add_level_time(lv.name, time.perf_counter() - t)

    # The avoid/ack mask lives on device for the whole pass and is updated
    # by scatter ops; ``base_avoid`` (caller avoids + the premasks + any
    # level feedback escalations) is OR-ed back each round so accumulated
    # feedback can never clear a standing constraint.
    base_avoid = problem.avoid
    avoid = base_avoid
    total_rejections = 0
    x_prev = None                    # continuation fixed-point detector
    res = timed_solve(problem)
    rounds = 1
    while rounds <= cfg.max_rounds and (time.perf_counter() - t0) < wallclock:
        x_np = np.asarray(res.assignment)       # one device->host pull/round
        moved = np.where(x_np != x0_np)[0]
        timings.round_costs.append(
            round(movement_cost_of(x_np, x0_np, cfg.move_cost), 4))

        # Fig. 2 order: each level vets in stack order; a level only sees
        # the candidates that survived the levels above it (with premasks
        # on, the upper vets are no-op passes and packing decides).
        candidates = moved
        round_rej: dict[str, np.ndarray] = {}
        for lv in active:
            rej = _vet_timed(lv, Proposal(x_np, x0_np, candidates), timings,
                             bp)
            if rej.size:
                # Defensive protocol clamp: a level may only reject its own
                # candidates.  An id outside the candidate set (a plugin
                # bug) would otherwise be scattered as avoid[n, x0[n]] —
                # forbidding the app's fallback of staying home.
                rej = rej[np.isin(rej, candidates)]
            round_rej[lv.name] = rej
            timings.add_rejections(lv.name, rej.size)
            if rej.size:
                candidates = candidates[~np.isin(candidates, rej)]
        rej_n = (np.concatenate(list(round_rej.values()))
                 if round_rej else np.empty(0, np.int64))

        if rej_n.size == 0:
            if (res.converged or rounds >= cfg.max_rounds
                    or (time.perf_counter() - t0) >= wallclock
                    or (x_prev is not None and np.array_equal(x_np, x_prev))):
                if cfg.restart_rounds > 0:
                    res = _restart_phase(
                        cluster, problem, res, timed_solve, active,
                        timings, cfg.restart_rounds, t0 + wallclock, x0_np,
                        breakers=bp)
                res = enforce_cost_budget(cluster, res, x0_np, cfg.move_cost,
                                          cfg.cost_budget, active, timings,
                                          breakers=bp)
                total = time.perf_counter() - t0
                timings.rounds = rounds
                bp.finish(timings)
                _collect_level_counters(timings, levels)
                res.extra["coop_timings"] = _finish_timings(timings, total)
                return CooperationResult(res, use_variant, rounds,
                                         total_rejections, total, True,
                                         timings=timings)
            # The proposal was accepted whole, but the solver ran out of
            # sweep budget with improving moves left.  Spend the remaining
            # rounds continuing the search (warm-started, same mask) — the
            # rejection-heavy path gets exactly this extra search for free
            # from its re-solves, so stopping here would trade solution
            # quality for the rounds pre-masking saved.  Every continued
            # proposal is re-vetted at the top of the loop, and an unchanged
            # proposal (an engine at a fixed point, or one that ignores warm
            # starts — greedy) ends the continuation instead of burning the
            # remaining rounds on identical solves.
            x_prev = x_np
            res = timed_solve(problem, init_assignment=res.assignment)
            rounds += 1
            continue

        # Feedback: rejections become avoid constraints; re-solve, warm-
        # started from the vetted subset of the proposal.  Accepted moves are
        # *locked* (the lower level ack'd them — Fig. 2's acknowledgement):
        # the solver may keep them or send them home, but not churn them to a
        # third, unvetted tier.  This makes the unknown-placement set shrink
        # every round, so the loop converges instead of exploring forever.
        # All of it is one compiled scatter step on the standing mask — no
        # [N, T] numpy rebuild, no re-upload, no per-shape recompiles.
        t = time.perf_counter()
        total_rejections += int(rej_n.size)
        acked = candidates                       # ack'd placements
        N = x_np.shape[0]
        rej_pad = _pad_ids(rej_n, N)
        acked_pad = _pad_ids(acked, N)
        avoid, x_accepted = _feedback_update(
            avoid, base_avoid, res.assignment, x0_dev,
            jnp.asarray(rej_pad),
            jnp.asarray(np.take(x_np, rej_pad, mode="clip")),
            jnp.asarray(acked_pad),
            jnp.asarray(np.take(x_np, acked_pad, mode="clip")),
            jnp.asarray(np.take(x0_np, acked_pad, mode="clip")))
        # Level escalation hook: a level may answer a rejection round with
        # extra *standing* avoid rows (beyond the per-(app, dest) scatter).
        state = BusState(round=rounds, x=x_np, x0=x0_np, rejections=round_rej)
        extra_masks = []
        for lv in active:
            extra = bp.feedback(lv, state)
            if extra is not None:
                extra = np.asarray(extra, bool).copy()
                extra[home_open, x0_np] = False  # staying home stays legal
                extra_masks.append(extra)
        if extra_masks:
            mask_dev = jnp.asarray(np.logical_or.reduce(extra_masks))
            base_avoid = base_avoid | mask_dev
            avoid = avoid | mask_dev
        problem = dataclasses.replace(problem, avoid=avoid)
        timings.feedback_s += time.perf_counter() - t

        res = timed_solve(problem, init_assignment=x_accepted)
        rounds += 1

    # Iteration/timeout limit: drop still-rejected moves and re-vet the
    # stack to a fixpoint — including pure-returner home tiers (see
    # _revert_fixpoint; the batched pack already re-vetted tiers whose
    # returners arrived alongside surviving newcomers, this closes the
    # no-movers-left gap).
    x_np = _revert_fixpoint(active, np.asarray(res.assignment), x0_np,
                            timings, breakers=bp)
    x_final = jnp.asarray(x_np)
    # Reverting moves changes the mapping, so the solver's reported
    # objective is stale — recompute it against the *original* problem
    # (the accumulated avoid mask never enters the goal terms).
    res = dataclasses.replace(
        res, assignment=x_final,
        num_moved=int(np.sum(x_np != x0_np)),
        objective=float(_objective(cluster.problem, x_final)))
    res = enforce_cost_budget(cluster, res, x0_np, cfg.move_cost,
                              cfg.cost_budget, active, timings, breakers=bp)
    total = time.perf_counter() - t0
    timings.rounds = rounds
    bp.finish(timings)
    _collect_level_counters(timings, levels)
    res.extra["coop_timings"] = _finish_timings(timings, total)
    return CooperationResult(res, use_variant, rounds, total_rejections,
                             total, False, timings=timings)
