"""Hierarchy co-operation (paper §3.4 + Fig. 2).

Three lower-level-scheduler integration variants for SPTLB:

  * ``no_cnst``     — solve once, ignore lower levels (best balance, worst
                      network latency; Fig. 4/5 baseline),
  * ``w_cnst``      — bake region-awareness into the solver: a tier->tier
                      transition is valid only if the tiers share a majority
                      (>50%) of regions.  Static constraints, "vastly
                      increasing its complexity",
  * ``manual_cnst`` — the paper's proposal: SPTLB proposes a mapping; the
                      region scheduler then the host scheduler accept or
                      reject each placement; rejections return to SPTLB as
                      avoid constraints ("similar to Constraint 3 in section
                      3.2.1") and it re-solves.  "These iterations continue
                      until SPTLB times out or the number of iterations limit
                      is reached."

The region and host schedulers are themselves small, self-contained
schedulers — the paper treats them as black boxes that answer accept/reject,
and that contract is exactly what we implement.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Literal

import jax.numpy as jnp
import numpy as np

from repro.core.problem import Problem
from repro.core.solver_local import SolveResult
from repro.core.telemetry import ClusterState

Variant = Literal["no_cnst", "w_cnst", "manual_cnst"]


class RegionScheduler:
    """Region-preference placement (paper [4]-style shard placement).

    Accepts a placement iff the destination tier has hosts within a latency
    budget of the app's data-source region — "if it isn't possible to keep an
    app near its data source with the given tier, it returns false".
    """

    def __init__(self, cluster: ClusterState, latency_budget_ms: float = 36.0):
        self.cluster = cluster
        self.budget = latency_budget_ms

    def check(self, app: int, tier: int) -> bool:
        """Accept iff *any* host region the tier may place the app in stays
        within the latency budget of the app's data source — the region
        scheduler can steer placement within a tier, but host capacity is
        fungible across the tier's regions, so the guarantee must hold for
        the worst region (max), not the best."""
        c = self.cluster
        dst_regions = np.where(c.tier_regions[tier])[0]
        worst = c.region_latency[c.app_region[app], dst_regions].max()
        return bool(worst <= self.budget)


class HostScheduler:
    """Host allocation: first-fit-decreasing bin-packing into tier hosts.

    Accepts a placement iff every app mapped to the tier still fits after
    packing — "if there are available hosts to allocate the application to,
    it accepts the mapping".  Rejections name the specific apps that failed
    to pack (the ones whose placement SPTLB must avoid).
    """

    def __init__(self, cluster: ClusterState):
        self.cluster = cluster

    def check_tier(self, tier: int, apps: np.ndarray) -> list[int]:
        """Returns the app ids that could NOT be packed into this tier."""
        c = self.cluster
        demand = np.asarray(c.problem.demand)[apps]          # [M, R]
        order = np.argsort(-demand.max(axis=1))              # decreasing
        hosts = np.tile(c.host_capacity, (int(c.hosts_per_tier[tier]), 1))
        rejected: list[int] = []
        for i in order:
            fit = np.all(hosts >= demand[i], axis=1)
            if not fit.any():
                rejected.append(int(apps[i]))
                continue
            h = int(np.argmax(fit))                          # first fit
            hosts[h] -= demand[i]
        return rejected


@dataclasses.dataclass
class CooperationResult:
    result: SolveResult
    variant: str
    feedback_rounds: int
    num_rejections: int
    total_time_s: float
    accepted: bool


def region_overlap_avoid(cluster: ClusterState) -> np.ndarray:
    """w_cnst static constraint: avoid[n, t] unless >50% of the regions of
    app n's current tier overlap with tier t (paper §4.2.2 item 2)."""
    c = cluster
    T = c.tier_regions.shape[0]
    overlap_ok = np.zeros((T, T), bool)
    for a in range(T):
        na = c.tier_regions[a].sum()
        for b in range(T):
            shared = (c.tier_regions[a] & c.tier_regions[b]).sum()
            overlap_ok[a, b] = shared > 0.5 * na
    x0 = np.asarray(c.problem.assignment0)
    return ~overlap_ok[x0]                                   # [N, T]


def cooperate(
    cluster: ClusterState,
    solve_fn: Callable[[Problem], SolveResult],
    variant: Variant = "manual_cnst",
    *,
    max_rounds: int = 8,
    timeout_s: float = float("inf"),
    region_budget_ms: float = 36.0,
) -> CooperationResult:
    """Run one SPTLB balancing pass under the chosen integration variant."""
    t0 = time.perf_counter()
    problem = cluster.problem
    region = RegionScheduler(cluster, latency_budget_ms=region_budget_ms)
    host = HostScheduler(cluster)

    if variant == "w_cnst":
        problem = problem.with_avoid(jnp.asarray(region_overlap_avoid(cluster)))
        res = solve_fn(problem)
        return CooperationResult(res, variant, 1, 0, time.perf_counter() - t0, True)

    if variant == "no_cnst":
        res = solve_fn(problem)
        return CooperationResult(res, variant, 1, 0, time.perf_counter() - t0, True)

    assert variant == "manual_cnst", variant
    x0 = np.asarray(problem.assignment0)
    total_rejections = 0
    res = solve_fn(problem)
    rounds = 1
    x_accepted = None
    while rounds <= max_rounds and (time.perf_counter() - t0) < timeout_s:
        x = np.asarray(res.assignment)
        moved = np.where(x != x0)[0]
        rejected_pairs: list[tuple[int, int]] = []

        # Fig. 2 order: region scheduler first...
        region_ok = np.ones(len(moved), bool)
        for i, n in enumerate(moved):
            if not region.check(int(n), int(x[n])):
                rejected_pairs.append((int(n), int(x[n])))
                region_ok[i] = False
        # ...then host allocation for the placements the region level kept.
        surviving = moved[region_ok]
        for t in np.unique(x[surviving]) if len(surviving) else []:
            apps_t = np.concatenate([
                np.where((x == t) & (x == x0))[0],           # incumbents
                surviving[x[surviving] == t],                # newcomers
            ])
            for n in host.check_tier(int(t), apps_t):
                if x[n] != x0[n]:                            # only newcomers bounce
                    rejected_pairs.append((int(n), int(x[n])))

        if not rejected_pairs:
            return CooperationResult(res, variant, rounds, total_rejections,
                                     time.perf_counter() - t0, True)

        # Feedback: rejections become avoid constraints; re-solve, warm-
        # started from the vetted subset of the proposal.  Accepted moves are
        # *locked* (the lower level ack'd them — Fig. 2's acknowledgement):
        # the solver may keep them or send them home, but not churn them to a
        # third, unvetted tier.  This makes the unknown-placement set shrink
        # every round, so the loop converges instead of exploring forever.
        total_rejections += len(rejected_pairs)
        extra = np.zeros((problem.num_apps, problem.num_tiers), bool)
        x_accepted = x.copy()
        rejected_apps = {n for n, _ in rejected_pairs}
        for n, t in rejected_pairs:
            extra[n, t] = True
            x_accepted[n] = x0[n]
        for n in moved:
            n = int(n)
            if n not in rejected_apps:                       # ack'd placement
                extra[n, :] = True
                extra[n, x[n]] = False
                extra[n, x0[n]] = False
        problem = problem.with_avoid(jnp.asarray(extra))
        res = solve_fn(problem, init_assignment=jnp.asarray(x_accepted))
        rounds += 1

    # Iteration/timeout limit: drop still-rejected moves (stay-home is safe —
    # the app's original placement was already accepted by the lower levels).
    x = np.asarray(res.assignment).copy()
    for n in np.where(x != x0)[0]:
        if not region.check(int(n), int(x[n])):
            x[n] = x0[n]
    for t in np.unique(x[x != x0]):
        apps_t = np.where(x == t)[0]
        for n in host.check_tier(int(t), apps_t):
            if x[n] != x0[n]:
                x[n] = x0[n]
    res = dataclasses.replace(
        res, assignment=jnp.asarray(x),
        num_moved=int(np.sum(x != x0)))
    return CooperationResult(res, variant, rounds, total_rejections,
                             time.perf_counter() - t0, False)
