"""Hierarchy co-operation (paper §3.4 + Fig. 2).

Three lower-level-scheduler integration variants for SPTLB:

  * ``no_cnst``     — solve once, ignore lower levels (best balance, worst
                      network latency; Fig. 4/5 baseline),
  * ``w_cnst``      — bake region-awareness into the solver: a tier->tier
                      transition is valid only if the tiers share a majority
                      (>50%) of regions.  Static constraints, "vastly
                      increasing its complexity",
  * ``manual_cnst`` — the paper's proposal: SPTLB proposes a mapping; the
                      region scheduler then the host scheduler accept or
                      reject each placement; rejections return to SPTLB as
                      avoid constraints ("similar to Constraint 3 in section
                      3.2.1") and it re-solves.  "These iterations continue
                      until SPTLB times out or the number of iterations limit
                      is reached."

The region and host schedulers are themselves small, self-contained
schedulers — the paper treats them as black boxes that answer accept/reject,
and that contract is exactly what we implement.

Device-resident feedback rounds: a ``manual_cnst`` pass used to leave the
device three times per round (per-tier host packing dispatches, numpy avoid
matrices rebuilt and re-uploaded, region vetting of moves the region level
was always going to reject).  The loop is now structured so the device does
the heavy phases and the host only routes ids:

  * **region pre-masking** (``premask_region``, default on): the region
    scheduler's full [N, T] feasibility matrix is folded into the problem's
    avoid mask *before the first solve*, so the solver never proposes a
    region-infeasible move and the region-rejection class disappears from
    the feedback loop entirely (staying home is always allowed — the current
    placement was accepted by the lower levels by definition),
  * **all-tier batched packing** (``HostScheduler.check_tiers``): the
    proposal's apps are segment-sorted by destination tier into one padded
    [T, M_b, R] membership tensor and every tier is packed in a single
    vmapped FFD dispatch (``kernels.pack.pack_ffd_tiers``) — one compiled
    executable per (app-bucket, host-bucket) instead of one per tier size,
    bit-identical accept/reject to the per-tier scan,
  * **a resident round loop**: the avoid/ack mask and warm-start assignment
    stay on device across rounds and are updated with scatter ops instead of
    rebuilding numpy matrices and re-converting each round.

``cooperate`` reports the per-phase wall-clock split (solve / region / host
glue / pack / feedback), per-round pack dispatch and retrace counters, and
the region/host rejection breakdown in ``CooperationResult.timings`` and
``SolveResult.extra["coop_timings"]``.  ``host_side_frac`` is everything
that is neither the solver nor the pack dispatches, as a fraction of the
total — driven from 0.53 (seed) to 0.21 (PR 1) to <=0.03 here.  Note the
definition tightened in this PR: PR 1 counted pack time as host-side
(packing was dispatched from a per-tier Python loop); now that packing is
a single compiled device scan per round it counts device-side, and under
PR 1's everything-but-solve definition the premasked N=10_000 pass still
measures ~0.16 — both the glue and the classification improved.

Precomputes that depend only on cluster geometry (the region worst-latency
matrix, the region feasibility matrix, the w_cnst overlap mask) are memoized
on ``ClusterState._cache`` so controller ticks stop paying them on every
``cooperate``/``balance`` call; any ``dataclasses.replace`` of the cluster
(capacity events, applied rebalances) resets the cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.goals import objective as _objective
from repro.core.planner import movement_cost_of
from repro.core.problem import Problem, bucket_size
from repro.core.solver_local import SolveResult
from repro.core.telemetry import ClusterState
from repro.kernels.pack import pack_ffd, pack_ffd_tiers, pack_trace_count

Variant = Literal["no_cnst", "w_cnst", "manual_cnst"]

# The region scheduler's default latency budget (ms): placements must keep
# an app within this worst-case latency of its data-source region.
REGION_LATENCY_BUDGET_MS = 36.0


class RegionScheduler:
    """Region-preference placement (paper [4]-style shard placement).

    Accepts a placement iff the destination tier has hosts within a latency
    budget of the app's data-source region — "if it isn't possible to keep an
    app near its data source with the given tier, it returns false".

    ``latency_budget_ms`` may be a scalar (every app gets the same budget)
    or an f32[N] per-app array — the planner's maintenance placement mode
    relaxes the budget for residents evacuating a declared deep drain
    (``core.planner``), and the relaxation must bind proposal vetting, the
    premask, and the revert paths identically, so it lives here.
    """

    def __init__(self, cluster: ClusterState,
                 latency_budget_ms=REGION_LATENCY_BUDGET_MS):
        self.cluster = cluster
        if np.ndim(latency_budget_ms) == 0:
            self.budget = float(latency_budget_ms)
            self._budget_per_app = None
        else:
            self.budget = None
            self._budget_per_app = np.asarray(latency_budget_ms, np.float32)
        self._worst_ms = self._worst_ms_matrix(cluster)

    @staticmethod
    def _worst_ms_matrix(cluster: ClusterState) -> np.ndarray:
        """[G, T] worst-case latency from each source region to each tier,
        memoized on the cluster (it depends only on geometry, not on the
        assignment, so every scheduler instance over this cluster shares it).

        Host capacity is fungible across a tier's regions, so the guarantee
        must hold for the worst region the tier may place the app in (max),
        not the best.  One vectorized max replaces the per-(app, tier)
        Python rescans of ``region_latency``.
        """
        cache = cluster._cache
        if "region_worst_ms" not in cache:
            c = cluster
            worst = np.where(
                c.tier_regions.T[None, :, :],              # [1, G, T] region in tier?
                c.region_latency[:, :, None],              # [G, G, 1]
                -np.inf,
            ).max(axis=1)                                  # [G, T]
            # A tier with no regions has no hosts anywhere near any data
            # source: reject placements into it (the pre-vectorization code
            # raised on the empty reduction; -inf would silently *accept*).
            worst[:, ~c.tier_regions.any(axis=1)] = np.inf
            cache["region_worst_ms"] = worst
        return cache["region_worst_ms"]

    def _budget_of(self, apps) -> np.ndarray | float:
        if self._budget_per_app is None:
            return self.budget
        return self._budget_per_app[apps]

    def check(self, app: int, tier: int) -> bool:
        """Accept iff the tier's worst region stays within the budget."""
        return bool(self._worst_ms[self.cluster.app_region[app], tier]
                    <= self._budget_of(app))

    def check_many(self, apps: np.ndarray, tiers: np.ndarray) -> np.ndarray:
        """Vectorized ``check`` over (app, tier) pairs -> bool[len(apps)]."""
        apps = np.asarray(apps, np.int64)
        tiers = np.asarray(tiers, np.int64)
        return (self._worst_ms[self.cluster.app_region[apps], tiers]
                <= self._budget_of(apps))

    def feasibility_matrix(self) -> np.ndarray:
        """bool[N, T]: the full region-feasibility matrix for every app.

        Memoized per (cluster, budget) — this is what ``premask_region``
        folds into the solver's avoid mask every cooperation pass.  Per-app
        budget arrays (maintenance placement mode) skip the memo: they are
        derived per control round, and one cooperation pass reads the
        matrix once.
        """
        if self._budget_per_app is not None:
            return (self._worst_ms[self.cluster.app_region]
                    <= self._budget_per_app[:, None])
        key = ("region_feasibility", float(self.budget))
        cache = self.cluster._cache
        if key not in cache:
            cache[key] = self._worst_ms[self.cluster.app_region] <= self.budget
        return cache[key]


class HostScheduler:
    """Host allocation: first-fit-decreasing bin-packing into tier hosts.

    Accepts a placement iff every app mapped to the tier still fits after
    packing — "if there are available hosts to allocate the application to,
    it accepts the mapping".  Rejections name the specific apps that failed
    to pack (the ones whose placement SPTLB must avoid).

    Packing runs on device (``kernels.pack``): the sorted demand axis is
    bucket-padded to a power-of-two length and the host-bin axis is padded
    to one power-of-two for the whole cluster with the live count traced, so
    *all* tiers — whatever their host count — share one compiled executable
    per app bucket.  ``check_tiers`` packs every tier of a proposal in a
    single vmapped dispatch; ``check_tier`` is the legacy one-tier entry
    point with identical decisions.  The instance accumulates pack dispatch
    / retrace / wall-clock counters for ``CooperationResult.timings``.
    """

    def __init__(self, cluster: ClusterState):
        self.cluster = cluster
        self._hosts_pad = bucket_size(int(cluster.hosts_per_tier.max()),
                                      minimum=16)
        # Pack-side constants, memoized on the cluster like the region
        # matrices: the host-side demand copy (one device->host transfer
        # per cluster, not per tick) and the device-side capacity / host
        # count arrays (re-used by every dispatch instead of re-uploaded).
        cache = cluster._cache
        if "host_pack_consts" not in cache:
            cache["host_pack_consts"] = (
                np.asarray(cluster.problem.demand),            # [N, R]
                jnp.asarray(cluster.host_capacity),            # f32[R]
                jnp.asarray(cluster.hosts_per_tier.astype(np.int32)))
        self._demand, self._cap_dev, self._hosts_dev = cache["host_pack_consts"]
        self.pack_s = 0.0
        self.pack_dispatches = 0
        self.pack_retraces = 0
        # Residents (apps already home) of a *force-packed* tier that failed
        # to pack.  They have nowhere better to go — home is the fallback of
        # every revert path — but they must be observable instead of the
        # tier being silently trusted to absorb its returners.  A set of
        # ids, not a counter: revert fixpoints and restart re-vets can
        # force-pack the same tier repeatedly.
        self._resident_overflow_ids: set[int] = set()

    @property
    def resident_overflows(self) -> int:
        """Distinct residents that failed a force re-pack."""
        return len(self._resident_overflow_ids)

    def _dispatch(self, fn, *args, **kw) -> np.ndarray:
        t = time.perf_counter()
        before = pack_trace_count()
        out = np.asarray(fn(*args, **kw))          # asarray syncs the device
        self.pack_retraces += pack_trace_count() - before
        self.pack_dispatches += 1
        self.pack_s += time.perf_counter() - t
        return out

    def check_tier(self, tier: int, apps: np.ndarray) -> list[int]:
        """Returns the app ids that could NOT be packed into this tier."""
        c = self.cluster
        apps = np.asarray(apps, np.int64)
        if apps.size == 0:
            return []
        # Canonical order: ascending id, then a *stable* decreasing sort —
        # ties on max demand resolve identically to ``check_tiers``'s
        # stable (tier, -dmax) lexsort, so the two paths stay bit-identical
        # whatever order the caller passed the membership in.
        apps = np.sort(apps)
        demand = self._demand[apps]                          # [M, R]
        order = np.argsort(-demand.max(axis=1), kind="stable")
        M = apps.size
        Mb = bucket_size(M, minimum=128)
        d_sorted = np.zeros((Mb, demand.shape[1]), demand.dtype)
        d_sorted[:M] = demand[order]
        rejected = self._dispatch(
            pack_ffd, jnp.asarray(d_sorted), self._cap_dev,
            jnp.int32(c.hosts_per_tier[tier]),
            num_hosts_pad=self._hosts_pad)[:M]
        return [int(a) for a in apps[order][rejected]]

    def check_tiers(self, x: np.ndarray, x0: np.ndarray,
                    newcomers: np.ndarray,
                    force_tiers: np.ndarray | None = None) -> np.ndarray:
        """Batched accept/reject for a whole proposal in one device call.

        Tier t's membership is its incumbents (``x == x0 == t``) plus the
        ``newcomers`` moved into t; only tiers receiving at least one
        newcomer are packed (identical tier set and per-tier membership to
        the per-tier loop this replaces).  The membership is segment-sorted
        by (destination tier, decreasing demand) and scattered into a padded
        [T, M_b, R] tensor for ``pack_ffd_tiers``.  Returns the *newcomer*
        app ids whose placement failed to pack, i64[K] (incumbents never
        bounce — their current placement was already accepted).

        ``force_tiers`` adds tiers to pack even when no newcomer targets
        them — the revert paths use it for home tiers whose only change is
        returning apps (FFD is not monotone under item removal, so a
        membership that *shrank* back toward the original can still fail to
        pack).  Residents of a forced tier that fail are counted in
        ``resident_overflows`` (their placement is already the fallback).
        """
        c = self.cluster
        T = len(c.hosts_per_tier)
        x = np.asarray(x, np.int64)
        x0 = np.asarray(x0, np.int64)
        newcomers = np.asarray(newcomers, np.int64)
        force = (np.asarray(force_tiers, np.int64)
                 if force_tiers is not None else np.empty(0, np.int64))
        if newcomers.size == 0 and force.size == 0:
            return newcomers
        is_new = np.zeros(x.shape[0], bool)
        is_new[newcomers] = True
        active = np.zeros(T, bool)
        active[x[newcomers]] = True
        active[force] = True
        member = active[x] & ((x == x0) | is_new)
        ids = np.where(member)[0]
        if ids.size == 0:
            return np.empty(0, np.int64)
        demand = self._demand                                # [N, R]
        dmax = demand[ids].max(axis=1)
        order = np.lexsort((-dmax, x[ids]))                  # tier, then FFD order
        ids = ids[order]
        tiers = x[ids]
        counts = np.bincount(tiers, minlength=T)
        Mb = bucket_size(int(counts.max()), minimum=128)
        pos = np.arange(ids.size) - (np.cumsum(counts) - counts)[tiers]
        dem = np.zeros((T, Mb, demand.shape[1]), demand.dtype)
        dem[tiers, pos] = demand[ids]
        slot_app = np.full((T, Mb), -1, np.int64)
        slot_app[tiers, pos] = ids
        rejected = self._dispatch(
            pack_ffd_tiers, jnp.asarray(dem), self._cap_dev, self._hosts_dev,
            num_hosts_pad=self._hosts_pad)
        rej = slot_app[rejected & (slot_app >= 0)]
        if force.size:
            # Only the force-packed tiers feed the overflow set: a hot
            # tier's incumbents failing a routine vet is the pre-existing
            # overload the seed already tolerates, not a returner gap.
            in_force = np.zeros(T, bool)
            in_force[force] = True
            self._resident_overflow_ids.update(
                rej[(x[rej] == x0[rej]) & in_force[x[rej]]].tolist())
        return rej[x[rej] != x0[rej]]                        # newcomers bounce


@dataclasses.dataclass
class CooperationResult:
    result: SolveResult
    variant: str
    feedback_rounds: int
    num_rejections: int
    total_time_s: float
    accepted: bool
    # Per-phase wall-clock split: solve_s (device solver), pack_s (device
    # FFD dispatches), region_s / host_s (lower-level scheduler glue),
    # feedback_s (avoid-mask scatter updates); plus counters: rounds,
    # region_rejections / host_rejections, pack_dispatches / pack_retraces,
    # and premask (whether region pre-masking was active).  host_side_frac
    # is everything except the device phases (solve_s + pack_s) as a
    # fraction of the total.
    timings: dict = dataclasses.field(default_factory=dict)


def region_overlap_avoid(cluster: ClusterState) -> np.ndarray:
    """w_cnst static constraint: avoid[n, t] unless >50% of the regions of
    app n's current tier overlap with tier t (paper §4.2.2 item 2).

    Memoized on the cluster — it depends on geometry and ``assignment0``,
    both of which only change through ``dataclasses.replace`` (which resets
    the cache).
    """
    cache = cluster._cache
    if "region_overlap_avoid" not in cache:
        c = cluster
        regions = c.tier_regions.astype(np.int64)
        shared = regions @ regions.T                         # [T, T]
        na = regions.sum(axis=1)
        overlap_ok = shared > 0.5 * na[:, None]
        x0 = np.asarray(c.problem.assignment0)
        cache["region_overlap_avoid"] = ~overlap_ok[x0]      # [N, T]
    return cache["region_overlap_avoid"]


@jax.jit
def _feedback_update(avoid, base_avoid, assignment, x0, rej, rej_dst,
                     acked, acked_dst, acked_home):
    """One compiled feedback step: scatter the round's rejections and
    acknowledgements into the standing avoid mask and build the warm-start
    assignment with the rejected moves sent home.

    ``rej``/``acked`` are id arrays bucket-padded with the out-of-range
    sentinel N, and every scatter uses ``mode="drop"`` so the padding rows
    vanish — one executable per (N-bucket, id-bucket) pair instead of a
    fresh eager dispatch chain for every distinct rejection count.
    """
    avoid = avoid.at[rej, rej_dst].set(True, mode="drop")
    avoid = avoid.at[acked, :].set(True, mode="drop")
    avoid = avoid.at[acked, acked_dst].set(False, mode="drop")
    avoid = avoid.at[acked, acked_home].set(False, mode="drop")
    # Caller avoids + the premask are OR-ed back so accumulated feedback can
    # never clear a standing constraint.
    avoid = avoid | base_avoid
    x_acc = assignment.at[rej].set(x0.at[rej].get(mode="clip"), mode="drop")
    return avoid, x_acc


def _pad_ids(ids: np.ndarray, sentinel: int, minimum: int = 32) -> np.ndarray:
    """Pad an id array to a power-of-two bucket with ``sentinel`` (== N,
    out of range) so ``_feedback_update`` sees O(log N) distinct shapes."""
    b = bucket_size(max(ids.size, 1), minimum=minimum)
    out = np.full(b, sentinel, np.int32)
    out[:ids.size] = ids
    return out


def _finish_timings(timings: dict, total_s: float) -> dict:
    # Device phases are the solver and the compiled pack dispatches;
    # everything else counts as host-side — the per-phase counters plus
    # untimed glue (membership builds, np/jnp conversions), so the fraction
    # cannot undercount host work.
    timings["total_s"] = total_s
    device_s = timings.get("solve_s", 0.0) + timings.get("pack_s", 0.0)
    timings["host_side_frac"] = (
        max(0.0, total_s - device_s) / total_s if total_s > 0 else 0.0)
    return timings


def _collect_pack_counters(timings: dict, host: HostScheduler | None) -> None:
    if host is None:                 # variant never packed anything
        timings.update(pack_s=0.0, pack_dispatches=0, pack_retraces=0,
                       resident_overflows=0)
        return
    timings["pack_s"] = host.pack_s
    # check_tier(s) wall-clock minus the device dispatches = host-side glue.
    timings["host_s"] = max(0.0, timings["host_s"] - host.pack_s)
    timings["pack_dispatches"] = host.pack_dispatches
    timings["pack_retraces"] = host.pack_retraces
    timings["resident_overflows"] = host.resident_overflows


def _revert_unvetted(x_np: np.ndarray, x0_np: np.ndarray,
                     region: RegionScheduler, host: HostScheduler,
                     timings: dict) -> np.ndarray:
    """Drop region/host-unvetted moves (stay-home is safe — the original
    placement was accepted by the lower levels) and re-pack to a fixpoint.

    Home tiers whose only change is their *returners* are force re-packed
    too: the seed trusted them to absorb returners unchecked, but FFD is
    not monotone under item removal, so even a membership that shrank back
    toward the original can overflow.  A forced tier's residents that still
    fail have no better placement than home; they are surfaced through
    ``HostScheduler.resident_overflows`` instead of being silently trusted.
    Each re-pack iteration reverts at least one mover, so it terminates.
    """
    x_np = x_np.copy()
    t = time.perf_counter()
    moved = np.where(x_np != x0_np)[0]
    bad = moved[~region.check_many(moved, x_np[moved])]
    x_np[bad] = x0_np[bad]
    timings["region_s"] += time.perf_counter() - t
    t = time.perf_counter()
    force = np.unique(x0_np[bad]) if bad.size else np.empty(0, np.int64)
    movers = np.where(x_np != x0_np)[0]
    while movers.size or force.size:
        rej = host.check_tiers(x_np, x0_np, movers, force_tiers=force)
        if rej.size == 0:
            break
        x_np[rej] = x0_np[rej]
        force = np.unique(x0_np[rej])
        movers = np.where(x_np != x0_np)[0]
    timings["host_s"] += time.perf_counter() - t
    return x_np


def enforce_cost_budget(cluster: ClusterState, res: SolveResult,
                         x0_np: np.ndarray, move_cost, cost_budget: float,
                         host: HostScheduler | None, timings: dict) -> SolveResult:
    """Price the final mapping and trim it to the round's movement budget.

    Movement is the §3.2.1 goal-8 downtime the paper prices; Madsen et al.
    price live reconfiguration explicitly.  Every vetted mapping is priced
    (``timings["movement_cost"]``); when the caller hands down a finite
    ``cost_budget`` and the mapping exceeds it, moves are reverted until it
    fits.  Moves that rescue an SLO-stranded incumbent (home tier no longer
    eligible for the app's class) are kept first — their revert costs
    violation ticks, not just balance — then cheap moves before expensive
    ones, so the budget buys as much placement repair as possible.

    Reverting sends apps home, and home tiers can overflow on returners
    (FFD is not monotone under item removal), so trimmed mappings re-run
    the host-packing fixpoint with the affected home tiers force-packed —
    the same contract as ``_revert_unvetted``.  Trimming never *adds* moves,
    so the budget holds after the fixpoint too.
    """
    x_np = np.asarray(res.assignment)
    total = movement_cost_of(x_np, x0_np, move_cost)
    timings["movement_cost"] = total
    if total <= cost_budget + 1e-9:
        return res
    t = time.perf_counter()
    x_np = x_np.copy()
    moved = np.where(x_np != x0_np)[0]
    per = (np.ones(moved.size, np.float32) if move_cost is None
           else np.asarray(move_cost)[moved])
    p = cluster.problem
    slo_ok_home = np.asarray(p.slo_allowed)[
        x0_np[moved], np.asarray(p.slo)[moved]]
    # lexsort: last key is primary — strand-fixers (slo_ok_home False) first,
    # then ascending per-move cost within each class.
    order = np.lexsort((per, slo_ok_home))
    keep = np.zeros(moved.size, bool)
    spent = 0.0
    for i in order:
        if spent + per[i] <= cost_budget + 1e-9:
            spent += per[i]
            keep[i] = True
    reverted = moved[~keep]
    x_np[reverted] = x0_np[reverted]
    timings["budget_trimmed"] = (timings.get("budget_trimmed", 0)
                                 + int(reverted.size))
    if host is not None and reverted.size:
        force = np.unique(x0_np[reverted])
        movers = np.where(x_np != x0_np)[0]
        while movers.size or force.size:
            rej = host.check_tiers(x_np, x0_np, movers, force_tiers=force)
            if rej.size == 0:
                break
            x_np[rej] = x0_np[rej]
            force = np.unique(x0_np[rej])
            movers = np.where(x_np != x0_np)[0]
    timings["host_s"] = timings.get("host_s", 0.0) + (time.perf_counter() - t)
    x_final = jnp.asarray(x_np)
    timings["movement_cost"] = movement_cost_of(x_np, x0_np, move_cost)
    return dataclasses.replace(
        res, assignment=x_final,
        num_moved=int(np.sum(x_np != x0_np)),
        objective=float(_objective(cluster.problem, x_final)))


def _restart_phase(cluster: ClusterState, problem: Problem, res: SolveResult,
                   timed_solve, region: RegionScheduler, host: HostScheduler,
                   timings: dict, restart_rounds: int, deadline: float,
                   x0_np: np.ndarray) -> SolveResult:
    """Perturbation restarts after an accepted fixed point (ROADMAP knob).

    The unmasked feedback loop gets diversification for free: every
    rejection round re-solves from a perturbed warm start.  Pre-masking
    removes those rounds, so at small N it can land in a worse local
    optimum at a *better* wall-clock.  Each restart sends a random third of
    the current movers home, re-solves warm-started under the same standing
    avoid mask, re-vets the proposal (region + host, exactly like the
    exhausted-rounds path), and keeps the best vetted objective — so the
    result can never get worse, only cost extra solves.
    """
    x_best = np.asarray(res.assignment).copy()
    obj_best = float(_objective(cluster.problem, jnp.asarray(x_best)))
    rng = np.random.default_rng(x_best.size)     # deterministic per problem
    attempts = improved = 0
    for _ in range(restart_rounds):
        if time.perf_counter() >= deadline:
            break
        moved = np.where(x_best != x0_np)[0]
        if moved.size == 0:
            break
        sel = rng.choice(moved, size=max(1, moved.size // 3), replace=False)
        x_pert = x_best.copy()
        x_pert[sel] = x0_np[sel]
        attempts += 1
        r = timed_solve(problem, init_assignment=jnp.asarray(
            x_pert.astype(np.int32)))
        x_r = _revert_unvetted(np.asarray(r.assignment), x0_np, region, host,
                               timings)
        obj_r = float(_objective(cluster.problem, jnp.asarray(x_r)))
        if obj_r < obj_best - 1e-9:
            obj_best, x_best = obj_r, x_r
            improved += 1
    timings["restarts"] = attempts
    timings["restart_improved"] = improved
    if improved:
        res = dataclasses.replace(
            res, assignment=jnp.asarray(x_best), objective=obj_best,
            num_moved=int(np.sum(x_best != x0_np)))
    return res


def cooperate(
    cluster: ClusterState,
    solve_fn: Callable[[Problem], SolveResult],
    variant: Variant = "manual_cnst",
    *,
    max_rounds: int = 8,
    timeout_s: float = float("inf"),
    region_budget_ms=REGION_LATENCY_BUDGET_MS,
    premask_region: bool = True,
    restart_rounds: int = 0,
    move_cost: np.ndarray | None = None,
    cost_budget: float = float("inf"),
) -> CooperationResult:
    """Run one SPTLB balancing pass under the chosen integration variant.

    ``premask_region`` (manual_cnst only, default on) folds the region
    scheduler's feasibility matrix into the avoid mask before the first
    solve: the solver stops proposing region-infeasible moves, the region
    level stops rejecting, and the feedback loop converges in fewer rounds.
    The final mapping is vetted by exactly the same region/host checks
    either way, so the knob trades search-space pruning for rounds, never
    feasibility.

    ``restart_rounds`` (manual_cnst only, default 0) adds perturbation
    restarts after the pass reaches an accepted fixed point — the
    diversification the unmasked path got for free from its rejection
    rounds.  Every restart is fully re-vetted and only adopted if its
    objective improves, so the knob spends solves, never quality.

    ``move_cost``/``cost_budget`` price movement (Madsen-style
    reconfiguration costing — ``core.planner.move_costs``): every returned
    mapping's total cost lands in ``timings["movement_cost"]`` (per-round
    proposal costs in ``timings["round_costs"]``), and a finite budget
    trims the final mapping to fit (``enforce_cost_budget``), preferring
    moves that rescue SLO-stranded incumbents.

    ``region_budget_ms`` may be an f32[N] per-app array (maintenance
    placement mode — ``core.planner.PlanOutlook.relax_home_tiers``): the
    premask, the per-round vet, and the revert fixpoint then all share the
    same relaxed region contract.
    """
    t0 = time.perf_counter()
    problem = cluster.problem
    timings = {"solve_s": 0.0, "region_s": 0.0, "host_s": 0.0,
               "feedback_s": 0.0, "rounds": 1,
               "region_rejections": 0, "host_rejections": 0,
               "restarts": 0, "restart_improved": 0,
               "movement_cost": 0.0, "budget_trimmed": 0, "round_costs": [],
               "premask": bool(premask_region) and variant == "manual_cnst"}

    def timed_solve(p, **kw):
        t = time.perf_counter()
        r = solve_fn(p, **kw)
        timings["solve_s"] += time.perf_counter() - t
        return r

    if variant in ("no_cnst", "w_cnst"):
        # Neither variant consults the lower-level schedulers, so don't pay
        # their precomputes (the host scheduler's demand transfer, the
        # region matrices) just to return early.
        if variant == "w_cnst":
            problem = problem.with_avoid(jnp.asarray(region_overlap_avoid(cluster)))
        res = timed_solve(problem)
        res = enforce_cost_budget(cluster, res, np.asarray(problem.assignment0),
                                   move_cost, cost_budget, None, timings)
        total = time.perf_counter() - t0
        _collect_pack_counters(timings, None)
        res.extra["coop_timings"] = _finish_timings(timings, total)
        return CooperationResult(res, variant, 1, 0, total, True,
                                 timings=timings)

    assert variant == "manual_cnst", variant
    region = RegionScheduler(cluster, latency_budget_ms=region_budget_ms)
    host = HostScheduler(cluster)
    x0_np = np.asarray(problem.assignment0)
    x0_dev = problem.assignment0
    if timings["premask"]:
        # Tentpole (1): commit region feasibility into the solver's mask so
        # the region-rejection class never reaches the feedback loop.  The
        # home column stays open — the current placement was already
        # accepted by the lower levels, so "stay" must remain legal even
        # for apps whose data source has since drifted out of budget.
        t = time.perf_counter()
        pre = ~region.feasibility_matrix()
        pre[np.arange(problem.num_apps), x0_np] = False
        problem = problem.with_avoid(jnp.asarray(pre))
        timings["region_s"] += time.perf_counter() - t

    # Tentpole (3): the avoid/ack mask lives on device for the whole pass
    # and is updated by scatter ops; ``base_avoid`` (caller avoids + the
    # premask) is OR-ed back each round so accumulated feedback can never
    # clear a standing constraint.
    base_avoid = problem.avoid
    avoid = base_avoid
    total_rejections = 0
    x_prev = None                    # continuation fixed-point detector
    res = timed_solve(problem)
    rounds = 1
    while rounds <= max_rounds and (time.perf_counter() - t0) < timeout_s:
        x_np = np.asarray(res.assignment)       # one device->host pull/round
        moved = np.where(x_np != x0_np)[0]
        timings["round_costs"].append(
            round(movement_cost_of(x_np, x0_np, move_cost), 4))

        # Fig. 2 order: region scheduler first (one vectorized gather; with
        # the premask on this is a no-op vet that always passes)...
        t = time.perf_counter()
        region_ok = region.check_many(moved, x_np[moved])
        rej_region = moved[~region_ok]
        surviving = moved[region_ok]
        timings["region_s"] += time.perf_counter() - t

        # ...then host allocation: every destination tier packed in one
        # batched device dispatch (tentpole 2).
        t = time.perf_counter()
        rej_host = host.check_tiers(x_np, x0_np, surviving)
        timings["host_s"] += time.perf_counter() - t

        timings["region_rejections"] += int(rej_region.size)
        timings["host_rejections"] += int(rej_host.size)
        rej_n = np.concatenate([rej_region, rej_host])
        if rej_n.size == 0:
            if (res.converged or rounds >= max_rounds
                    or (time.perf_counter() - t0) >= timeout_s
                    or (x_prev is not None and np.array_equal(x_np, x_prev))):
                if restart_rounds > 0:
                    res = _restart_phase(
                        cluster, problem, res, timed_solve, region, host,
                        timings, restart_rounds, t0 + timeout_s, x0_np)
                res = enforce_cost_budget(cluster, res, x0_np, move_cost,
                                           cost_budget, host, timings)
                total = time.perf_counter() - t0
                timings["rounds"] = rounds
                _collect_pack_counters(timings, host)
                res.extra["coop_timings"] = _finish_timings(timings, total)
                return CooperationResult(res, variant, rounds,
                                         total_rejections, total, True,
                                         timings=timings)
            # The proposal was accepted whole, but the solver ran out of
            # sweep budget with improving moves left.  Spend the remaining
            # rounds continuing the search (warm-started, same mask) — the
            # rejection-heavy path gets exactly this extra search for free
            # from its re-solves, so stopping here would trade solution
            # quality for the rounds pre-masking saved.  Every continued
            # proposal is re-vetted at the top of the loop, and an unchanged
            # proposal (an engine at a fixed point, or one that ignores warm
            # starts — greedy) ends the continuation instead of burning the
            # remaining rounds on identical solves.
            x_prev = x_np
            res = timed_solve(problem, init_assignment=res.assignment)
            rounds += 1
            continue

        # Feedback: rejections become avoid constraints; re-solve, warm-
        # started from the vetted subset of the proposal.  Accepted moves are
        # *locked* (the lower level ack'd them — Fig. 2's acknowledgement):
        # the solver may keep them or send them home, but not churn them to a
        # third, unvetted tier.  This makes the unknown-placement set shrink
        # every round, so the loop converges instead of exploring forever.
        # All of it is one compiled scatter step on the standing mask — no
        # [N, T] numpy rebuild, no re-upload, no per-shape recompiles.
        t = time.perf_counter()
        total_rejections += int(rej_n.size)
        acked = surviving[~np.isin(surviving, rej_host)]     # ack'd placements
        N = x_np.shape[0]
        rej_pad = _pad_ids(rej_n, N)
        acked_pad = _pad_ids(acked, N)
        avoid, x_accepted = _feedback_update(
            avoid, base_avoid, res.assignment, x0_dev,
            jnp.asarray(rej_pad),
            jnp.asarray(np.take(x_np, rej_pad, mode="clip")),
            jnp.asarray(acked_pad),
            jnp.asarray(np.take(x_np, acked_pad, mode="clip")),
            jnp.asarray(np.take(x0_np, acked_pad, mode="clip")))
        problem = dataclasses.replace(problem, avoid=avoid)
        timings["feedback_s"] += time.perf_counter() - t

        res = timed_solve(problem, init_assignment=x_accepted)
        rounds += 1

    # Iteration/timeout limit: drop still-rejected moves and re-pack to a
    # fixpoint — including pure-returner home tiers (see _revert_unvetted;
    # the batched pack already re-vetted tiers whose returners arrived
    # alongside surviving newcomers, this closes the no-movers-left gap).
    x_np = _revert_unvetted(np.asarray(res.assignment), x0_np, region, host,
                            timings)
    x_final = jnp.asarray(x_np)
    # Reverting moves changes the mapping, so the solver's reported
    # objective is stale — recompute it against the *original* problem
    # (the accumulated avoid mask never enters the goal terms).
    res = dataclasses.replace(
        res, assignment=x_final,
        num_moved=int(np.sum(x_np != x0_np)),
        objective=float(_objective(cluster.problem, x_final)))
    res = enforce_cost_budget(cluster, res, x0_np, move_cost, cost_budget,
                               host, timings)
    total = time.perf_counter() - t0
    timings["rounds"] = rounds
    _collect_pack_counters(timings, host)
    res.extra["coop_timings"] = _finish_timings(timings, total)
    return CooperationResult(res, variant, rounds, total_rejections,
                             total, False, timings=timings)
