"""Hierarchy co-operation (paper §3.4 + Fig. 2).

Three lower-level-scheduler integration variants for SPTLB:

  * ``no_cnst``     — solve once, ignore lower levels (best balance, worst
                      network latency; Fig. 4/5 baseline),
  * ``w_cnst``      — bake region-awareness into the solver: a tier->tier
                      transition is valid only if the tiers share a majority
                      (>50%) of regions.  Static constraints, "vastly
                      increasing its complexity",
  * ``manual_cnst`` — the paper's proposal: SPTLB proposes a mapping; the
                      region scheduler then the host scheduler accept or
                      reject each placement; rejections return to SPTLB as
                      avoid constraints ("similar to Constraint 3 in section
                      3.2.1") and it re-solves.  "These iterations continue
                      until SPTLB times out or the number of iterations limit
                      is reached."

The region and host schedulers are themselves small, self-contained
schedulers — the paper treats them as black boxes that answer accept/reject,
and that contract is exactly what we implement.

Fleet-scale feedback rounds: the original per-app Python loops made every
``manual_cnst`` round O(moved * T) Python-interpreter work.  The region
scheduler now precomputes a [G, T] worst-case-latency matrix once (one
vectorized max over ``region_latency``), so a whole proposal is vetted with
one fancy-indexing gather; the host scheduler packs sorted demand arrays in
one compiled ``lax.scan`` on device instead of a per-item Python loop; and
the rejection->avoid-constraint feedback pass is pure array ops over the
moved set.  ``cooperate`` reports per-phase wall-clock timings
(solve / region / host / feedback) in ``CooperationResult.timings`` and in
``SolveResult.extra["coop_timings"]`` so the split is observable.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import Problem, bucket_size
from repro.core.solver_local import SolveResult
from repro.core.telemetry import ClusterState

Variant = Literal["no_cnst", "w_cnst", "manual_cnst"]


class RegionScheduler:
    """Region-preference placement (paper [4]-style shard placement).

    Accepts a placement iff the destination tier has hosts within a latency
    budget of the app's data-source region — "if it isn't possible to keep an
    app near its data source with the given tier, it returns false".
    """

    def __init__(self, cluster: ClusterState, latency_budget_ms: float = 36.0):
        self.cluster = cluster
        self.budget = latency_budget_ms
        c = cluster
        # Worst-case latency from each source region to each tier [G, T]:
        # host capacity is fungible across a tier's regions, so the guarantee
        # must hold for the worst region the tier may place the app in (max),
        # not the best.  One vectorized max replaces the per-(app, tier)
        # Python rescans of ``region_latency``.
        self._worst_ms = np.where(
            c.tier_regions.T[None, :, :],                  # [1, G, T] region in tier?
            c.region_latency[:, :, None],                  # [G, G, 1]
            -np.inf,
        ).max(axis=1)                                      # [G, T]
        # A tier with no regions has no hosts anywhere near any data source:
        # reject placements into it (the pre-vectorization code raised on
        # the empty reduction; -inf would silently *accept*).
        self._worst_ms[:, ~c.tier_regions.any(axis=1)] = np.inf

    def check(self, app: int, tier: int) -> bool:
        """Accept iff the tier's worst region stays within the budget."""
        return bool(self._worst_ms[self.cluster.app_region[app], tier]
                    <= self.budget)

    def check_many(self, apps: np.ndarray, tiers: np.ndarray) -> np.ndarray:
        """Vectorized ``check`` over (app, tier) pairs -> bool[len(apps)]."""
        apps = np.asarray(apps, np.int64)
        tiers = np.asarray(tiers, np.int64)
        return self._worst_ms[self.cluster.app_region[apps], tiers] <= self.budget

    def feasibility_matrix(self) -> np.ndarray:
        """bool[N, T]: the full region-feasibility matrix for every app."""
        return self._worst_ms[self.cluster.app_region] <= self.budget


@partial(jax.jit, static_argnames=("num_hosts",))
def _pack_ffd(demand_sorted: jax.Array, capacity: jax.Array,
              *, num_hosts: int) -> jax.Array:
    """First-fit packing of pre-sorted items into ``num_hosts`` identical
    bins, as one compiled ``lax.scan`` — bitwise the same accept/reject
    decisions as the seed's per-item numpy loop (same f32 subtracts in the
    same order, first fit == lowest host index), with zero per-item Python.

    ``demand_sorted`` may be bucket-padded with zero rows: a zero item fits
    host 0 and consumes nothing, so padding never changes the packing.
    Returns rejected bool[M].
    """
    hosts0 = jnp.tile(capacity[None, :], (num_hosts, 1))

    def step(hosts, d):
        fit = jnp.all(hosts >= d[None, :], axis=1)
        any_fit = jnp.any(fit)
        h = jnp.argmax(fit)                                 # first fit
        hosts = hosts.at[h].add(jnp.where(any_fit, -d, 0.0))
        return hosts, ~any_fit

    _, rejected = jax.lax.scan(step, hosts0, demand_sorted)
    return rejected


class HostScheduler:
    """Host allocation: first-fit-decreasing bin-packing into tier hosts.

    Accepts a placement iff every app mapped to the tier still fits after
    packing — "if there are available hosts to allocate the application to,
    it accepts the mapping".  Rejections name the specific apps that failed
    to pack (the ones whose placement SPTLB must avoid).

    Packing runs on device (``_pack_ffd``): the sorted demand array is
    bucket-padded to a power-of-two length so repeated feedback rounds with
    drifting app counts reuse one compiled executable per (bucket, tier
    size), and the host side of a cooperation round does no per-app Python.
    """

    def __init__(self, cluster: ClusterState):
        self.cluster = cluster

    def check_tier(self, tier: int, apps: np.ndarray) -> list[int]:
        """Returns the app ids that could NOT be packed into this tier."""
        c = self.cluster
        apps = np.asarray(apps, np.int64)
        if apps.size == 0:
            return []
        demand = np.asarray(c.problem.demand)[apps]          # [M, R]
        order = np.argsort(-demand.max(axis=1))              # decreasing
        M = apps.size
        Mb = bucket_size(M, minimum=128)
        d_sorted = np.zeros((Mb, demand.shape[1]), demand.dtype)
        d_sorted[:M] = demand[order]
        rejected = np.asarray(_pack_ffd(
            jnp.asarray(d_sorted), jnp.asarray(c.host_capacity),
            num_hosts=int(c.hosts_per_tier[tier])))[:M]
        return [int(a) for a in apps[order][rejected]]


@dataclasses.dataclass
class CooperationResult:
    result: SolveResult
    variant: str
    feedback_rounds: int
    num_rejections: int
    total_time_s: float
    accepted: bool
    # Per-phase wall-clock split: solve_s (device solver), region_s / host_s
    # (lower-level scheduler checks), feedback_s (avoid-matrix construction),
    # host_side_frac (everything except solve_s, as a fraction of the total).
    timings: dict = dataclasses.field(default_factory=dict)


def region_overlap_avoid(cluster: ClusterState) -> np.ndarray:
    """w_cnst static constraint: avoid[n, t] unless >50% of the regions of
    app n's current tier overlap with tier t (paper §4.2.2 item 2)."""
    c = cluster
    regions = c.tier_regions.astype(np.int64)
    shared = regions @ regions.T                             # [T, T]
    na = regions.sum(axis=1)
    overlap_ok = shared > 0.5 * na[:, None]
    x0 = np.asarray(c.problem.assignment0)
    return ~overlap_ok[x0]                                   # [N, T]


def _finish_timings(timings: dict, total_s: float) -> dict:
    # Everything that is not device solve time counts as host-side — the
    # per-phase counters plus untimed glue (matrix precompute, np/jnp
    # conversions), so the fraction cannot undercount host work.
    timings["total_s"] = total_s
    timings["host_side_frac"] = (
        max(0.0, total_s - timings.get("solve_s", 0.0)) / total_s
        if total_s > 0 else 0.0)
    return timings


def cooperate(
    cluster: ClusterState,
    solve_fn: Callable[[Problem], SolveResult],
    variant: Variant = "manual_cnst",
    *,
    max_rounds: int = 8,
    timeout_s: float = float("inf"),
    region_budget_ms: float = 36.0,
) -> CooperationResult:
    """Run one SPTLB balancing pass under the chosen integration variant."""
    t0 = time.perf_counter()
    problem = cluster.problem
    region = RegionScheduler(cluster, latency_budget_ms=region_budget_ms)
    host = HostScheduler(cluster)
    timings = {"solve_s": 0.0, "region_s": 0.0, "host_s": 0.0,
               "feedback_s": 0.0}

    def timed_solve(p, **kw):
        t = time.perf_counter()
        r = solve_fn(p, **kw)
        timings["solve_s"] += time.perf_counter() - t
        return r

    if variant in ("no_cnst", "w_cnst"):
        if variant == "w_cnst":
            problem = problem.with_avoid(jnp.asarray(region_overlap_avoid(cluster)))
        res = timed_solve(problem)
        total = time.perf_counter() - t0
        res.extra["coop_timings"] = _finish_timings(timings, total)
        return CooperationResult(res, variant, 1, 0, total, True,
                                 timings=timings)

    assert variant == "manual_cnst", variant
    x0 = np.asarray(problem.assignment0)
    total_rejections = 0
    res = timed_solve(problem)
    rounds = 1
    while rounds <= max_rounds and (time.perf_counter() - t0) < timeout_s:
        x = np.asarray(res.assignment)
        moved = np.where(x != x0)[0]

        # Fig. 2 order: region scheduler first (one vectorized gather)...
        t = time.perf_counter()
        region_ok = region.check_many(moved, x[moved])
        timings["region_s"] += time.perf_counter() - t
        rej_n = [moved[~region_ok]]
        rej_t = [x[moved[~region_ok]]]

        # ...then host allocation for the placements the region level kept.
        surviving = moved[region_ok]
        t = time.perf_counter()
        for tier in np.unique(x[surviving]):
            newcomers = surviving[x[surviving] == tier]
            incumbents = np.where((x == tier) & (x0 == tier))[0]
            rej = np.asarray(host.check_tier(int(tier),
                                             np.concatenate([incumbents,
                                                             newcomers])),
                             np.int64)
            if rej.size:
                rej = rej[x[rej] != x0[rej]]                 # newcomers bounce
                rej_n.append(rej)
                rej_t.append(x[rej])
        timings["host_s"] += time.perf_counter() - t

        rej_n = np.concatenate(rej_n)
        rej_t = np.concatenate(rej_t)
        if rej_n.size == 0:
            total = time.perf_counter() - t0
            res.extra["coop_timings"] = _finish_timings(timings, total)
            return CooperationResult(res, variant, rounds, total_rejections,
                                     total, True, timings=timings)

        # Feedback: rejections become avoid constraints; re-solve, warm-
        # started from the vetted subset of the proposal.  Accepted moves are
        # *locked* (the lower level ack'd them — Fig. 2's acknowledgement):
        # the solver may keep them or send them home, but not churn them to a
        # third, unvetted tier.  This makes the unknown-placement set shrink
        # every round, so the loop converges instead of exploring forever.
        # All of it is fancy-indexed array ops — no per-app Python.
        t = time.perf_counter()
        total_rejections += int(rej_n.size)
        extra = np.zeros((problem.num_apps, problem.num_tiers), bool)
        extra[rej_n, rej_t] = True
        x_accepted = x.copy()
        x_accepted[rej_n] = x0[rej_n]
        acked = moved[~np.isin(moved, rej_n)]                # ack'd placements
        extra[acked, :] = True
        extra[acked, x[acked]] = False
        extra[acked, x0[acked]] = False
        problem = problem.with_avoid(jnp.asarray(extra))
        timings["feedback_s"] += time.perf_counter() - t

        res = timed_solve(problem, init_assignment=jnp.asarray(x_accepted))
        rounds += 1

    # Iteration/timeout limit: drop still-rejected moves (stay-home is safe —
    # the app's original placement was already accepted by the lower levels).
    x = np.asarray(res.assignment).copy()
    t = time.perf_counter()
    moved = np.where(x != x0)[0]
    bad = moved[~region.check_many(moved, x[moved])]
    x[bad] = x0[bad]
    timings["region_s"] += time.perf_counter() - t
    t = time.perf_counter()
    for tier in np.unique(x[x != x0]):
        apps_t = np.where(x == tier)[0]
        rej = np.asarray(host.check_tier(int(tier), apps_t), np.int64)
        if rej.size:
            rej = rej[x[rej] != x0[rej]]
            x[rej] = x0[rej]
    timings["host_s"] += time.perf_counter() - t
    res = dataclasses.replace(
        res, assignment=jnp.asarray(x),
        num_moved=int(np.sum(x != x0)))
    total = time.perf_counter() - t0
    res.extra["coop_timings"] = _finish_timings(timings, total)
    return CooperationResult(res, variant, rounds, total_rejections,
                             total, False, timings=timings)
