"""SPTLB orchestration (paper Fig. 1): collect -> construct -> solve -> execute.

The three stages of §3:
  1. data collection      -> telemetry.generate_cluster / ResourceMonitor
  2. problem construction -> core.problem (Rebalancer-compliant structures)
  3. output & execution   -> projected metrics, constraint validation,
                             decision evaluation vs. the greedy baseline
plus §3.4 hierarchy integration via core.hierarchy.cooperate.

``Sptlb.balance`` is the public entry point used by the launch drivers and
benchmarks; ``BalanceDecision`` is the §3.3 output record ("projected
mappings from tier to app after load balancing and the projected metrics").

Shape-bucketed compilation caching: ``balance`` runs on every telemetry tick
while the live app count drifts, and every new N would retrace the jitted
solvers.  With ``bucket_apps=True`` (default) the jit-compiled engines see
the problem padded to a power-of-two app bucket (problem.pad_problem — inert
rows that cannot move and carry no load), so all ticks in a bucket share one
compiled executable.  Cache behaviour is observable: ``SolveResult.extra``
carries ``bucket``/``padded_from`` plus the solver's ``retraced`` flag and
per-phase timings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import constraints, metrics
from repro.core.greedy import GreedyConfig, solve_greedy
from repro.core.hierarchy import (CooperationResult, cooperate,
                                  enforce_cost_budget)
from repro.core.levels import CoopConfig, Hierarchy
from repro.core.planner import PlanOutlook, movement_cost_of
from repro.core.problem import Problem, bucket_size, pad_problem
from repro.core.solver_local import LocalSearchConfig, SolveResult, solve_local
from repro.core.solver_optimal import OptimalSearchConfig, solve_optimal
from repro.core.telemetry import ClusterState

Engine = Literal["local", "optimal", "greedy-cpu", "greedy-mem", "greedy-task"]

# Deterministic iteration budgets standing in for the paper's wall-clock
# timeout knobs (30s / 60s / 10min / 30min) — see DESIGN.md §7(2).
TIMEOUT_BUDGETS = {30: 256, 60: 512, 600: 2048, 1800: 8192}


def _bucketed(solve):
    """Wrap a solve_fn so jit sees power-of-two app buckets.

    The padded problem solves to the same trajectory as the original (inert
    rows can't move and carry no load), so slicing the assignment back to N
    is lossless; ``extra`` records the bucket for observability.
    """
    def run(p: Problem, init_assignment=None):
        N = p.num_apps
        b = bucket_size(N)
        if b == N:
            res = solve(p, init_assignment=init_assignment)
            res.extra.update(bucket=b, padded_from=N)
            return res
        pp = pad_problem(p, b)
        init = init_assignment
        if init is not None:
            init = jnp.concatenate([jnp.asarray(init, pp.assignment0.dtype),
                                    pp.assignment0[N:]])
        res = solve(pp, init_assignment=init)
        res = dataclasses.replace(res, assignment=res.assignment[:N])
        res.extra.update(bucket=b, padded_from=N)
        return res
    return run


def engine_fn(engine: Engine, timeout_s: int = 30, seed: int = 0,
              *, batch_moves: Optional[int] = None,
              bucket_apps: bool = True):
    """Build a solve_fn(problem, init_assignment=None) for the chosen engine.

    ``init_assignment`` warm-starts re-solves inside the manual_cnst feedback
    loop (engines without warm-start support ignore it).  ``batch_moves``
    overrides the top-k commit batch of the LocalSearch paths (None keeps the
    config default); ``bucket_apps`` pads the app axis to power-of-two
    buckets so drifting app counts reuse compiled executables.
    """
    budget = TIMEOUT_BUDGETS.get(timeout_s, max(64, int(timeout_s * 8)))
    if engine == "local":
        kw = {} if batch_moves is None else {"batch_moves": batch_moves}
        cfg = LocalSearchConfig(max_iters=budget, seed=seed, **kw)

        def fn(p, init_assignment=None):
            return solve_local(p, cfg, init_assignment=init_assignment)

        return _bucketed(fn) if bucket_apps else fn
    if engine == "optimal":
        kw = {} if batch_moves is None else {"batch_moves": batch_moves}
        cfg = OptimalSearchConfig(steps=budget, seed=seed, **kw)

        def fn(p, init_assignment=None):
            return solve_optimal(p, cfg)

        return _bucketed(fn) if bucket_apps else fn
    if engine.startswith("greedy-"):
        # Host-side numpy: nothing to jit-cache, so never bucket.
        obj = engine.split("-", 1)[1]
        obj = {"task-count": "task"}.get(obj, obj)
        gcfg = GreedyConfig(objective=obj, max_steps=budget)

        def fn(p, init_assignment=None):
            return solve_greedy(p, gcfg)

        return fn
    raise ValueError(f"unknown engine {engine!r}")


@dataclasses.dataclass
class BalanceDecision:
    """§3.3 solver output: projected mapping + metrics + evaluation hooks."""

    assignment: object                       # i32[N] final app -> tier
    projected: metrics.ProjectedMetrics
    violations: constraints.Violations
    difference_to_balance: float
    network_p99_ms: float
    solve: SolveResult
    cooperation: CooperationResult | None
    # Madsen-style reconfiguration cost of the mapping (goal 8's downtime,
    # priced — see core.planner.move_costs); the controller charges applied
    # decisions against its trajectory budget.  ``budget_trimmed`` counts
    # the moves reverted to fit ``cost_budget`` (every engine, including
    # the hierarchy-unaware greedy baselines).
    movement_cost: float = 0.0
    budget_trimmed: int = 0


class Sptlb:
    """The Stream-Processing Tier Load Balancer."""

    def __init__(self, cluster: ClusterState):
        self.cluster = cluster

    def balance(
        self,
        engine: Engine = "local",
        *,
        timeout_s: int = 30,
        seed: int = 0,
        config: Optional[CoopConfig] = None,
        hierarchy: Optional[Hierarchy] = None,
        plan: Optional[PlanOutlook] = None,
        move_cost: Optional[np.ndarray] = None,
        cost_budget: Optional[float] = None,
    ) -> BalanceDecision:
        """One balancing pass.

        ``config`` (a ``core.levels.CoopConfig``) carries the cooperation
        knobs — variant, round cap, premask, restarts, engine batching, the
        scheduler-level stack (``config.levels`` names or an explicit
        ``hierarchy``), and the movement pricing; ``plan`` / ``move_cost``
        / ``cost_budget`` stay accepted per call because the controller
        derives them every tick.  The PR-5 deprecated keyword shims
        (variant, max_feedback_rounds, batch_moves, bucket_apps,
        premask_region, restart_rounds) have been removed — pass a
        ``CoopConfig``.

        ``config.plan`` (a ``core.planner.PlanOutlook``) makes the pass
        proactive: the *solver* balances against the planning problem
        (declared-horizon capacity targets, will-drain tiers premasked),
        while the decision's projected metrics, constraint validation, and
        d2b are evaluated against the real collected problem —
        anticipation changes what the solver aims for, never what the
        decision is judged on.  The host scheduler packs against real host
        counts either way, so proposals stay physically placeable; each
        level's ``relax`` hook sees the plan (maintenance placement mode).
        """
        cfg = config if config is not None else CoopConfig()
        # Per-call dynamic inputs: the controller re-derives them every tick.
        if plan is not None:
            cfg = dataclasses.replace(cfg, plan=plan)
        if move_cost is not None:
            cfg = dataclasses.replace(cfg, move_cost=move_cost)
        if cost_budget is not None:
            cfg = dataclasses.replace(cfg, cost_budget=cost_budget)
        if cfg.timeout_s is None:
            # The engine's iteration budget is the deterministic stand-in
            # for ``timeout_s`` *within* a solve; across rounds the paper's
            # "until SPTLB times out" is wall-clock, and the restart phase
            # bounds itself against the same deadline.  3x leaves the
            # feedback loop headroom over a single solve's nominal budget
            # while still cutting off pathological round/restart spirals.
            cfg = dataclasses.replace(cfg, timeout_s=3.0 * timeout_s)

        solve_fn = engine_fn(engine, timeout_s, seed,
                             batch_moves=cfg.batch_moves,
                             bucket_apps=cfg.bucket_apps)
        # An active shed plan (core.shedding) is an actuated throttle: the
        # fleet really serves ``cap x demand``, so BOTH the solver's problem
        # and the decision's evaluation see the capped demand — unlike
        # ``plan``, which only steers the solver.
        base_cluster = self.cluster
        shed = cfg.shed
        if shed is not None and shed.active:
            base_cluster = dataclasses.replace(
                self.cluster, problem=shed.apply(self.cluster.problem))
        solve_cluster = base_cluster
        plan = cfg.plan
        if plan is not None and plan.active:
            # dataclasses.replace starts a fresh precompute cache, which is
            # correct: the planning problem's avoid/slo tables differ from
            # the real cluster's.  The level relax hooks (region latency,
            # shard co-location) fire inside ``cooperate`` via cfg.plan.
            solve_cluster = dataclasses.replace(
                base_cluster, problem=plan.apply(base_cluster.problem))
        t0 = time.perf_counter()
        greedy_timings = None
        if engine.startswith("greedy-"):
            # The baseline greedy scheduler is hierarchy-unaware by design —
            # but the movement budget binds every engine, so its mapping is
            # priced and trimmed too (no level re-vet: greedy never had the
            # stack's packing contract).
            res = solve_fn(solve_cluster.problem)
            greedy_timings = {}
            res = enforce_cost_budget(base_cluster, res,
                                      np.asarray(base_cluster.problem.assignment0),
                                      cfg.move_cost, cfg.cost_budget, (),
                                      greedy_timings)
            coop = None
        else:
            coop = cooperate(solve_cluster, solve_fn, config=cfg,
                             hierarchy=hierarchy)
            res = coop.result
        t_solve = time.perf_counter()

        # Decision evaluation is against the *served* problem (real collected
        # demand, scaled by any actuated shed caps) — a plan only steers the
        # solver (tightened capacity would otherwise mis-score a perfectly
        # good mapping as over-capacity), but shed caps change what the fleet
        # actually serves.
        problem: Problem = base_cluster.problem
        if coop is not None:
            movement = coop.timings.get("movement_cost", 0.0)
            trimmed = int(coop.timings.get("budget_trimmed", 0))
        elif greedy_timings is not None:
            movement = greedy_timings["movement_cost"]
            trimmed = int(greedy_timings.get("budget_trimmed", 0))
        else:
            movement = movement_cost_of(res.assignment, problem.assignment0,
                                        cfg.move_cost)
            trimmed = 0
        if plan is not None and plan.active:
            res.extra["plan"] = {
                "pending": plan.pending,
                "min_tier_factor": float(plan.tier_factor.min()),
                "avoid_tiers": int(plan.avoid_tiers.sum()),
                "relax_tiers": int(plan.relax_home_tiers.sum()),
            }
        if shed is not None and shed.active:
            res.extra["shed"] = {
                "capped": int(np.sum(shed.caps < 1.0)),
                "churn": shed.churned,
                "churn_cost": shed.churn_cost,
                "overload_frac": shed.overload_frac,
            }
        decision = BalanceDecision(
            assignment=res.assignment,
            projected=metrics.projected_metrics(problem, res.assignment),
            violations=constraints.validate(problem, res.assignment),
            difference_to_balance=metrics.difference_to_balance(problem, res.assignment),
            network_p99_ms=metrics.network_p99_ms(self.cluster, res.assignment),
            solve=res,
            cooperation=coop,
            movement_cost=movement,
            budget_trimmed=trimmed,
        )
        res.extra["balance_timings"] = {
            "solve_s": t_solve - t0,
            "evaluate_s": time.perf_counter() - t_solve,
        }
        return decision
