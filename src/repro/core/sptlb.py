"""SPTLB orchestration (paper Fig. 1): collect -> construct -> solve -> execute.

The three stages of §3:
  1. data collection      -> telemetry.generate_cluster / ResourceMonitor
  2. problem construction -> core.problem (Rebalancer-compliant structures)
  3. output & execution   -> projected metrics, constraint validation,
                             decision evaluation vs. the greedy baseline
plus §3.4 hierarchy integration via core.hierarchy.cooperate.

``Sptlb.balance`` is the public entry point used by the launch drivers and
benchmarks; ``BalanceDecision`` is the §3.3 output record ("projected
mappings from tier to app after load balancing and the projected metrics").

Shape-bucketed compilation caching: ``balance`` runs on every telemetry tick
while the live app count drifts, and every new N would retrace the jitted
solvers.  With ``bucket_apps=True`` (default) the jit-compiled engines see
the problem padded to a power-of-two app bucket (problem.pad_problem — inert
rows that cannot move and carry no load), so all ticks in a bucket share one
compiled executable.  Cache behaviour is observable: ``SolveResult.extra``
carries ``bucket``/``padded_from`` plus the solver's ``retraced`` flag and
per-phase timings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal, Optional

import jax.numpy as jnp

from repro.core import constraints, metrics
from repro.core.greedy import GreedyConfig, solve_greedy
from repro.core.hierarchy import CooperationResult, Variant, cooperate
from repro.core.problem import Problem, bucket_size, pad_problem
from repro.core.solver_local import LocalSearchConfig, SolveResult, solve_local
from repro.core.solver_optimal import OptimalSearchConfig, solve_optimal
from repro.core.telemetry import ClusterState

Engine = Literal["local", "optimal", "greedy-cpu", "greedy-mem", "greedy-task"]

# Deterministic iteration budgets standing in for the paper's wall-clock
# timeout knobs (30s / 60s / 10min / 30min) — see DESIGN.md §7(2).
TIMEOUT_BUDGETS = {30: 256, 60: 512, 600: 2048, 1800: 8192}


def _bucketed(solve):
    """Wrap a solve_fn so jit sees power-of-two app buckets.

    The padded problem solves to the same trajectory as the original (inert
    rows can't move and carry no load), so slicing the assignment back to N
    is lossless; ``extra`` records the bucket for observability.
    """
    def run(p: Problem, init_assignment=None):
        N = p.num_apps
        b = bucket_size(N)
        if b == N:
            res = solve(p, init_assignment=init_assignment)
            res.extra.update(bucket=b, padded_from=N)
            return res
        pp = pad_problem(p, b)
        init = init_assignment
        if init is not None:
            init = jnp.concatenate([jnp.asarray(init, pp.assignment0.dtype),
                                    pp.assignment0[N:]])
        res = solve(pp, init_assignment=init)
        res = dataclasses.replace(res, assignment=res.assignment[:N])
        res.extra.update(bucket=b, padded_from=N)
        return res
    return run


def engine_fn(engine: Engine, timeout_s: int = 30, seed: int = 0,
              *, batch_moves: Optional[int] = None,
              bucket_apps: bool = True):
    """Build a solve_fn(problem, init_assignment=None) for the chosen engine.

    ``init_assignment`` warm-starts re-solves inside the manual_cnst feedback
    loop (engines without warm-start support ignore it).  ``batch_moves``
    overrides the top-k commit batch of the LocalSearch paths (None keeps the
    config default); ``bucket_apps`` pads the app axis to power-of-two
    buckets so drifting app counts reuse compiled executables.
    """
    budget = TIMEOUT_BUDGETS.get(timeout_s, max(64, int(timeout_s * 8)))
    if engine == "local":
        kw = {} if batch_moves is None else {"batch_moves": batch_moves}
        cfg = LocalSearchConfig(max_iters=budget, seed=seed, **kw)
        fn = lambda p, init_assignment=None: solve_local(
            p, cfg, init_assignment=init_assignment)
        return _bucketed(fn) if bucket_apps else fn
    if engine == "optimal":
        kw = {} if batch_moves is None else {"batch_moves": batch_moves}
        cfg = OptimalSearchConfig(steps=budget, seed=seed, **kw)
        fn = lambda p, init_assignment=None: solve_optimal(p, cfg)
        return _bucketed(fn) if bucket_apps else fn
    if engine.startswith("greedy-"):
        # Host-side numpy: nothing to jit-cache, so never bucket.
        obj = engine.split("-", 1)[1]
        obj = {"task-count": "task"}.get(obj, obj)
        gcfg = GreedyConfig(objective=obj, max_steps=budget)
        return lambda p, init_assignment=None: solve_greedy(p, gcfg)
    raise ValueError(f"unknown engine {engine!r}")


@dataclasses.dataclass
class BalanceDecision:
    """§3.3 solver output: projected mapping + metrics + evaluation hooks."""

    assignment: object                       # i32[N] final app -> tier
    projected: metrics.ProjectedMetrics
    violations: constraints.Violations
    difference_to_balance: float
    network_p99_ms: float
    solve: SolveResult
    cooperation: CooperationResult | None


class Sptlb:
    """The Stream-Processing Tier Load Balancer."""

    def __init__(self, cluster: ClusterState):
        self.cluster = cluster

    def balance(
        self,
        engine: Engine = "local",
        *,
        timeout_s: int = 30,
        variant: Variant = "manual_cnst",
        max_feedback_rounds: int = 8,
        seed: int = 0,
        batch_moves: Optional[int] = None,
        bucket_apps: bool = True,
        premask_region: bool = True,
        restart_rounds: int = 0,
    ) -> BalanceDecision:
        """One balancing pass.  ``premask_region`` (default on) folds the
        region scheduler's feasibility matrix into the solver's avoid mask
        before the first manual_cnst solve, so feedback rounds are spent on
        host packing only; ``restart_rounds`` adds vetted perturbation
        restarts after an accepted fixed point (the diversification the
        unmasked path got from its rejection rounds) — see
        ``hierarchy.cooperate``."""
        solve_fn = engine_fn(engine, timeout_s, seed,
                             batch_moves=batch_moves, bucket_apps=bucket_apps)
        t0 = time.perf_counter()
        if engine.startswith("greedy-"):
            # The baseline greedy scheduler is hierarchy-unaware by design.
            res = solve_fn(self.cluster.problem)
            coop = None
        else:
            # The engine's iteration budget is the deterministic stand-in
            # for ``timeout_s`` *within* a solve; across rounds the paper's
            # "until SPTLB times out" is wall-clock, and the restart phase
            # bounds itself against the same deadline.  3x leaves the
            # feedback loop headroom over a single solve's nominal budget
            # while still cutting off pathological round/restart spirals.
            coop = cooperate(self.cluster, solve_fn, variant,
                             max_rounds=max_feedback_rounds,
                             timeout_s=3.0 * timeout_s,
                             premask_region=premask_region,
                             restart_rounds=restart_rounds)
            res = coop.result
        t_solve = time.perf_counter()

        problem: Problem = self.cluster.problem
        decision = BalanceDecision(
            assignment=res.assignment,
            projected=metrics.projected_metrics(problem, res.assignment),
            violations=constraints.validate(problem, res.assignment),
            difference_to_balance=metrics.difference_to_balance(problem, res.assignment),
            network_p99_ms=metrics.network_p99_ms(self.cluster, res.assignment),
            solve=res,
            cooperation=coop,
        )
        res.extra["balance_timings"] = {
            "solve_s": t_solve - t0,
            "evaluate_s": time.perf_counter() - t_solve,
        }
        return decision
