"""SPTLB orchestration (paper Fig. 1): collect -> construct -> solve -> execute.

The three stages of §3:
  1. data collection      -> telemetry.generate_cluster / ResourceMonitor
  2. problem construction -> core.problem (Rebalancer-compliant structures)
  3. output & execution   -> projected metrics, constraint validation,
                             decision evaluation vs. the greedy baseline
plus §3.4 hierarchy integration via core.hierarchy.cooperate.

``Sptlb.balance`` is the public entry point used by the launch drivers and
benchmarks; ``BalanceDecision`` is the §3.3 output record ("projected
mappings from tier to app after load balancing and the projected metrics").
"""
from __future__ import annotations

import dataclasses
from typing import Literal

from repro.core import constraints, metrics
from repro.core.greedy import GreedyConfig, solve_greedy
from repro.core.hierarchy import CooperationResult, Variant, cooperate
from repro.core.problem import Problem
from repro.core.solver_local import LocalSearchConfig, SolveResult, solve_local
from repro.core.solver_optimal import OptimalSearchConfig, solve_optimal
from repro.core.telemetry import ClusterState

Engine = Literal["local", "optimal", "greedy-cpu", "greedy-mem", "greedy-task"]

# Deterministic iteration budgets standing in for the paper's wall-clock
# timeout knobs (30s / 60s / 10min / 30min) — see DESIGN.md §7(2).
TIMEOUT_BUDGETS = {30: 256, 60: 512, 600: 2048, 1800: 8192}


def engine_fn(engine: Engine, timeout_s: int = 30, seed: int = 0):
    """Build a solve_fn(problem, init_assignment=None) for the chosen engine.

    ``init_assignment`` warm-starts re-solves inside the manual_cnst feedback
    loop (engines without warm-start support ignore it).
    """
    budget = TIMEOUT_BUDGETS.get(timeout_s, max(64, int(timeout_s * 8)))
    if engine == "local":
        cfg = LocalSearchConfig(max_iters=budget, seed=seed)
        return lambda p, init_assignment=None: solve_local(
            p, cfg, init_assignment=init_assignment)
    if engine == "optimal":
        cfg = OptimalSearchConfig(steps=budget, seed=seed)
        return lambda p, init_assignment=None: solve_optimal(p, cfg)
    if engine.startswith("greedy-"):
        obj = engine.split("-", 1)[1]
        obj = {"task-count": "task"}.get(obj, obj)
        gcfg = GreedyConfig(objective=obj, max_steps=budget)
        return lambda p, init_assignment=None: solve_greedy(p, gcfg)
    raise ValueError(f"unknown engine {engine!r}")


@dataclasses.dataclass
class BalanceDecision:
    """§3.3 solver output: projected mapping + metrics + evaluation hooks."""

    assignment: object                       # i32[N] final app -> tier
    projected: metrics.ProjectedMetrics
    violations: constraints.Violations
    difference_to_balance: float
    network_p99_ms: float
    solve: SolveResult
    cooperation: CooperationResult | None


class Sptlb:
    """The Stream-Processing Tier Load Balancer."""

    def __init__(self, cluster: ClusterState):
        self.cluster = cluster

    def balance(
        self,
        engine: Engine = "local",
        *,
        timeout_s: int = 30,
        variant: Variant = "manual_cnst",
        max_feedback_rounds: int = 8,
        seed: int = 0,
    ) -> BalanceDecision:
        solve_fn = engine_fn(engine, timeout_s, seed)
        if engine.startswith("greedy-"):
            # The baseline greedy scheduler is hierarchy-unaware by design.
            res = solve_fn(self.cluster.problem)
            coop = None
        else:
            coop = cooperate(self.cluster, solve_fn, variant,
                             max_rounds=max_feedback_rounds)
            res = coop.result

        problem: Problem = self.cluster.problem
        return BalanceDecision(
            assignment=res.assignment,
            projected=metrics.projected_metrics(problem, res.assignment),
            violations=constraints.validate(problem, res.assignment),
            difference_to_balance=metrics.difference_to_balance(problem, res.assignment),
            network_p99_ms=metrics.network_p99_ms(self.cluster, res.assignment),
            solve=res,
            cooperation=coop,
        )
