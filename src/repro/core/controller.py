"""Continuous-balancing controller: SPTLB as a long-running service.

The paper's §3.3 decision-execution stage, made operational: instead of a
one-shot solve, a controller periodically samples telemetry, decides
*whether* to rebalance (hysteresis — the paper's criticality/downtime goals
exist precisely because gratuitous movement is expensive), applies the
decision, and keeps an audit trail ("decision evaluation can also result in
finding bugs with the solver").

Policies:
  * trigger: rebalance only when difference-to-balance exceeds
    ``trigger_d2b``, any tier exceeds its ideal utilization by
    ``trigger_over_ideal``, or at least ``trigger_slo_apps`` live apps sit
    on a tier no longer eligible for their SLO class (capacity events and
    outages strand incumbents — constraint 4 read as a state),
  * anticipation: with declared maintenance advisories on board
    (``set_advisories``), a ``core.planner.MaintenancePlanner`` derives
    per-tick capacity/eligibility targets over the declared horizon; an
    active outlook triggers proactively and the solver balances against
    the planning problem — evacuation starts *before* the first ramp step
    instead of after SLO-stranded triggers fire,
  * movement budget: every applied decision is priced
    (``core.planner.move_costs``, Madsen-style reconfiguration cost) and
    charged against ``movement_cost_budget`` for the controller's
    lifetime; decisions that would overrun are trimmed inside the
    cooperation loop and exhausted budgets block movement entirely
    (``budget_overruns`` counts both),
  * cooldown: at least ``cooldown_rounds`` collection rounds between moves,
  * dry_run: compute + log decisions without applying (shadow mode — how a
    new scheduler is actually rolled out at scale).

Externally-evolved clusters: the controller is driven by whoever owns the
telemetry loop (``repro.sim.harness`` in the fleet simulator).  Callers
hand the evolved cluster to ``tick(cluster)`` (or assign ``self.cluster``
between ticks); the controller re-syncs its reused ``Sptlb`` either way, so
capacity events, demand drift, and churn (``valid``-mask flips) are picked
up without rebuilding the controller or losing cooldown/audit state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core.levels import CoopConfig
from repro.core.planner import (MaintenancePlanner, PlannerConfig, PlanOutlook,
                                move_costs)
from repro.core.problem import utilization_fraction
from repro.core.sptlb import Sptlb
from repro.core.telemetry import ClusterState


@dataclasses.dataclass(eq=False)
class ControllerConfig:
    trigger_d2b: float = 0.15
    trigger_over_ideal: float = 0.05
    # Trigger when this many live apps are stranded on SLO-ineligible tiers
    # (None disables the check).  Default 1: any stranded app is an active
    # SLO breach, and waiting for the *balance* metrics to drift far enough
    # would leave it stranded through the whole event.
    trigger_slo_apps: Optional[int] = 1
    cooldown_rounds: int = 3
    engine: str = "local"
    # Legacy cooperation knobs, folded into ``coop`` when it is not given
    # explicitly (kept so historical ControllerConfig(...) call sites work).
    variant: str = "manual_cnst"
    timeout_s: int = 30
    dry_run: bool = False
    restart_rounds: int = 0
    # Maintenance anticipation: lookahead (ticks) over declared advisories
    # and the declared-capacity fraction below which a tier is premasked.
    # Only engages once ``set_advisories`` hands the controller a schedule.
    anticipation_horizon: int = 12
    drain_avoid_threshold: float = 0.5
    # Trajectory-level movement budget in ``core.planner.move_costs`` units
    # (mean live app == 1.0); None leaves movement priced but uncapped.
    movement_cost_budget: Optional[float] = None
    # The cooperation configuration every tick's balance runs under —
    # variant, round cap, premask, restarts, and the scheduler-level stack
    # (``coop.levels`` names, e.g. ("region", "host", "shard")).  The
    # controller fills the per-tick dynamic fields (plan / move_cost /
    # cost_budget) itself via dataclasses.replace.
    coop: Optional[CoopConfig] = None

    def __post_init__(self):
        if self.coop is None:
            self.coop = CoopConfig(variant=self.variant,
                                   restart_rounds=self.restart_rounds)
            return
        # Same shim precedence as Sptlb.balance/cooperate: a legacy field
        # the caller actually set (non-default) that disagrees with an
        # explicit coop config warns and overrides (after folding they
        # agree, so dataclasses.replace stays silent).
        import warnings as _warnings
        for legacy, default in (("variant", "manual_cnst"),
                                ("restart_rounds", 0)):
            value = getattr(self, legacy)
            if value != default and value != getattr(self.coop, legacy):
                _warnings.warn(
                    f"ControllerConfig({legacy}=...) is deprecated alongside "
                    f"an explicit coop config; the legacy value overrides — "
                    f"set CoopConfig({legacy}=...) instead",
                    DeprecationWarning, stacklevel=3)
                self.coop = dataclasses.replace(self.coop, **{legacy: value})


@dataclasses.dataclass
class ControllerEvent:
    round: int
    triggered: bool
    reason: str
    applied: bool
    d2b_before: float
    d2b_after: Optional[float] = None
    moved: int = 0
    time_s: float = 0.0
    # Priced reconfiguration cost of the decision (0 when nothing solved)
    # and whether the movement budget bound this round (trimmed proposal or
    # exhausted budget blocking the solve).
    movement_cost: float = 0.0
    budget_limited: bool = False
    # Declared advisories inside the planning horizon this round.
    plan_pending: int = 0


class BalanceController:
    def __init__(self, cluster: ClusterState,
                 config: ControllerConfig = ControllerConfig()):
        self.cluster = cluster
        self.config = config
        self.round = 0
        self.last_applied_round = -10**9
        self.history: list[ControllerEvent] = []
        # One balancer for the controller's lifetime: re-instantiating it
        # every trigger discarded nothing expensive per se, but the cluster
        # it points at carries the memoized hierarchy precomputes — keep
        # both in lock-step instead of rebuilding per tick.
        self._sptlb = Sptlb(cluster)
        # Anticipation + movement accounting (see module docstring).
        self.planner: Optional[MaintenancePlanner] = None
        self.now = 0                      # external tick of the last tick()
        self.cost_spent = 0.0             # applied movement cost, lifetime
        self.budget_overruns = 0          # rounds the budget bound movement

    def set_advisories(self, advisories, *,
                       horizon: Optional[int] = None) -> None:
        """Hand the controller a declared maintenance schedule (a sequence
        of ``core.planner.Advisory``).  An empty schedule disables
        anticipation; the budget and history are untouched either way."""
        advisories = tuple(advisories)
        if not advisories or self.config.anticipation_horizon <= 0:
            self.planner = None
            return
        self.planner = MaintenancePlanner(
            advisories,
            PlannerConfig(
                horizon=(self.config.anticipation_horizon
                         if horizon is None else horizon),
                drain_threshold=self.config.drain_avoid_threshold))

    # -- trigger policy -----------------------------------------------------
    def should_rebalance(self, d2b: Optional[float] = None,
                         outlook: Optional[PlanOutlook] = None
                         ) -> tuple[bool, str]:
        """Trigger decision.  ``d2b`` lets ``tick`` pass the
        difference-to-balance it already computed instead of paying the
        tier-loads reduction twice per round; ``outlook`` is the planner's
        view of the declared horizon (an active outlook triggers
        proactively — the whole point of declared maintenance)."""
        cfg = self.config
        p = self.cluster.problem
        if d2b is None:
            d2b = M.difference_to_balance(p, p.assignment0)
        if self.round - self.last_applied_round < cfg.cooldown_rounds:
            return False, f"cooldown ({d2b=:.3f})"
        if outlook is not None and outlook.active:
            return True, (
                f"declared-maintenance ({outlook.pending} advisories within "
                f"{outlook.horizon} ticks, min capacity factor "
                f"{float(outlook.tier_factor.min()):.2f})")
        uf, tf = utilization_fraction(p, p.assignment0)
        over = float(jnp.max(uf - p.ideal_frac))
        over_t = float(jnp.max(tf - p.ideal_task_frac))
        if d2b > cfg.trigger_d2b:
            return True, f"d2b {d2b:.3f} > {cfg.trigger_d2b}"
        if max(over, over_t) > cfg.trigger_over_ideal:
            return True, f"over-ideal {max(over, over_t):.3f}"
        if cfg.trigger_slo_apps is not None:
            slo_ok = p.slo_allowed[p.assignment0, p.slo]
            stranded = int(jnp.sum(~slo_ok & p.valid))
            if stranded >= cfg.trigger_slo_apps:
                return True, f"slo-stranded apps {stranded}"
        return False, f"balanced ({d2b=:.3f})"

    def observe(self, cluster: ClusterState) -> None:
        """Adopt an externally-evolved cluster (fresh telemetry, capacity
        events, churn) without losing cooldown/audit state."""
        self.cluster = cluster
        self._sptlb.cluster = cluster

    # -- one control round ----------------------------------------------------
    def tick(self, cluster: Optional[ClusterState] = None,
             now: Optional[int] = None) -> ControllerEvent:
        """One control round.  ``now`` is the external clock the advisory
        schedule is declared against (the sim harness passes its tick);
        callers without one get the controller's own 0-based round count."""
        if cluster is not None:
            self.observe(cluster)
        self.round += 1
        self.now = (self.round - 1) if now is None else int(now)
        # Callers may also swap ``self.cluster`` directly between ticks; the
        # reused balancer must follow it either way.
        self._sptlb.cluster = self.cluster
        p = self.cluster.problem
        outlook = (self.planner.outlook(self.now, self.cluster)
                   if self.planner is not None else None)
        d2b_before = M.difference_to_balance(p, p.assignment0)
        triggered, reason = self.should_rebalance(d2b_before, outlook)
        ev = ControllerEvent(self.round, triggered, reason, False, d2b_before)
        if outlook is not None:
            ev.plan_pending = outlook.pending
        budget = self.config.movement_cost_budget
        remaining = float("inf") if budget is None else budget - self.cost_spent
        if triggered and remaining <= 1e-9:
            # The downtime budget is spent: movement is off the table, no
            # matter what the metrics say.  Observable, never silent.
            ev.reason = f"{reason}; movement budget exhausted"
            ev.budget_limited = True
            self.budget_overruns += 1
        elif triggered:
            t0 = time.perf_counter()
            coop_cfg = dataclasses.replace(
                self.config.coop, plan=outlook, move_cost=move_costs(p),
                cost_budget=remaining)
            decision = self._sptlb.balance(
                self.config.engine, timeout_s=self.config.timeout_s,
                config=coop_cfg)
            ev.time_s = time.perf_counter() - t0
            ev.d2b_after = decision.difference_to_balance
            ev.moved = decision.projected.num_moved
            ev.movement_cost = decision.movement_cost
            if decision.budget_trimmed:
                ev.budget_limited = True
                self.budget_overruns += 1
            # A decision the budget trimmed down to nothing executed nothing:
            # marking it applied would reset the cooldown and count a no-op
            # rebalance in the audit.
            trimmed_to_noop = (decision.budget_trimmed
                               and decision.projected.num_moved == 0)
            if (not self.config.dry_run and decision.violations.ok
                    and not trimmed_to_noop):
                self.cluster = dataclasses.replace(
                    self.cluster,
                    problem=p.with_assignment0(
                        jnp.asarray(decision.assignment)))
                self._sptlb.cluster = self.cluster   # next tick re-syncs too
                self.last_applied_round = self.round
                ev.applied = True
                self.cost_spent += decision.movement_cost
        self.history.append(ev)
        return ev

    def audit(self) -> dict:
        """Summary of the decision trail (§3.3's emitted metrics)."""
        applied = [e for e in self.history if e.applied]
        return {
            "rounds": self.round,
            "rebalances": len(applied),
            "total_moved": sum(e.moved for e in applied),
            "mean_improvement": float(np.mean(
                [e.d2b_before - e.d2b_after for e in applied]))
            if applied else 0.0,
            "movement_cost": round(self.cost_spent, 4),
            "movement_cost_budget": self.config.movement_cost_budget,
            "budget_overruns": self.budget_overruns,
        }
