"""Continuous-balancing controller: SPTLB as a long-running service.

The paper's §3.3 decision-execution stage, made operational: instead of a
one-shot solve, a controller periodically samples telemetry, decides
*whether* to rebalance (hysteresis — the paper's criticality/downtime goals
exist precisely because gratuitous movement is expensive), applies the
decision, and keeps an audit trail ("decision evaluation can also result in
finding bugs with the solver").

Policies:
  * trigger: rebalance only when difference-to-balance exceeds
    ``trigger_d2b``, any tier exceeds its ideal utilization by
    ``trigger_over_ideal``, or at least ``trigger_slo_apps`` live apps sit
    on a tier no longer eligible for their SLO class (capacity events and
    outages strand incumbents — constraint 4 read as a state),
  * anticipation: with declared maintenance advisories on board
    (an ``AdvisoryBatch`` event), a ``core.planner.MaintenancePlanner``
    derives
    per-tick capacity/eligibility targets over the declared horizon; an
    active outlook triggers proactively and the solver balances against
    the planning problem — evacuation starts *before* the first ramp step
    instead of after SLO-stranded triggers fire,
  * movement budget: every applied decision is priced
    (``core.planner.move_costs``, Madsen-style reconfiguration cost) and
    charged against ``movement_cost_budget`` for the controller's
    lifetime; decisions that would overrun are trimmed inside the
    cooperation loop and exhausted budgets block movement entirely
    (``budget_overruns`` counts both),
  * cooldown: at least ``cooldown_rounds`` collection rounds between moves,
  * dry_run: compute + log decisions without applying (shadow mode — how a
    new scheduler is actually rolled out at scale).

Externally-evolved clusters: the controller is driven by whoever owns the
telemetry loop (``repro.sim.harness`` in the fleet simulator).  Callers
hand the evolved cluster to ``step(TickInput(cluster=...))`` (or assign
``self.cluster`` between ticks); the controller re-syncs its reused
``Sptlb`` either way, so capacity events, demand drift, and churn
(``valid``-mask flips) are picked up without rebuilding the controller or
losing cooldown/audit state.

Public surface (this is the redesigned API):

  * ``step(TickInput) -> TickResult`` — one control round, decomposed into
    observe / decide / actuate phases.  ``TickInput.events`` carries typed
    ``ServiceEvent`` records (``repro.service.events``, duck-typed on
    ``kind`` so core never imports service); ``TickInput.dirty_shards``
    scopes the sharded solve to a dirty region (delta solve).
  * ``ingest(event)`` — fold one event into controller state between
    rounds (advisory schedules, fault windows, telemetry/capacity/
    membership deltas).

The pre-PR-9 entry points (``tick`` / ``observe`` / ``set_advisories`` /
``admit``) are gone; callers use ``step(TickInput)`` / ``ingest``.
"""
from __future__ import annotations

import dataclasses
import enum
import time
import warnings
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core.health import (BreakerBoard, BreakerConfig, HealthConfig,
                               TelemetryHealth, TelemetryMonitor)
from repro.core.levels import CoopConfig
from repro.core.planner import (MaintenancePlanner, PlannerConfig, PlanOutlook,
                                move_costs)
from repro.core.problem import utilization_fraction
from repro.core.shedding import LoadShedder, ShedConfig
from repro.core.sptlb import Sptlb
from repro.core.telemetry import ClusterState


class Mode(str, enum.Enum):
    """Controller operating modes, ordered by how degraded the control
    plane believes itself to be.  A ``str`` enum so audit records and
    BENCH JSON serialize the mode name directly.

    * NORMAL       — full trigger policy, full movement budget.
    * CONSERVATIVE — strand-fixing moves only (apps whose home tier is
      SLO-ineligible or over hard capacity), per-tick movement budget
      halved.  Entered when the composite health score degrades.
    * SAFE         — no moves at all except evacuating failing tiers; the
      balance trigger itself requires evacuation candidates.  Entered when
      the control plane is effectively blind or the solver/levels are
      failing.
    """

    NORMAL = "normal"
    CONSERVATIVE = "conservative"
    SAFE = "safe"


_MODE_RANK = {Mode.NORMAL: 0, Mode.CONSERVATIVE: 1, Mode.SAFE: 2}


@dataclasses.dataclass(frozen=True)
class FaultToleranceConfig:
    """Arms the degraded-mode control plane (``ControllerConfig.fault``).

    The composite health score in [0, 1] is the product of three factors:
    telemetry health (``core.health.TelemetryMonitor``), the breaker
    board's open-level factor, and ``1 - solver_distress`` (an EWMA over
    the cooperation ``accepted`` flag — a solver that keeps timing out or
    failing drags the score down without consulting any wall clock, so
    mode decisions stay deterministic).  Transitions *down* (toward SAFE)
    are immediate; transitions *up* require the score to clear the current
    mode's floor threshold plus ``recover_margin`` for ``recover_ticks``
    consecutive ticks, one mode step per tick — the hysteresis that keeps
    modes from flapping.
    """

    health: HealthConfig = dataclasses.field(default_factory=HealthConfig)
    breakers: BreakerConfig = dataclasses.field(default_factory=BreakerConfig)
    conservative_below: float = 0.7
    safe_below: float = 0.35
    recover_margin: float = 0.1
    recover_ticks: int = 3
    # CONSERVATIVE halves what the remaining trajectory budget allows a
    # single tick to spend.
    budget_factor_conservative: float = 0.5
    # Solver-distress EWMA: weight of the newest accepted/failed sample,
    # and the per-tick decay applied when no solve ran.
    solver_distress_weight: float = 0.5
    solver_distress_decay: float = 0.5


@dataclasses.dataclass(eq=False)
class ControllerConfig:
    trigger_d2b: float = 0.15
    trigger_over_ideal: float = 0.05
    # Trigger when this many live apps are stranded on SLO-ineligible tiers
    # (None disables the check).  Default 1: any stranded app is an active
    # SLO breach, and waiting for the *balance* metrics to drift far enough
    # would leave it stranded through the whole event.
    trigger_slo_apps: Optional[int] = 1
    cooldown_rounds: int = 3
    engine: str = "local"
    # Legacy cooperation knobs, folded into ``coop`` when it is not given
    # explicitly (kept so historical ControllerConfig(...) call sites work).
    variant: str = "manual_cnst"
    timeout_s: int = 30
    dry_run: bool = False
    restart_rounds: int = 0
    # Maintenance anticipation: lookahead (ticks) over declared advisories
    # and the declared-capacity fraction below which a tier is premasked.
    # Only engages once ``set_advisories`` hands the controller a schedule.
    anticipation_horizon: int = 12
    drain_avoid_threshold: float = 0.5
    # Trajectory-level movement budget in ``core.planner.move_costs`` units
    # (mean live app == 1.0); None leaves movement priced but uncapped.
    movement_cost_budget: Optional[float] = None
    # The cooperation configuration every tick's balance runs under —
    # variant, round cap, premask, restarts, and the scheduler-level stack
    # (``coop.levels`` names, e.g. ("region", "host", "shard")).  The
    # controller fills the per-tick dynamic fields (plan / move_cost /
    # cost_budget) itself via dataclasses.replace.
    coop: Optional[CoopConfig] = None
    # Degraded-mode control plane: None (default) disables telemetry
    # health, circuit breakers, and operating modes entirely — the
    # controller behaves bit-identically to the pre-fault code path.
    fault: Optional[FaultToleranceConfig] = None
    # Overload shedding (core.shedding): None (default) disables.  A
    # ShedConfig arms a LoadShedder that computes utility-optimal delivery
    # caps each tick; it requires utility curves on the problem
    # (``Problem.has_utility``) and is a no-op without them.  Cap
    # transitions are priced against ``movement_cost_budget`` and published
    # as SHED advisories.
    shed: Optional[ShedConfig] = None
    # Sharded fleet solver (repro.shard): partition the fleet into this
    # many region-affine shards and solve them as one batched vmapped pass
    # with coordinator-granted boundary migrations, instead of the global
    # Sptlb engine.  None (default) keeps the global path bit-identical.
    shards: Optional[int] = None

    def __post_init__(self):
        if self.coop is None:
            self.coop = CoopConfig(variant=self.variant,
                                   restart_rounds=self.restart_rounds)
            return
        # Same shim precedence as Sptlb.balance/cooperate: a legacy field
        # the caller actually set (non-default) that disagrees with an
        # explicit coop config warns and overrides (after folding they
        # agree, so dataclasses.replace stays silent).
        import warnings as _warnings
        for legacy, default in (("variant", "manual_cnst"),
                                ("restart_rounds", 0)):
            value = getattr(self, legacy)
            if value != default and value != getattr(self.coop, legacy):
                _warnings.warn(
                    f"ControllerConfig({legacy}=...) is deprecated alongside "
                    f"an explicit coop config; the legacy value overrides — "
                    f"set CoopConfig({legacy}=...) instead",
                    DeprecationWarning, stacklevel=3)
                self.coop = dataclasses.replace(self.coop, **{legacy: value})


@dataclasses.dataclass
class ControllerEvent:
    round: int
    triggered: bool
    reason: str
    applied: bool
    d2b_before: float
    d2b_after: Optional[float] = None
    moved: int = 0
    time_s: float = 0.0
    # Priced reconfiguration cost of the decision (0 when nothing solved)
    # and whether the movement budget bound this round (trimmed proposal or
    # exhausted budget blocking the solve).
    movement_cost: float = 0.0
    budget_limited: bool = False
    # Declared advisories inside the planning horizon this round.
    plan_pending: int = 0
    # Overload shedding this round: apps capped after the plan, cap
    # transitions executed, and their priced reconfiguration cost (charged
    # to the movement budget on top of ``movement_cost``).
    shed_active: int = 0
    shed_churn: int = 0
    shed_cost: float = 0.0
    # Degraded-mode state at this tick (NORMAL/1.0 when fault tolerance is
    # disabled — the fields exist either way so audits stay uniform).
    mode: str = Mode.NORMAL.value
    health_score: float = 1.0


@dataclasses.dataclass(frozen=True)
class TickInput:
    """Everything one control round may consume, as one typed record.

    Replaces the legacy ``tick(cluster=..., now=..., collected_at=...)``
    kwargs.  ``events`` is a sequence of ``ServiceEvent`` records folded in
    (via ``ingest``) before the observe phase; ``dirty_shards`` scopes the
    sharded solve to those shard indices (the delta-solve path — ignored
    on the global engine, where there is no incremental structure to
    exploit)."""

    cluster: Optional[ClusterState] = None
    now: Optional[int] = None
    collected_at: Optional[int] = None
    events: tuple = ()
    dirty_shards: Optional[tuple] = None
    # Shard count the dirty ids were computed against.  Only consulted when
    # ``dirty_shards`` is given and the config has no standing shard count:
    # it lets a delta solve route through the partitioned solver while full
    # passes keep the (higher-quality, cross-region) global engine.
    num_shards: Optional[int] = None


@dataclasses.dataclass
class TickResult:
    """What one control round produced.

    Wraps the audit-trail ``ControllerEvent`` (every legacy field is
    reachable directly on the result — attribute access delegates) plus
    the full ``BalanceDecision`` when a solve ran, the advisories that
    expired this round, and whether the solve was scoped to a dirty
    region (``delta``)."""

    event: ControllerEvent
    decision: Optional[object] = None  # core.sptlb.BalanceDecision
    expired_advisories: tuple = ()
    delta: bool = False

    def __getattr__(self, name):
        # Delegation keeps ``res.applied`` / ``res.reason`` / ... working
        # for code written against the ControllerEvent return type.
        return getattr(self.event, name)


class BalanceController:
    def __init__(self, cluster: ClusterState,
                 config: ControllerConfig = ControllerConfig()):
        self.cluster = cluster
        self.config = config
        self.round = 0
        self.last_applied_round = -10**9
        self.last_applied_now = -10**9
        self.history: list[ControllerEvent] = []
        # One balancer for the controller's lifetime: re-instantiating it
        # every trigger discarded nothing expensive per se, but the cluster
        # it points at carries the memoized hierarchy precomputes — keep
        # both in lock-step instead of rebuilding per tick.
        self._sptlb = Sptlb(cluster)
        # Anticipation + movement accounting (see module docstring).
        self.planner: Optional[MaintenancePlanner] = None
        self.now = 0                      # external tick of the last tick()
        self.cost_spent = 0.0             # applied movement cost, lifetime
        self.budget_overruns = 0          # rounds the budget bound movement
        # Degraded-mode control plane (all inert when config.fault is None).
        fault = config.fault
        self.monitor = (TelemetryMonitor(fault.health)
                        if fault is not None else None)
        self.board = (BreakerBoard(fault.breakers)
                      if fault is not None else None)
        self.mode = Mode.NORMAL
        self.mode_transitions: list[dict] = []
        self.health: Optional[TelemetryHealth] = None
        self._recover_streak = 0
        self._solver_distress = 0.0
        # Overload shedding (inert when config.shed is None): the shedder
        # holds the per-app delivery caps across ticks; every cap transition
        # is appended to ``shed_advisories`` (SHED-kind records).
        self.shedder = (LoadShedder(config.shed)
                        if config.shed is not None else None)
        self.shed_advisories: list = []
        # Admission gate: owners attach a streams.admission
        # AdmissionController here (duck-typed — core stays free of a
        # streams import); ``admit`` then prices arrivals in the current
        # operating mode.
        self.admission = None
        # Test/chaos hook: an explicit Hierarchy the balance pass should use
        # instead of the config's level names (the sim's LevelFault event
        # swaps in a faulty wrapper here).
        self.hierarchy_override = None
        # Advisory lifecycle: one record per declared advisory tracking
        # whether a solve was applied while it steered the planning horizon
        # (``acted``).  An advisory whose deadline passes unacted — e.g. the
        # controller sat in SAFE through the whole window — raises the
        # catch-up flag, which forces one post-recovery rebalance instead of
        # silently forgetting the event ever happened.
        self._advisory_log: list[dict] = []
        self.advisory_expiries: list[dict] = []
        self._advisory_catchup = False
        # Externally-declared fault windows (FaultSignal events): (until,
        # severity) pairs folded into the composite health score while
        # ``now < until``.
        self._ext_faults: list[tuple[int, float]] = []

    def _set_advisories(self, advisories, *,
                        horizon: Optional[int] = None) -> None:
        """Hand the controller a declared maintenance schedule (a sequence
        of ``core.planner.Advisory``).  An empty schedule disables
        anticipation; the budget and history are untouched either way."""
        advisories = tuple(advisories)
        if not advisories or self.config.anticipation_horizon <= 0:
            self.planner = None
            self._advisory_log = []
            return
        self.planner = MaintenancePlanner(
            advisories,
            PlannerConfig(
                horizon=(self.config.anticipation_horizon
                         if horizon is None else horizon),
                drain_threshold=self.config.drain_avoid_threshold))
        self._advisory_log = [
            {"advisory": a, "acted": False, "expired": False}
            for a in self.planner.advisories]

    # -- admission gate (requires an attached streams.admission controller) --
    def _admit(self, *, demand, tasks, slo, criticality, key,
               app_id: Optional[int] = None):
        """Price one arriving app in the current operating mode.

        Delegates to the attached ``AdmissionController`` (``admission``):
        CONSERVATIVE tightens the headroom margin and disables degraded
        admissions, SAFE rejects non-critical arrivals outright.  When the
        arrival occupies a known pool row (``app_id``) and the decision is
        admit-degraded, its delivery cap is registered with the shedder so
        it lifts through the same hysteretic re-admission.
        """
        if self.admission is None:
            raise RuntimeError("no AdmissionController attached "
                               "(set controller.admission)")
        decision = self.admission.decide(
            self.cluster.problem, demand=demand, tasks=tasks, slo=slo,
            criticality=criticality, key=key, mode=self.mode.value,
            now=self.now)
        if (app_id is not None and self.shedder is not None
                and decision.state.value == "admit_degraded"):
            self.shedder._ensure(self.cluster.problem.num_apps)
            self.shedder.set_cap(app_id, decision.cap)
        return decision

    # -- event ingestion ------------------------------------------------------
    def ingest(self, event) -> None:
        """Fold one ``ServiceEvent`` into controller state.

        Dispatch is duck-typed on ``event.kind`` (core never imports
        ``repro.service``).  Fleet-state events mutate ``self.cluster``
        directly — the standalone path for callers without a service loop;
        under a loop the ``FleetShadow`` owns fleet state and only
        advisory/fault events reach here."""
        kind = getattr(event, "kind", None)
        if kind == "advisories":
            self._set_advisories(event.advisories, horizon=event.horizon)
        elif kind == "fault":
            self._ext_faults.append((int(event.until),
                                     float(event.severity)))
        elif kind == "telemetry":
            p = self.cluster.problem
            ids = jnp.asarray(np.asarray(event.app_ids, np.int64))
            demand = p.demand.at[ids].set(
                jnp.asarray(event.demand, p.demand.dtype).reshape(
                    ids.shape[0], -1))
            tasks = p.tasks.at[ids].set(
                jnp.asarray(event.tasks, p.tasks.dtype).reshape(-1))
            self._observe(dataclasses.replace(
                self.cluster,
                problem=dataclasses.replace(p, demand=demand, tasks=tasks),
                collected_at=max(self.cluster.collected_at,
                                 int(event.collected_at))))
        elif kind == "capacity":
            p = self.cluster.problem
            fields = {}
            for name in ("capacity", "task_limit", "slo_allowed"):
                value = getattr(event, name)
                if value is not None:
                    fields[name] = jnp.asarray(value)
            cl = dataclasses.replace(
                self.cluster, problem=dataclasses.replace(p, **fields))
            if event.region_latency is not None:
                cl = dataclasses.replace(
                    cl, region_latency=np.asarray(event.region_latency))
            if event.hosts_per_tier is not None:
                cl = dataclasses.replace(
                    cl, hosts_per_tier=np.asarray(event.hosts_per_tier))
            self._observe(cl)
        elif kind == "arrival":
            p = self.cluster.problem
            n = int(event.app_id)
            x0 = p.assignment0
            if event.tier >= 0:
                x0 = x0.at[n].set(int(event.tier))
            self._observe(dataclasses.replace(
                self.cluster, problem=dataclasses.replace(
                    p,
                    valid=p.valid.at[n].set(True),
                    demand=p.demand.at[n].set(
                        jnp.asarray(event.demand, p.demand.dtype)),
                    tasks=p.tasks.at[n].set(float(event.tasks)),
                    slo=p.slo.at[n].set(int(event.slo)),
                    criticality=p.criticality.at[n].set(
                        float(event.criticality)),
                    assignment0=x0)))
        elif kind == "departure":
            p = self.cluster.problem
            n = int(event.app_id)
            self._observe(dataclasses.replace(
                self.cluster, problem=dataclasses.replace(
                    p,
                    valid=p.valid.at[n].set(False),
                    demand=p.demand.at[n].set(0.0),
                    tasks=p.tasks.at[n].set(0.0))))
        else:
            raise ValueError(f"unknown service event kind: {kind!r}")

    # -- trigger policy -----------------------------------------------------
    def should_rebalance(self, d2b: Optional[float] = None,
                         outlook: Optional[PlanOutlook] = None
                         ) -> tuple[bool, str]:
        """Trigger decision.  ``d2b`` lets ``tick`` pass the
        difference-to-balance it already computed instead of paying the
        tier-loads reduction twice per round; ``outlook`` is the planner's
        view of the declared horizon (an active outlook triggers
        proactively — the whole point of declared maintenance)."""
        cfg = self.config
        p = self.cluster.problem
        if d2b is None:
            d2b = M.difference_to_balance(p, p.assignment0)
        # Cooldown is wall-clock (``now``), not controller rounds: under an
        # event-driven frontend the controller only steps on solve-worthy
        # ticks, and counting rounds would stretch the cooldown across
        # arbitrarily many quiescent wall ticks.  In lockstep operation the
        # two clocks advance together, so the semantics are unchanged.
        if self.now - self.last_applied_now < cfg.cooldown_rounds:
            return False, f"cooldown ({d2b=:.3f})"
        if outlook is not None and outlook.active:
            return True, (
                f"declared-maintenance ({outlook.pending} advisories within "
                f"{outlook.horizon} ticks, min capacity factor "
                f"{float(outlook.tier_factor.min()):.2f})")
        uf, tf = utilization_fraction(p, p.assignment0)
        over = float(jnp.max(uf - p.ideal_frac))
        over_t = float(jnp.max(tf - p.ideal_task_frac))
        if d2b > cfg.trigger_d2b:
            return True, f"d2b {d2b:.3f} > {cfg.trigger_d2b}"
        if max(over, over_t) > cfg.trigger_over_ideal:
            return True, f"over-ideal {max(over, over_t):.3f}"
        if cfg.trigger_slo_apps is not None:
            slo_ok = p.slo_allowed[p.assignment0, p.slo]
            stranded = int(jnp.sum(~slo_ok & p.valid))
            if stranded >= cfg.trigger_slo_apps:
                return True, f"slo-stranded apps {stranded}"
        return False, f"balanced ({d2b=:.3f})"

    def _observe(self, cluster: ClusterState) -> None:
        """Adopt an externally-evolved cluster (fresh telemetry, capacity
        events, churn) without losing cooldown/audit state."""
        self.cluster = cluster
        self._sptlb.cluster = cluster

    # -- degraded-mode machinery (inert when config.fault is None) -----------
    def _evacuation_mask(self, p) -> np.ndarray:
        """bool[N]: live apps whose *home* placement is already failing —
        SLO-ineligible tier, or a tier over hard capacity.  These are the
        only apps SAFE mode will move (and the strand-fixers CONSERVATIVE
        mode restricts itself to)."""
        x0 = np.asarray(p.assignment0)
        live = np.asarray(p.valid, bool)
        slo_ok = np.asarray(p.slo_allowed)[x0, np.asarray(p.slo)]
        uf, _ = utilization_fraction(p, p.assignment0)
        over_cap = np.asarray(uf).max(axis=-1) > 1.0 + 1e-6   # [T]
        return live & (~slo_ok | over_cap[x0])

    @staticmethod
    def _mode_avoid(p, movable: np.ndarray) -> np.ndarray:
        """[N, T] avoid mask holding every non-``movable`` app on its home
        tier (home column open — staying put is always legal)."""
        hold = np.ones((p.num_apps, p.num_tiers), bool)
        hold[movable] = False
        hold[np.arange(p.num_apps), np.asarray(p.assignment0)] = False
        return hold

    def _composite_score(self) -> float:
        telemetry = self.health.score if self.health is not None else 1.0
        board = self.board.health_factor() if self.board is not None else 1.0
        score = float(telemetry * board * (1.0 - self._solver_distress))
        # Externally-declared fault windows (FaultSignal events) degrade the
        # score while active; expired windows are pruned as time passes.
        self._ext_faults = [(u, s) for (u, s) in self._ext_faults
                            if self.now < u]
        for _, severity in self._ext_faults:
            score *= max(0.0, 1.0 - severity)
        return score

    def _transition(self, to: Mode, score: float) -> None:
        self.mode_transitions.append({
            "tick": self.now, "round": self.round,
            "from": self.mode.value, "to": to.value,
            "score": round(score, 4)})
        self.mode = to

    def _update_mode(self, score: float) -> None:
        """Hysteretic mode machine: degrade immediately (straight to SAFE
        when warranted), recover one step per tick and only after the score
        has cleared the current mode's floor plus ``recover_margin`` for
        ``recover_ticks`` consecutive ticks."""
        f = self.config.fault
        target = (Mode.SAFE if score < f.safe_below
                  else Mode.CONSERVATIVE if score < f.conservative_below
                  else Mode.NORMAL)
        if _MODE_RANK[target] > _MODE_RANK[self.mode]:
            self._transition(target, score)
            self._recover_streak = 0
            return
        if _MODE_RANK[target] < _MODE_RANK[self.mode]:
            floor = (f.safe_below if self.mode is Mode.SAFE
                     else f.conservative_below)
            if score >= floor + f.recover_margin:
                self._recover_streak += 1
            else:
                self._recover_streak = 0
            if self._recover_streak >= f.recover_ticks:
                up = (Mode.CONSERVATIVE if self.mode is Mode.SAFE
                      else Mode.NORMAL)
                self._transition(up, score)
                self._recover_streak = 0
            return
        self._recover_streak = 0

    def _note_solve(self, accepted: bool) -> None:
        w = self.config.fault.solver_distress_weight
        self._solver_distress = ((1.0 - w) * self._solver_distress
                                 + w * (0.0 if accepted else 1.0))

    # -- advisory lifecycle ---------------------------------------------------
    def _expire_advisories(self) -> tuple:
        """Expire advisories whose deadline has passed.

        This is the stale-advisory fix: an advisory whose ``at`` tick goes
        by while the controller is held (SAFE mode, exhausted budget) used
        to vanish silently — ``MaintenancePlanner.outlook`` only looks at
        ``now < at``, so on recovery nothing ever re-phased the fleet for
        the event that already happened.  Expiry is now explicit: each
        record lands in ``advisory_expiries`` (audited), and an *unacted*
        expiry raises the catch-up flag that forces one rebalance when the
        controller is next free to move."""
        expired = []
        for rec in self._advisory_log:
            a = rec["advisory"]
            if not rec["expired"] and a.at <= self.now:
                rec["expired"] = True
                entry = {"tick": self.now, "kind": a.kind, "tier": a.tier,
                         "at": a.at, "acted": rec["acted"]}
                self.advisory_expiries.append(entry)
                expired.append(entry)
                if not rec["acted"]:
                    self._advisory_catchup = True
        return tuple(expired)

    def _mark_advisories_acted(self) -> None:
        """A decision was applied at ``self.now``: every advisory currently
        steering the planning horizon has been acted on."""
        if self.planner is None:
            return
        horizon = self.planner.config.horizon
        for rec in self._advisory_log:
            a = rec["advisory"]
            if not rec["expired"] and self.now < a.at <= self.now + horizon:
                rec["acted"] = True

    # -- one control round ----------------------------------------------------
    def step(self, inp: Optional[TickInput] = None) -> TickResult:
        """One control round: observe -> decide -> actuate.

        ``inp.now`` is the external clock the advisory schedule is declared
        against (the sim harness passes its tick); callers without one get
        the controller's own 0-based round count.  ``inp.collected_at``
        stamps when the observed telemetry was actually collected (defaults
        to the cluster's own ``collected_at``); with fault tolerance armed,
        ``now - collected_at`` is the staleness the telemetry monitor
        scores."""
        inp = inp if inp is not None else TickInput()
        self._observe_phase(inp)
        plan = self._decide_phase(inp)
        return self._actuate_phase(inp, plan)

    def _observe_phase(self, inp: TickInput) -> None:
        """Adopt the world: the handed cluster, queued events, the clock,
        then (fault-armed) telemetry sanitation and the mode machine."""
        if inp.cluster is not None:
            self._observe(inp.cluster)
        for event in inp.events:
            self.ingest(event)
        self.round += 1
        self.now = (self.round - 1) if inp.now is None else int(inp.now)
        fault = self.config.fault
        if fault is not None:
            # Sanitize first: quarantined/implausible readings are replaced
            # by last-known-good values (inflated with staleness), and every
            # downstream decision this tick plans against the sanitized view.
            # A cluster nobody ever stamped (collected_at at its default 0)
            # reads as fresh — staleness only engages for producers that
            # participate in the stamping protocol.
            collected_at = inp.collected_at
            if collected_at is None:
                collected_at = (self.cluster.collected_at
                                if self.cluster.collected_at else self.now)
            sanitized, self.health = self.monitor.ingest(
                self.cluster, self.now, collected_at)
            self._observe(sanitized)
            self._update_mode(self._composite_score())
        # Callers may also swap ``self.cluster`` directly between ticks; the
        # reused balancer must follow it either way.
        self._sptlb.cluster = self.cluster

    def _decide_phase(self, inp: Optional[TickInput] = None) -> dict:
        """Everything between fresh telemetry and the solver: shed caps,
        the planning outlook, advisory expiry, the trigger policy, mode
        gating, and the movement budget.  Returns the actuation plan."""
        inp = inp if inp is not None else TickInput()
        fault = self.config.fault
        p = self.cluster.problem
        # Overload shedding runs first (in every mode — capping demand needs
        # no movement and only reduces risk): the plan's caps are the
        # actuated throttles this tick's balance and evaluation run under.
        shed_plan = None
        if self.shedder is not None and p.has_utility:
            budget = self.config.movement_cost_budget
            shed_remaining = (float("inf") if budget is None
                              else max(0.0, budget - self.cost_spent))
            shed_plan = self.shedder.plan(
                p, move_cost=np.asarray(move_costs(p)),
                budget=shed_remaining, now=self.now)
            if shed_plan.churned:
                self.cost_spent += shed_plan.churn_cost
                self.shed_advisories.extend(shed_plan.advisories)
        outlook = (self.planner.outlook(self.now, self.cluster)
                   if self.planner is not None else None)
        expired = self._expire_advisories()
        d2b_before = M.difference_to_balance(p, p.assignment0)
        triggered, reason = self.should_rebalance(d2b_before, outlook)
        if (not triggered and inp.dirty_shards is not None
                and self.now - self.last_applied_now
                >= self.config.cooldown_rounds):
            # A delta request arrives pre-triggered: the caller's drift
            # detector already judged the dirty region solve-worthy, and a
            # scoped sharded solve is too cheap to double-gate behind the
            # lockstep trigger thresholds.  Cooldown and the mode gates
            # below still apply.
            triggered = True
            reason = (f"drift delta over {len(inp.dirty_shards)} dirty "
                      f"shards ({reason})")
        if shed_plan is not None and shed_plan.churned and not triggered:
            # Cap transitions change what the fleet serves this tick —
            # rebalance promptly (overrides cooldown, like declared events).
            triggered = True
            reason = (f"overload-shed churn ({len(shed_plan.shed_ids)} shed, "
                      f"{len(shed_plan.readmitted_ids)} readmitted; {reason})")
        evac = None
        if fault is not None and self.mode is not Mode.NORMAL:
            evac = self._evacuation_mask(p)
            n_evac = int(evac.sum())
            if self.mode is Mode.SAFE:
                # SAFE: the only acceptable reason to move is evacuation.
                if triggered and n_evac == 0:
                    triggered = False
                    reason = f"safe-mode hold ({reason})"
                elif triggered:
                    reason = f"safe-mode evacuation of {n_evac} apps ({reason})"
            elif triggered and n_evac == 0:
                # CONSERVATIVE with nothing stranded: every move would be a
                # balance optimization on suspect data — hold.
                triggered = False
                reason = f"conservative hold ({reason})"
            elif triggered:
                reason = f"conservative strand-fix of {n_evac} apps ({reason})"
        if (not triggered and self._advisory_catchup
                and (fault is None or self.mode is Mode.NORMAL)):
            # An advisory deadline passed while the controller was held
            # (SAFE/CONSERVATIVE or budget-blocked): the fleet was never
            # re-phased for the event.  Force one rebalance now that moving
            # is acceptable again — overrides cooldown, like declared events.
            triggered = True
            reason = f"expired-advisory catch-up ({reason})"
        ev = ControllerEvent(self.round, triggered, reason, False, d2b_before,
                             mode=self.mode.value,
                             health_score=round(self._composite_score(), 4)
                             if fault is not None else 1.0)
        if outlook is not None:
            ev.plan_pending = outlook.pending
        if shed_plan is not None:
            ev.shed_active = int(np.sum(shed_plan.caps < 1.0))
            ev.shed_churn = shed_plan.churned
            ev.shed_cost = shed_plan.churn_cost
        budget = self.config.movement_cost_budget
        remaining = float("inf") if budget is None else budget - self.cost_spent
        if (fault is not None and self.mode is Mode.CONSERVATIVE
                and remaining != float("inf")):
            remaining = remaining * fault.budget_factor_conservative
        return {"ev": ev, "triggered": triggered, "outlook": outlook,
                "shed_plan": shed_plan, "evac": evac, "remaining": remaining,
                "expired": expired}

    def _actuate_phase(self, inp: TickInput, plan: dict) -> TickResult:
        """Run (or skip) the solve the decide phase asked for and commit
        its consequences: the applied assignment, the movement ledger,
        solver-distress accounting, and the audit trail."""
        fault = self.config.fault
        p = self.cluster.problem
        ev = plan["ev"]
        triggered = plan["triggered"]
        outlook = plan["outlook"]
        shed_plan = plan["shed_plan"]
        evac = plan["evac"]
        remaining = plan["remaining"]
        reason = ev.reason
        decision = None
        delta = False
        if triggered and remaining <= 1e-9:
            # The downtime budget is spent: movement is off the table, no
            # matter what the metrics say.  Observable, never silent.
            ev.reason = f"{reason}; movement budget exhausted"
            ev.budget_limited = True
            self.budget_overruns += 1
        elif triggered:
            t0 = time.perf_counter()
            coop_cfg = dataclasses.replace(
                self.config.coop, plan=outlook, move_cost=move_costs(p),
                cost_budget=remaining, shed=shed_plan)
            balance_cluster = self.cluster
            if fault is not None:
                coop_cfg = dataclasses.replace(coop_cfg, breakers=self.board)
                if self.mode is not Mode.NORMAL:
                    # Mode-restricted movement: everyone outside the
                    # evacuation set is held home by a standing avoid mask
                    # (the solver literally cannot propose other moves).
                    balance_cluster = dataclasses.replace(
                        self.cluster, problem=p.with_avoid(
                            jnp.asarray(self._mode_avoid(p, evac))))
            dirty = inp.dirty_shards
            delta = dirty is not None
            shards = self.config.shards or (inp.num_shards if delta else None)
            if shards:
                # Sharded fleet path: partitioned batched solve + the
                # FleetCoordinator's priced boundary migrations, under the
                # same BalanceDecision contract (plan steering, shed caps,
                # and the movement budget all ride coop_cfg).  A dirty-region
                # scope from the service loop turns this into a delta solve;
                # without a standing config.shards, *only* delta solves route
                # here and full passes keep the global engine.
                from repro.shard import FleetConfig, balance_fleet
                decision = balance_fleet(
                    balance_cluster,
                    fleet=FleetConfig(num_shards=shards,
                                      timeout_s=self.config.timeout_s),
                    coop=coop_cfg,
                    dirty_shards=dirty)
            else:
                self._sptlb.cluster = balance_cluster
                decision = self._sptlb.balance(
                    self.config.engine, timeout_s=self.config.timeout_s,
                    config=coop_cfg, hierarchy=self.hierarchy_override)
                self._sptlb.cluster = self.cluster
            if fault is not None:
                coop = decision.cooperation
                # Solver distress means the solver *couldn't answer*, not
                # that the answer was hard: an unaccepted pass that still
                # had rounds left exited on wall-clock (a brownout), and an
                # unconverged zero-iteration result is the bus's dead-solver
                # fallback.  A pass that merely exhausted its round budget
                # on a contentious workload is healthy.
                timed_out = (coop is not None and not coop.accepted
                             and coop.timings.rounds <= coop_cfg.max_rounds)
                dead = (decision.solve.iterations == 0
                        and not decision.solve.converged)
                self._note_solve(not (timed_out or dead))
            ev.time_s = time.perf_counter() - t0
            ev.d2b_after = decision.difference_to_balance
            ev.moved = decision.projected.num_moved
            ev.movement_cost = decision.movement_cost
            if decision.budget_trimmed:
                ev.budget_limited = True
                self.budget_overruns += 1
            # A decision the budget trimmed down to nothing executed nothing:
            # marking it applied would reset the cooldown and count a no-op
            # rebalance in the audit.
            trimmed_to_noop = (decision.budget_trimmed
                               and decision.projected.num_moved == 0)
            if (not self.config.dry_run and decision.violations.ok
                    and not trimmed_to_noop):
                self.cluster = dataclasses.replace(
                    self.cluster,
                    problem=p.with_assignment0(
                        jnp.asarray(decision.assignment)))
                self._sptlb.cluster = self.cluster   # next tick re-syncs too
                self.last_applied_round = self.round
                self.last_applied_now = self.now
                ev.applied = True
                self.cost_spent += decision.movement_cost
                self._mark_advisories_acted()
                self._advisory_catchup = False
        if fault is not None and not triggered:
            # No solve this tick: solver distress decays toward healthy
            # (the breaker board and telemetry keep their own state).
            self._solver_distress *= fault.solver_distress_decay
        self.history.append(ev)
        return TickResult(event=ev, decision=decision,
                          expired_advisories=plan["expired"], delta=delta)

    def audit(self) -> dict:
        """Summary of the decision trail (§3.3's emitted metrics)."""
        applied = [e for e in self.history if e.applied]
        out = {
            "rounds": self.round,
            "rebalances": len(applied),
            "total_moved": sum(e.moved for e in applied),
            "mean_improvement": float(np.mean(
                [e.d2b_before - e.d2b_after for e in applied]))
            if applied else 0.0,
            "movement_cost": round(self.cost_spent, 4),
            "movement_cost_budget": self.config.movement_cost_budget,
            "budget_overruns": self.budget_overruns,
        }
        if self.advisory_expiries:
            out["advisory_expiries"] = list(self.advisory_expiries)
            out["advisories_expired_unacted"] = sum(
                1 for e in self.advisory_expiries if not e["acted"])
        if self.admission is not None:
            out["admission"] = self.admission.audit()
        if self.shedder is not None:
            out["shed_events"] = self.shedder.shed_events
            out["readmit_events"] = self.shedder.readmit_events
            out["shed_advisories"] = len(self.shed_advisories)
            out["apps_capped"] = (int(np.sum(self.shedder.caps < 1.0))
                                  if self.shedder.caps is not None else 0)
        if self.config.fault is not None:
            out["mode"] = self.mode.value
            out["mode_transitions"] = list(self.mode_transitions)
            out["health_score"] = round(self._composite_score(), 4)
            out["breaker_trips"] = self.board.trips
            out["telemetry_quarantined"] = (self.health.quarantined
                                            if self.health is not None else 0)
        return out
