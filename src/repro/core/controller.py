"""Continuous-balancing controller: SPTLB as a long-running service.

The paper's §3.3 decision-execution stage, made operational: instead of a
one-shot solve, a controller periodically samples telemetry, decides
*whether* to rebalance (hysteresis — the paper's criticality/downtime goals
exist precisely because gratuitous movement is expensive), applies the
decision, and keeps an audit trail ("decision evaluation can also result in
finding bugs with the solver").

Policies:
  * trigger: rebalance only when difference-to-balance exceeds
    ``trigger_d2b``, any tier exceeds its ideal utilization by
    ``trigger_over_ideal``, or at least ``trigger_slo_apps`` live apps sit
    on a tier no longer eligible for their SLO class (capacity events and
    outages strand incumbents — constraint 4 read as a state),
  * cooldown: at least ``cooldown_rounds`` collection rounds between moves,
  * dry_run: compute + log decisions without applying (shadow mode — how a
    new scheduler is actually rolled out at scale).

Externally-evolved clusters: the controller is driven by whoever owns the
telemetry loop (``repro.sim.harness`` in the fleet simulator).  Callers
hand the evolved cluster to ``tick(cluster)`` (or assign ``self.cluster``
between ticks); the controller re-syncs its reused ``Sptlb`` either way, so
capacity events, demand drift, and churn (``valid``-mask flips) are picked
up without rebuilding the controller or losing cooldown/audit state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core.problem import utilization_fraction
from repro.core.sptlb import BalanceDecision, Sptlb
from repro.core.telemetry import ClusterState


@dataclasses.dataclass
class ControllerConfig:
    trigger_d2b: float = 0.15
    trigger_over_ideal: float = 0.05
    # Trigger when this many live apps are stranded on SLO-ineligible tiers
    # (None disables the check).  Default 1: any stranded app is an active
    # SLO breach, and waiting for the *balance* metrics to drift far enough
    # would leave it stranded through the whole event.
    trigger_slo_apps: Optional[int] = 1
    cooldown_rounds: int = 3
    engine: str = "local"
    variant: str = "manual_cnst"
    timeout_s: int = 30
    dry_run: bool = False
    restart_rounds: int = 0


@dataclasses.dataclass
class ControllerEvent:
    round: int
    triggered: bool
    reason: str
    applied: bool
    d2b_before: float
    d2b_after: Optional[float] = None
    moved: int = 0
    time_s: float = 0.0


class BalanceController:
    def __init__(self, cluster: ClusterState,
                 config: ControllerConfig = ControllerConfig()):
        self.cluster = cluster
        self.config = config
        self.round = 0
        self.last_applied_round = -10**9
        self.history: list[ControllerEvent] = []
        # One balancer for the controller's lifetime: re-instantiating it
        # every trigger discarded nothing expensive per se, but the cluster
        # it points at carries the memoized hierarchy precomputes — keep
        # both in lock-step instead of rebuilding per tick.
        self._sptlb = Sptlb(cluster)

    # -- trigger policy -----------------------------------------------------
    def should_rebalance(self, d2b: Optional[float] = None) -> tuple[bool, str]:
        """Trigger decision.  ``d2b`` lets ``tick`` pass the
        difference-to-balance it already computed instead of paying the
        tier-loads reduction twice per round."""
        cfg = self.config
        p = self.cluster.problem
        if d2b is None:
            d2b = M.difference_to_balance(p, p.assignment0)
        if self.round - self.last_applied_round < cfg.cooldown_rounds:
            return False, f"cooldown ({d2b=:.3f})"
        uf, tf = utilization_fraction(p, p.assignment0)
        over = float(jnp.max(uf - p.ideal_frac))
        over_t = float(jnp.max(tf - p.ideal_task_frac))
        if d2b > cfg.trigger_d2b:
            return True, f"d2b {d2b:.3f} > {cfg.trigger_d2b}"
        if max(over, over_t) > cfg.trigger_over_ideal:
            return True, f"over-ideal {max(over, over_t):.3f}"
        if cfg.trigger_slo_apps is not None:
            slo_ok = p.slo_allowed[p.assignment0, p.slo]
            stranded = int(jnp.sum(~slo_ok & p.valid))
            if stranded >= cfg.trigger_slo_apps:
                return True, f"slo-stranded apps {stranded}"
        return False, f"balanced ({d2b=:.3f})"

    def observe(self, cluster: ClusterState) -> None:
        """Adopt an externally-evolved cluster (fresh telemetry, capacity
        events, churn) without losing cooldown/audit state."""
        self.cluster = cluster
        self._sptlb.cluster = cluster

    # -- one control round ----------------------------------------------------
    def tick(self, cluster: Optional[ClusterState] = None) -> ControllerEvent:
        if cluster is not None:
            self.observe(cluster)
        self.round += 1
        # Callers may also swap ``self.cluster`` directly between ticks; the
        # reused balancer must follow it either way.
        self._sptlb.cluster = self.cluster
        p = self.cluster.problem
        d2b_before = M.difference_to_balance(p, p.assignment0)
        triggered, reason = self.should_rebalance(d2b_before)
        ev = ControllerEvent(self.round, triggered, reason, False, d2b_before)
        if triggered:
            t0 = time.perf_counter()
            decision = self._sptlb.balance(
                self.config.engine, timeout_s=self.config.timeout_s,
                variant=self.config.variant,
                restart_rounds=self.config.restart_rounds)
            ev.time_s = time.perf_counter() - t0
            ev.d2b_after = decision.difference_to_balance
            ev.moved = decision.projected.num_moved
            if not self.config.dry_run and decision.violations.ok:
                self.cluster = dataclasses.replace(
                    self.cluster,
                    problem=p.with_assignment0(
                        jnp.asarray(decision.assignment)))
                self._sptlb.cluster = self.cluster   # next tick re-syncs too
                self.last_applied_round = self.round
                ev.applied = True
        self.history.append(ev)
        return ev

    def audit(self) -> dict:
        """Summary of the decision trail (§3.3's emitted metrics)."""
        applied = [e for e in self.history if e.applied]
        return {
            "rounds": self.round,
            "rebalances": len(applied),
            "total_moved": sum(e.moved for e in applied),
            "mean_improvement": float(np.mean(
                [e.d2b_before - e.d2b_after for e in applied]))
            if applied else 0.0,
        }
