"""Solution-quality metrics (paper §3.3 + Figs 3-5).

  * projected per-tier metrics after a proposed mapping (§3.3 output stage),
  * difference-to-balanced-state (Fig. 5 y-axis): worst-over-resources
    distance of final tier utilization from the evenly-balanced state,
  * network p99 latency (Fig. 4): per moved app, sample the source->dest
    region latency table proportionally to apps moved per tier transition,
    build the CDF, report the 99th percentile.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.problem import Problem, utilization_fraction
from repro.core.telemetry import ClusterState


@dataclasses.dataclass
class ProjectedMetrics:
    """The §3.3 solver-output record, emitted per tier."""

    util_frac: np.ndarray     # f32[T, R] projected cpu/mem utilization fraction
    task_frac: np.ndarray     # f32[T]    projected task-count fraction
    num_moved: int
    moved_apps: np.ndarray    # i32[M] app ids that moved
    transitions: dict         # (src, dst) -> count


def projected_metrics(problem: Problem, assignment) -> ProjectedMetrics:
    util_frac, task_frac = utilization_fraction(problem, assignment)
    x = np.asarray(assignment)
    x0 = np.asarray(problem.assignment0)
    moved = np.where(x != x0)[0]
    transitions: dict = {}
    for n in moved:
        key = (int(x0[n]), int(x[n]))
        transitions[key] = transitions.get(key, 0) + 1
    return ProjectedMetrics(
        util_frac=np.asarray(util_frac),
        task_frac=np.asarray(task_frac),
        num_moved=len(moved),
        moved_apps=moved,
        transitions=transitions,
    )


def difference_to_balance(problem: Problem, assignment) -> float:
    """Fig. 5 y-axis: worst-over-resources |final util - balanced state|.

    The balanced state per resource is the even distribution of the total
    demand over total capacity ("even distribution of said resource given the
    initial states"); we take the max difference across all resources and
    tiers — "the worst case scenario for balancing".
    """
    util_frac, task_frac = utilization_fraction(problem, assignment)
    util_frac = np.asarray(util_frac)
    task_frac = np.asarray(task_frac)
    total_frac = (np.asarray(problem.demand).sum(axis=0)
                  / np.asarray(problem.capacity).sum(axis=0))       # [R]
    total_task_frac = (np.asarray(problem.tasks).sum()
                       / np.asarray(problem.task_limit).sum())
    diffs = [np.max(np.abs(util_frac[:, r] - total_frac[r]))
             for r in range(util_frac.shape[1])]
    diffs.append(float(np.max(np.abs(task_frac - total_task_frac))))
    return float(max(diffs))


def network_p99_ms(cluster: ClusterState, assignment, *,
                   num_samples: int = 1000, seed: int = 0) -> float:
    """Fig. 4 metric: worst-case (p99) network latency of the app movements.

    For each (src_tier, dst_tier) transition in the mapping, the latency
    distribution is the cross product of the two tiers' region latencies;
    it is "randomly sampled 1000 times based on the number of apps selected
    for that particular source to destination tier combination", then the
    p99 of the pooled CDF is reported, "approximated to the closest ms".
    """
    pm = projected_metrics(cluster.problem, assignment)
    if pm.num_moved == 0:
        return 0.0
    rng = np.random.default_rng(seed)
    lat = cluster.region_latency
    x = np.asarray(assignment)
    # Latency an app experiences after a move: from its data-source region to
    # the region the destination tier actually places it in.  The in-tier
    # region scheduler prefers the closest region but spills to the next one
    # when host capacity is tight — a geometric spill model (P(best)=1-q,
    # P(next)=q(1-q), ...).  The tail of this distribution is what the p99
    # "worst case scenario network latency" (Fig. 4) is designed to expose.
    spill = 0.15
    per_app: list[np.ndarray] = []
    for n in pm.moved_apps:
        dst_regions = np.where(cluster.tier_regions[x[n]])[0]
        opts = np.sort(lat[cluster.app_region[n], dst_regions])
        probs = spill ** np.arange(len(opts)) * (1 - spill)
        probs[-1] += 1.0 - probs.sum()                    # renormalize tail
        per_app.append((opts, probs))
    k = max(1, num_samples // len(per_app))
    samples = [rng.choice(opts, size=k, replace=True, p=probs)
               for opts, probs in per_app]
    pooled = np.concatenate(samples)
    return float(np.round(np.percentile(pooled, 99)))


def placement_p99_ms(cluster: ClusterState, assignment=None) -> float:
    """p99-aware network score of the *standing placement*: the fleet mean
    of each live app's p99 experienced latency (ms).

    ``network_p99_ms`` scores the moves of one decision; trajectories need
    the state analogue.  Each app's latency distribution under the current
    assignment uses the same geometric spill model (its tier's closest
    region with P = 1 - q, the next with P = q(1 - q), ...); the app's p99
    is the exact discrete quantile of that distribution — typically the
    latency of its tier's second- or third-closest region, which is
    precisely the tail a placement behind a degraded link fattens.  The
    fleet mean of per-app p99s moves with *every* placement decision
    (a pooled fleet percentile is pinned by apps that never move), and is
    computed exactly — no sampling, so the scorecard is deterministic.
    """
    p = cluster.problem
    x = np.asarray(p.assignment0 if assignment is None else assignment)
    valid = np.asarray(p.valid, bool)
    if not valid.any():
        return 0.0
    spill = 0.15
    lat = cluster.region_latency
    total = 0.0
    n_live = int(valid.sum())
    for t in range(p.num_tiers):
        apps = np.where(valid & (x == t))[0]
        if apps.size == 0:
            continue
        regions = np.where(cluster.tier_regions[t])[0]
        if regions.size == 0:
            return float(np.inf)
        opts = np.sort(lat[cluster.app_region[apps]][:, regions], axis=1)
        probs = spill ** np.arange(regions.size) * (1.0 - spill)
        probs[-1] += 1.0 - probs.sum()
        # Exact discrete p99: same option index for every app in the tier
        # (it depends only on the tier's region count).
        idx = int(np.searchsorted(np.cumsum(probs), 0.99))
        total += float(opts[:, min(idx, regions.size - 1)].sum())
    return float(np.round(total / n_live, 3))


def app_move_latency_ms(cluster: ClusterState, app: int, dst_tier: int) -> float:
    """Best-case latency from the app's data-source region to the tier."""
    dst_regions = np.where(cluster.tier_regions[dst_tier])[0]
    return float(cluster.region_latency[cluster.app_region[app], dst_regions].min())
