"""Hard constraints (paper §3.2.1, items 1-4) — move masks and validators.

Constraints are "all equally important to be satisfiable to get a valid
solution".  The solvers enforce them *by construction* through the move mask;
``validate`` is the post-hoc oracle used by tests, the decision-execution
stage (§3.3: "decision evaluation can also result in finding bugs with the
solver"), and the hierarchy loop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.problem import Problem, tier_loads

# Absolute slack on the destination-headroom checks (constraints 1-2).  The
# single source of truth for every re-statement of the fit test: move_mask,
# the fused-best oracle (delta.move_best_per_app), the batched commit scan
# (solver_local), and the Pallas kernel's fraction-space form.
FEAS_TOL = 1e-6


def destination_fits(demand: jax.Array, tasks: jax.Array,
                     capacity: jax.Array, task_limit: jax.Array,
                     util: jax.Array, tier_tasks: jax.Array) -> jax.Array:
    """bool[N, T]: app n's demand fits tier t's remaining headroom
    (constraints 1 + 2, incremental form shared by all sweep paths)."""
    fits = jnp.all(util[None, :, :] + demand[:, None, :]
                   <= capacity[None, :, :] + FEAS_TOL, axis=-1)
    return fits & (tier_tasks[None, :] + tasks[:, None]
                   <= task_limit[None, :] + FEAS_TOL)


@dataclasses.dataclass(frozen=True)
class Violations:
    """Host-side constraint report."""

    capacity_exceeded: bool       # constraint 1
    task_limit_exceeded: bool     # constraint 2
    move_budget_exceeded: bool    # constraint 3
    slo_violated: bool            # constraint 4
    avoid_violated: bool          # hierarchy avoid pairs (modelled like 4)
    num_moved: int
    move_budget: int

    @property
    def ok(self) -> bool:
        return not (self.capacity_exceeded or self.task_limit_exceeded
                    or self.move_budget_exceeded or self.slo_violated
                    or self.avoid_violated)


def validate(problem: Problem, assignment: jax.Array,
             *, allow_preexisting: bool = True) -> Violations:
    """Check all hard constraints on a final assignment.

    ``allow_preexisting``: the initial (collected) state may already violate
    capacity — the paper's tier 3 starts hot.  A solution is only charged for
    violations it *introduces or keeps for apps it was free to move*; with the
    flag set we compare against the initial state's violations per tier.
    """
    util, tasks = tier_loads(problem, assignment)
    util0, tasks0 = tier_loads(problem, problem.assignment0)

    cap_over = util > problem.capacity + 1e-4
    task_over = tasks > problem.task_limit + 1e-4
    if allow_preexisting:
        cap_over = cap_over & ~(util0 > problem.capacity + 1e-4)
        task_over = task_over & ~(tasks0 > problem.task_limit + 1e-4)

    moved = assignment != problem.assignment0
    num_moved = int(jnp.sum(moved))
    budget = int(problem.move_budget)

    slo_ok = problem.slo_allowed[assignment, problem.slo]      # [N]
    avoid_hit = problem.avoid[jnp.arange(problem.num_apps), assignment]
    # Apps that never moved keep their (possibly grandfathered) placement.
    slo_bad = jnp.any(~slo_ok & moved)
    avoid_bad = jnp.any(avoid_hit & moved)

    return Violations(
        capacity_exceeded=bool(jnp.any(cap_over)),
        task_limit_exceeded=bool(jnp.any(task_over)),
        move_budget_exceeded=num_moved > budget,
        slo_violated=bool(slo_bad),
        avoid_violated=bool(avoid_bad),
        num_moved=num_moved,
        move_budget=budget,
    )


def move_mask(problem: Problem, assignment: jax.Array,
              util: jax.Array, tasks: jax.Array,
              moves_left: jax.Array) -> jax.Array:
    """bool[N, T]: is moving app n to tier t feasible *right now*?

    Encodes constraints 1-4 incrementally:
      1/2: destination tier load + app demand must stay within capacity/limit
      3:   if the app has not moved yet, the move budget must not be exhausted
           (moving an already-moved app again, or back home, is budget-neutral
           or budget-freeing)
      4:   SLO table + avoid matrix membership.
    """
    N, T = problem.num_apps, problem.num_tiers
    feas = problem.feasible_mask()                              # SLO + avoid

    # Capacity feasibility at destination: util[t] + d[n] <= C[t] (both resources).
    fits = destination_fits(problem.demand, problem.tasks, problem.capacity,
                            problem.task_limit, util, tasks)

    # Movement budget: an app not yet moved consumes budget unless target ==
    # current tier; an app already moved can re-target freely (its budget is
    # already spent; moving home refunds).
    already_moved = assignment != problem.assignment0           # [N]
    have_budget = moves_left > 0
    budget_ok = already_moved[:, None] | have_budget            # [N, T]
    # Staying put is always "feasible" but never an improvement; exclude it so
    # argmax never proposes a no-op.
    not_self = jnp.arange(T)[None, :] != assignment[:, None]

    return feas & fits & budget_ok & not_self


def moves_remaining(problem: Problem, assignment: jax.Array) -> jax.Array:
    moved = jnp.sum((assignment != problem.assignment0).astype(jnp.int32))
    return problem.move_budget - moved
