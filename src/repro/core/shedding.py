"""Utility-optimal overload shedding with hysteretic re-admission.

The fault-side control plane (PR 6) answers "what if the *controller* is
degraded"; this module answers the paper's other failure mode — demand
outgrowing the infrastructure.  When the fleet's offered load exceeds what
the tiers can serve, *somebody* is not getting their demanded capacity; the
binary SLO table just records who lost, while the utility curves
(``core.utility``) let the controller choose: shed the cheapest utility
first.

Mechanics:

  * A **delivery cap** in (0, 1] per app: the actuated throttle.  Capped
    apps keep running (and keep their placement) at ``cap x demand`` —
    shedding costs no *movement*, but every cap transition is a
    reconfiguration the fleet must execute, priced like a move
    (``core.planner.move_costs``) and charged against the same movement-
    cost budget the solver's moves draw from.
  * The **shed set** is chosen greedily by marginal utility density: the
    utility lost by capping an app to ``min_delivered`` divided by the
    capacity it frees.  Low-density (best-effort, light-curve) apps go
    first; apps above ``protect_critical`` criticality are never shed.
  * **Hysteretic re-admission**: caps only lift after the fleet has held
    ``readmit_margin`` headroom for ``readmit_ticks`` consecutive ticks,
    highest utility density first, and only while lifting keeps the
    margin — the asymmetry that prevents admit/shed flapping.
  * Every transition is published as a ``core.planner.Advisory`` with the
    ``SHED`` kind, so shed decisions ride the same declared-event channel
    maintenance does (audited by the controller, visible to scorecards).

The plan is applied inside the cooperation bus: ``CoopConfig.shed`` hands
it to ``Sptlb.balance``, which scales the problem's demand before the
solver sees it — the solver then balances (and the decision is judged on)
what the fleet will actually serve.  ``None``/inactive plans leave every
code path bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.planner import SHED, Advisory
from repro.core.problem import Problem
from repro.core.utility import utility_of


@dataclasses.dataclass(frozen=True)
class ShedConfig:
    # Serve at most this fraction of fleet capacity (per resource); offered
    # load beyond it is shed.  1.0 = shed only true over-capacity excess.
    target_frac: float = 1.0
    # Delivery cap applied to shed apps: degraded service, not a kill.
    min_delivered: float = 0.25
    # Re-admission headroom: caps lift only while the fleet stays below
    # ``target_frac * (1 - readmit_margin)`` of capacity...
    readmit_margin: float = 0.08
    # ...for this many consecutive ticks (the hysteresis).
    readmit_ticks: int = 3
    # Apps at or above this criticality are never shed.
    protect_critical: float = 0.9


@dataclasses.dataclass(frozen=True)
class ShedPlan:
    """One tick's shedding decision (immutable; the shedder holds state)."""

    caps: np.ndarray  # f32[N] delivery caps in (0, 1]
    shed_ids: tuple = ()  # newly capped this tick
    readmitted_ids: tuple = ()  # caps lifted this tick
    churn_cost: float = 0.0  # priced cost of this tick's transitions
    overload_frac: float = 0.0  # offered / (target_frac * capacity), max over R
    advisories: tuple = ()  # SHED-kind records for the channel

    @property
    def active(self) -> bool:
        return bool(np.any(self.caps < 1.0))

    @property
    def churned(self) -> int:
        return len(self.shed_ids) + len(self.readmitted_ids)

    def apply(self, problem: Problem) -> Problem:
        """The served problem: offered demand scaled by the delivery caps."""
        if not self.active:
            return problem
        caps = jnp.asarray(self.caps, problem.demand.dtype)
        return dataclasses.replace(problem, demand=problem.demand * caps[:, None])


class LoadShedder:
    """Stateful shed/readmit policy over a fixed app pool.

    ``plan(problem, ...)`` consumes the *offered* problem (uncapped demand,
    utility curves attached) and returns the tick's ``ShedPlan``; callers
    actuate it via ``ShedPlan.apply`` / ``CoopConfig.shed``.  Rows whose
    ``valid`` goes False reset to cap 1.0 (pool rows are recycled by
    churn).  ``set_cap`` is the admission controller's entry point for
    admit-degraded arrivals — those caps join the managed set and lift
    through the same hysteresis.
    """

    def __init__(self, config: ShedConfig = ShedConfig()):
        self.config = config
        self.caps: Optional[np.ndarray] = None
        self.shed_events = 0  # lifetime cap-lowering transitions
        self.readmit_events = 0  # lifetime cap-lifting transitions
        self._margin_streak = 0

    def _ensure(self, n: int) -> np.ndarray:
        if self.caps is None or self.caps.shape[0] != n:
            self.caps = np.ones(n, np.float32)
        return self.caps

    def set_cap(self, app_id: int, frac: float) -> None:
        """Admission-degraded entry: serve ``app_id`` at ``frac`` of demand."""
        if self.caps is None:
            raise RuntimeError("set_cap before first plan(); pool size unknown")
        self.caps[int(app_id)] = np.float32(min(1.0, max(0.0, frac)))

    # -- one tick -------------------------------------------------------------
    def plan(
        self, problem: Problem, *, move_cost=None, budget: float = float("inf"), now: int = 0
    ) -> ShedPlan:
        cfg = self.config
        n = problem.num_apps
        caps = self._ensure(n)
        valid = np.asarray(problem.valid, bool)
        caps[~valid] = 1.0  # recycled pool rows
        if not problem.has_utility:
            # No curves, no utility order — shedding would be arbitrary,
            # which is exactly what this subsystem exists to avoid.
            return ShedPlan(caps=caps.copy())

        demand = np.asarray(problem.demand, np.float64) * valid[:, None]
        target = cfg.target_frac * np.asarray(problem.capacity, np.float64).sum(axis=0)
        target = np.maximum(target, 1e-9)
        offered = demand.sum(axis=0)
        served = (demand * caps[:, None].astype(np.float64)).sum(axis=0)
        overload = float(np.max(offered / target))

        knee = np.asarray(problem.util_knee, np.float64)
        slope = np.asarray(problem.util_slope, np.float64)
        weight = np.asarray(problem.util_weight, np.float64)
        crit = np.asarray(problem.criticality, np.float64)
        cost = np.asarray(move_cost, np.float64) if move_cost is not None else np.ones(n)
        load = demand.sum(axis=1)
        # Utility lost by capping to min_delivered, per unit of load freed.
        curve = (jnp.asarray(knee), jnp.asarray(slope), jnp.asarray(weight))
        u_full = np.asarray(utility_of(jnp.asarray(1.0), *curve))
        u_shed = np.asarray(utility_of(jnp.asarray(cfg.min_delivered), *curve))
        freed = (1.0 - cfg.min_delivered) * np.maximum(load, 1e-9)
        density = (u_full - u_shed) / freed

        shed_ids: list[int] = []
        readmit_ids: list[int] = []
        churn = 0.0
        margin_target = target * (1.0 - cfg.readmit_margin)

        if np.any(served > target):
            self._margin_streak = 0
            order = np.argsort(density, kind="stable")
            for i in order:
                if not np.any(served > target):
                    break
                i = int(i)
                if not valid[i] or caps[i] < 1.0 or crit[i] >= cfg.protect_critical:
                    continue
                if churn + cost[i] > budget + 1e-9:
                    continue  # budget binds this tick
                caps[i] = np.float32(cfg.min_delivered)
                served = served - (1.0 - cfg.min_delivered) * demand[i]
                churn += float(cost[i])
                shed_ids.append(i)
            self.shed_events += len(shed_ids)
        else:
            if np.all(served <= margin_target):
                self._margin_streak += 1
            else:
                self._margin_streak = 0
            if self._margin_streak >= cfg.readmit_ticks:
                capped = [int(i) for i in np.where(valid & (caps < 1.0))[0]]
                # Highest utility density comes back first.
                capped.sort(key=lambda i: -density[i])
                for i in capped:
                    restore = (1.0 - float(caps[i])) * demand[i]
                    if np.any(served + restore > margin_target):
                        continue
                    if churn + cost[i] > budget + 1e-9:
                        continue
                    caps[i] = np.float32(1.0)
                    served = served + restore
                    churn += float(cost[i])
                    readmit_ids.append(i)
                self.readmit_events += len(readmit_ids)

        # ``region`` carries the app id — the channel's spare axis; SHED
        # advisories are app-, not tier-, scoped.
        advisories = tuple(
            Advisory(at=now, kind=SHED, region=i, scale=float(caps[i]))
            for i in shed_ids + readmit_ids
        )
        return ShedPlan(
            caps=caps.copy(),
            shed_ids=tuple(shed_ids),
            readmitted_ids=tuple(readmit_ids),
            churn_cost=round(churn, 6),
            overload_frac=round(overload, 6),
            advisories=advisories,
        )
