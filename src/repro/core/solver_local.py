"""LocalSearch engine (paper §3.2.1): greedy exploration of the move space.

"LocalSearch: Greedy exploration of search space to find a solution, can get
stuck in local minimums."

Each iteration scores *every* feasible single-app move with the exact
closed-form objective delta (core/delta.py — optionally the Pallas
move_eval kernel) and applies the best one; the loop runs under
``jax.lax.while_loop`` until no improving feasible move exists or the
iteration budget (the wall-clock "timeout" knob made deterministic) runs out.

An optional temperature turns best-improvement into Gumbel-softmax sampling
over improving moves — a restart-free way out of shallow local minima (kept 0
by default to stay faithful to the paper's description).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import constraints as C
from repro.core import goals
from repro.core.delta import move_delta_cost
from repro.core.problem import Problem, tier_loads


@dataclasses.dataclass(frozen=True)
class LocalSearchConfig:
    max_iters: int = 512          # deterministic stand-in for the timeout knob
    tol: float = 1e-7             # minimum improvement to keep moving
    temperature: float = 0.0      # 0 = pure best-improvement
    seed: int = 0


@dataclasses.dataclass
class SolveResult:
    assignment: jax.Array
    iterations: int
    converged: bool
    objective: float
    num_moved: int
    solve_time_s: float
    extra: dict = dataclasses.field(default_factory=dict)


def _weights_vector(problem: Problem) -> jax.Array:
    w = problem.weights
    return jnp.stack([w.under_ideal, w.resource_balance, w.task_balance,
                      w.movement_cost, w.criticality])


@partial(jax.jit, static_argnames=("max_iters", "temperature", "tol", "move_eval_fn"))
def _solve_local_jit(problem: Problem, key: jax.Array, x_init: jax.Array,
                     *, max_iters: int, temperature: float, tol: float,
                     move_eval_fn: Optional[Callable] = None):
    eval_fn = move_eval_fn or move_delta_cost
    wvec = _weights_vector(problem)
    util0, tasks0 = tier_loads(problem, x_init)

    def body(state):
        x, util, tasks, it, _, key = state
        moves_left = C.moves_remaining(problem, x)
        delta = eval_fn(problem.demand, problem.tasks, problem.criticality,
                        x, problem.assignment0,
                        problem.capacity, problem.task_limit,
                        problem.ideal_frac, problem.ideal_task_frac,
                        util, tasks, wvec)
        mask = C.move_mask(problem, x, util, tasks, moves_left)
        scores = jnp.where(mask, delta, jnp.inf)

        if temperature > 0.0:
            key, sub = jax.random.split(key)
            improving = scores < -tol
            logits = jnp.where(improving, -scores / temperature, -jnp.inf)
            flat = jax.random.categorical(sub, logits.reshape(-1))
            # If nothing improves, categorical over all -inf is undefined;
            # fall back to argmin (which will trigger convergence below).
            any_improving = jnp.any(improving)
            flat = jnp.where(any_improving, flat, jnp.argmin(scores))
        else:
            flat = jnp.argmin(scores)

        n = flat // problem.num_tiers
        t = flat % problem.num_tiers
        best = scores[n, t]
        improving = best < -tol

        src = x[n]
        x_new = x.at[n].set(jnp.where(improving, t, src).astype(x.dtype))
        util_new = jnp.where(
            improving,
            util.at[src].add(-problem.demand[n]).at[t].add(problem.demand[n]),
            util)
        tasks_new = jnp.where(
            improving,
            tasks.at[src].add(-problem.tasks[n]).at[t].add(problem.tasks[n]),
            tasks)
        return x_new, util_new, tasks_new, it + 1, ~improving, key

    def cond(state):
        _, _, _, it, done, _ = state
        return (~done) & (it < max_iters)

    init = (x_init, util0, tasks0, jnp.int32(0), jnp.bool_(False), key)
    x, util, tasks, it, done, _ = jax.lax.while_loop(cond, body, init)
    obj = goals.objective(problem, x)
    return x, it, done, obj


def solve_local(problem: Problem, config: LocalSearchConfig = LocalSearchConfig(),
                *, move_eval_fn: Optional[Callable] = None,
                init_assignment: Optional[jax.Array] = None) -> SolveResult:
    """Run LocalSearch; returns assignment + host-side stats.

    ``init_assignment`` warm-starts the search (movement budget is still
    accounted against ``problem.assignment0``) — used by OptimalSearch's
    refinement pass and by incremental re-balancing after failures.
    """
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(config.seed)
    x0 = problem.assignment0 if init_assignment is None else init_assignment
    x, it, done, obj = _solve_local_jit(
        problem, key, x0, max_iters=config.max_iters,
        temperature=config.temperature, tol=config.tol,
        move_eval_fn=move_eval_fn)
    x = jax.block_until_ready(x)
    dt = time.perf_counter() - t0
    return SolveResult(
        assignment=x,
        iterations=int(it),
        converged=bool(done),
        objective=float(obj),
        num_moved=int(jnp.sum(x != problem.assignment0)),
        solve_time_s=dt,
    )
