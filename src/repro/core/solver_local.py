"""LocalSearch engine (paper §3.2.1): greedy exploration of the move space.

"LocalSearch: Greedy exploration of search space to find a solution, can get
stuck in local minimums."

Each sweep scores *every* feasible single-app move with the exact closed-form
objective delta (core/delta.py — optionally the Pallas move_eval kernel).

Batched top-k move application: scoring the O(N*T) candidate sweep is the
expensive part, so committing only ONE move per sweep wastes almost all of
it.  Instead we reduce the sweep to a per-app best (score, tier), take the
``batch_moves`` best apps with ``lax.top_k``, and commit a conflict-free
subset in a ``lax.scan`` over the candidates in ascending-score order:

  * candidates are distinct apps by construction (one best tier per app),
  * each candidate is re-checked *incrementally* against the state left by
    the moves already accepted this sweep — destination capacity/task-limit
    headroom, the movement budget, and an exact O(T*R) delta re-evaluation
    (delta.single_move_delta) that must still be strictly improving,
  * the first candidate is exactly the single-move path's argmin and is
    accepted under exactly the old rule, so ``batch_moves=1`` reproduces the
    single-move trajectory bit-for-bit and convergence detection (no
    improving feasible move) is unchanged.

The loop runs under ``jax.lax.while_loop`` until no improving feasible move
exists or the sweep budget (the wall-clock "timeout" knob made deterministic)
runs out — but now commits up to k moves per sweep instead of 1.

An optional temperature turns best-improvement into Gumbel-softmax sampling
over improving moves — a restart-free way out of shallow local minima (kept 0
by default to stay faithful to the paper's description).  The temperature
path commits a single sampled move per sweep regardless of ``batch_moves``.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import constraints as C
from repro.core import goals
from repro.core.delta import move_delta_cost, single_move_delta
from repro.core.problem import Problem, tier_loads

# Retrace counter: incremented at *trace* time only, so (after - before) == 0
# across a solve means the jit cache was hit (no recompilation).  Surfaced in
# SolveResult.extra and used by the shape-bucketing benchmarks.
_TRACE_COUNTS = {"local_search": 0}


def local_search_trace_count() -> int:
    """Number of times the jitted LocalSearch body has been (re)traced."""
    return _TRACE_COUNTS["local_search"]


@dataclasses.dataclass(frozen=True)
class LocalSearchConfig:
    max_iters: int = 512          # candidate-sweep budget (the timeout knob)
    tol: float = 1e-7             # minimum improvement to keep moving
    temperature: float = 0.0      # 0 = pure best-improvement
    seed: int = 0
    batch_moves: int = 16         # top-k moves committed per sweep (1 = legacy)
    # A rank-i>0 candidate is only committed if its exact re-evaluated delta
    # is at least ``batch_quality`` of the sweep-best delta.  This guards the
    # scarce movement budget: batch-committing merely-improving moves spends
    # budget the single-move path would have used on better moves later.
    # 0.0 = accept any improving candidate, 1.0 = only ties with the best.
    # 0.9 measured: converged-solution parity with single-move at N=300 and
    # a 6.5x committed-move rate at N=10_000 (0.5 trades ~15% quality for
    # 11x) — see benchmarks/solver_scale.py / BENCH_solver.json.
    batch_quality: float = 0.9


@dataclasses.dataclass
class SolveResult:
    assignment: jax.Array
    iterations: int
    converged: bool
    objective: float
    num_moved: int
    solve_time_s: float
    extra: dict = dataclasses.field(default_factory=dict)


def _weights_vector(problem: Problem) -> jax.Array:
    w = problem.weights
    return jnp.stack([w.under_ideal, w.resource_balance, w.task_balance,
                      w.movement_cost, w.criticality])


@partial(jax.jit, static_argnames=("max_iters", "temperature", "tol",
                                   "move_eval_fn", "move_best_fn",
                                   "batch_moves", "batch_quality"))
def _solve_local_jit(problem: Problem, key: jax.Array, x_init: jax.Array,
                     *, max_iters: int, temperature: float, tol: float,
                     move_eval_fn: Optional[Callable] = None,
                     move_best_fn: Optional[Callable] = None,
                     batch_moves: int = 1, batch_quality: float = 0.5):
    _TRACE_COUNTS["local_search"] += 1          # trace-time side effect only
    eval_fn = move_eval_fn or move_delta_cost
    wvec = _weights_vector(problem)
    util0, tasks0 = tier_loads(problem, x_init)
    N, T = problem.num_apps, problem.num_tiers
    k = max(1, min(int(batch_moves), N))
    feas = problem.feasible_mask()
    total_tasks = jnp.maximum(jnp.sum(problem.tasks), 1.0)
    total_crit = jnp.maximum(jnp.sum(problem.criticality), 1.0)

    def sweep_args(x, util, tasks):
        return (problem.demand, problem.tasks, problem.criticality,
                x, problem.assignment0,
                problem.capacity, problem.task_limit,
                problem.ideal_frac, problem.ideal_task_frac,
                util, tasks, wvec)

    def body_sampled(state):
        # Temperature > 0: legacy single-move Gumbel-softmax sampling.
        x, util, tasks, it, _, committed, key = state
        moves_left = C.moves_remaining(problem, x)
        delta = eval_fn(*sweep_args(x, util, tasks))
        mask = C.move_mask(problem, x, util, tasks, moves_left)
        scores = jnp.where(mask, delta, jnp.inf)

        key, sub = jax.random.split(key)
        improving_mask = scores < -tol
        logits = jnp.where(improving_mask, -scores / temperature, -jnp.inf)
        flat = jax.random.categorical(sub, logits.reshape(-1))
        # If nothing improves, categorical over all -inf is undefined;
        # fall back to argmin (which will trigger convergence below).
        any_improving = jnp.any(improving_mask)
        flat = jnp.where(any_improving, flat, jnp.argmin(scores))

        n = flat // T
        t = flat % T
        best = scores[n, t]
        improving = best < -tol

        src = x[n]
        x_new = x.at[n].set(jnp.where(improving, t, src).astype(x.dtype))
        util_new = jnp.where(
            improving,
            util.at[src].add(-problem.demand[n]).at[t].add(problem.demand[n]),
            util)
        tasks_new = jnp.where(
            improving,
            tasks.at[src].add(-problem.tasks[n]).at[t].add(problem.tasks[n]),
            tasks)
        committed = committed + improving.astype(jnp.int32)
        return x_new, util_new, tasks_new, it + 1, ~improving, committed, key

    def body_topk(state):
        x, util, tasks, it, _, committed, key = state
        moves_left = C.moves_remaining(problem, x)
        if move_best_fn is not None:
            best_s, best_t = move_best_fn(*sweep_args(x, util, tasks),
                                          feas, moves_left)
        else:
            delta = eval_fn(*sweep_args(x, util, tasks))
            mask = C.move_mask(problem, x, util, tasks, moves_left)
            scores = jnp.where(mask, delta, jnp.inf)
            best_t = jnp.argmin(scores, axis=1).astype(jnp.int32)
            best_s = jnp.min(scores, axis=1)

        # lax.top_k is stable on ties, so cand_n[0] is exactly the flat
        # row-major argmin the single-move path would pick.
        top_neg, cand_n = jax.lax.top_k(-best_s, k)
        cand_s = -top_neg                                   # ascending scores
        cand_t = best_t[cand_n]
        improving = cand_s[0] < -tol                        # convergence

        def commit(carry, inp):
            x, util, tasks, left, acc = carry
            idx, n, t, s = inp
            src = x[n]
            d_exact = single_move_delta(
                n, t, src, problem.demand, problem.tasks, problem.criticality,
                problem.assignment0, problem.capacity, problem.task_limit,
                problem.ideal_frac, problem.ideal_task_frac,
                util, tasks, wvec, total_tasks, total_crit)
            already = src != problem.assignment0[n]
            fits = (jnp.all(util[t] + problem.demand[n]
                            <= problem.capacity[t] + C.FEAS_TOL)
                    & (tasks[t] + problem.tasks[n]
                       <= problem.task_limit[t] + C.FEAS_TOL))
            budget_ok = already | (left > 0)
            # Candidate 0 saw exactly this state during the sweep: trust the
            # sweep score (bit-parity with the single-move path).  Later
            # candidates must still improve against the *updated* state AND
            # be within the quality window of the sweep-best move — budget
            # spent on merely-improving moves is budget the single-move path
            # would have spent on better moves later.  Budget-neutral moves
            # (already-moved apps re-targeting) skip the window.
            window_ok = d_exact <= batch_quality * cand_s[0]
            good_enough = (d_exact < -tol) & (window_ok | already)
            still_improving = jnp.where(idx == 0, s < -tol, good_enough)
            accept = ((s < -tol) & still_improving & fits & budget_ok
                      & (t != src))
            x = x.at[n].set(jnp.where(accept, t, src).astype(x.dtype))
            util = jnp.where(
                accept,
                util.at[src].add(-problem.demand[n])
                    .at[t].add(problem.demand[n]),
                util)
            tasks = jnp.where(
                accept,
                tasks.at[src].add(-problem.tasks[n])
                     .at[t].add(problem.tasks[n]),
                tasks)
            going_home = t == problem.assignment0[n]
            spend = jnp.where(already, jnp.where(going_home, -1, 0), 1)
            left = left - jnp.where(accept, spend, 0)
            acc = acc + accept.astype(jnp.int32)
            return (x, util, tasks, left, acc), None

        (x_new, util_new, tasks_new, _, acc), _ = jax.lax.scan(
            commit, (x, util, tasks, moves_left, jnp.int32(0)),
            (jnp.arange(k), cand_n, cand_t, cand_s))
        return (x_new, util_new, tasks_new, it + 1, ~improving,
                committed + acc, key)

    body = body_sampled if temperature > 0.0 else body_topk

    def cond(state):
        _, _, _, it, done, _, _ = state
        return (~done) & (it < max_iters)

    init = (x_init, util0, tasks0, jnp.int32(0), jnp.bool_(False),
            jnp.int32(0), key)
    x, util, tasks, it, done, committed, _ = jax.lax.while_loop(
        cond, body, init)
    obj = goals.objective(problem, x)
    return x, it, done, committed, obj


def solve_local(problem: Problem, config: LocalSearchConfig = LocalSearchConfig(),
                *, move_eval_fn: Optional[Callable] = None,
                move_best_fn: Optional[Callable] = None,
                init_assignment: Optional[jax.Array] = None) -> SolveResult:
    """Run LocalSearch; returns assignment + host-side stats.

    ``init_assignment`` warm-starts the search (movement budget is still
    accounted against ``problem.assignment0``) — used by OptimalSearch's
    refinement pass and by incremental re-balancing after failures.

    ``move_best_fn`` optionally replaces the sweep + per-app-argmin reduction
    with a fused implementation (kernels.ops.move_eval_best); it receives the
    move_eval argument tuple plus (feasible_mask, moves_left) and must return
    (best_score[N], best_tier[N]) with +inf for infeasible apps.

    ``SolveResult.extra`` reports: sweeps, committed_moves, batch_moves,
    retraced (False == jit cache hit), trace_count, and solve_s.
    """
    t0 = time.perf_counter()
    traces_before = local_search_trace_count()
    key = jax.random.PRNGKey(config.seed)
    x0 = problem.assignment0 if init_assignment is None else init_assignment
    x, it, done, committed, obj = _solve_local_jit(
        problem, key, x0, max_iters=config.max_iters,
        temperature=config.temperature, tol=config.tol,
        move_eval_fn=move_eval_fn, move_best_fn=move_best_fn,
        batch_moves=config.batch_moves, batch_quality=config.batch_quality)
    x = jax.block_until_ready(x)
    dt = time.perf_counter() - t0
    return SolveResult(
        assignment=x,
        iterations=int(it),
        converged=bool(done),
        objective=float(obj),
        num_moved=int(jnp.sum((x != problem.assignment0) & problem.valid)),
        solve_time_s=dt,
        extra={
            "sweeps": int(it),
            "committed_moves": int(committed),
            "batch_moves": config.batch_moves,
            "retraced": local_search_trace_count() > traces_before,
            "trace_count": local_search_trace_count(),
            "solve_s": dt,
        },
    )
