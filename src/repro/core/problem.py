"""Load-balance problem model (paper §3.2): apps, tiers, resources as JAX arrays.

The problem mirrors Rebalancer's "compliant data structures" (paper §3.2):
  * entities  = streaming applications (N of them)
  * containers = tiers (T of them)
  * dimensions = cpu, mem (continuous) and task count (integral)
plus the app properties the paper balances/avoids over: SLO score, criticality
score, and the dynamic ``avoid`` matrix that the hierarchy-cooperation loop
(§3.4) feeds back into the solver.

Everything is a flat JAX array so the solvers (solver_local / solver_optimal)
and the Pallas move_eval kernel can operate on device without host round trips.

Shape-bucketed compilation caching: ``Sptlb.balance`` is called on every
telemetry tick and the live app count N drifts tick to tick, which would
retrace/recompile every jitted solver for every new N.  ``pad_problem`` pads
the app axis up to a power-of-two bucket (``bucket_size``) with *inert* rows:
``valid[n] = False`` rows have zero demand/tasks/criticality and their
``feasible_mask`` collapses to the home tier only, so they can never move,
never contribute to any goal term, and never consume movement budget
(``move_budget`` counts valid apps only).  Solving the padded problem is
therefore bitwise-equivalent to solving the original, while every N in a
bucket reuses one compiled executable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Resource axes of the continuous dimensions (paper: cpu, mem).
RESOURCES = ("cpu", "mem")
NUM_RESOURCES = len(RESOURCES)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GoalWeights:
    """Priority-ordered goal weights (paper §3.2.1 goals 5-9).

    The paper orders goals by "default priority"; Rebalancer treats them
    lexicographically below the hard constraints. We scalarize with
    decade-separated weights; permuting priorities is the paper's "tuning
    knob" (explored + found non-significant, §3.2.1 last paragraph).
    """

    # Goal 5: tiers prefer to stay under their ideal utilization limit.
    under_ideal: jax.Array
    # Goal 6: resource usage (cpu, mem) balanced across tiers.
    resource_balance: jax.Array
    # Goal 7: task count balanced across tiers.
    task_balance: jax.Array
    # Goal 8: low downtime — movement cost proportional to task count.
    movement_cost: jax.Array
    # Goal 9: high-criticality apps not moved.
    criticality: jax.Array

    @staticmethod
    def default() -> "GoalWeights":
        # Decade separation emulates lexicographic goal priorities.
        return GoalWeights(
            under_ideal=jnp.float32(1e4),
            resource_balance=jnp.float32(1e3),
            task_balance=jnp.float32(1e2),
            movement_cost=jnp.float32(1e1),
            criticality=jnp.float32(1e0),
        )

    @staticmethod
    def from_priority(order: tuple[str, ...]) -> "GoalWeights":
        """Build weights from a priority permutation (highest first)."""
        names = ("under_ideal", "resource_balance", "task_balance",
                 "movement_cost", "criticality")
        assert sorted(order) == sorted(names), f"bad priority order {order}"
        vals = {name: jnp.float32(10.0 ** (len(order) - i)) for i, name in enumerate(order)}
        return GoalWeights(**vals)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Problem:
    """One SPTLB load-balancing instance.

    Shapes: N apps, T tiers, S SLO classes, R = NUM_RESOURCES.
    """

    # --- apps (entities) ---
    demand: jax.Array        # f32[N, R]  p99 resource demand (cpu cores, mem GB)
    tasks: jax.Array         # f32[N]     task count of the app (integral-valued)
    slo: jax.Array           # i32[N]     SLO class id
    criticality: jax.Array   # f32[N]     criticality score in [0, 1]
    assignment0: jax.Array   # i32[N]     current app -> tier assignment
    valid: jax.Array         # bool[N]    False for shape-bucket padding rows

    # --- tiers (containers) ---
    capacity: jax.Array      # f32[T, R]  hard headroom capacity (constraint 1)
    task_limit: jax.Array    # f32[T]     hard task-count limit (constraint 2)
    ideal_frac: jax.Array    # f32[T, R]  ideal utilization fraction (default 0.70)
    ideal_task_frac: jax.Array  # f32[T]  ideal task fraction (default 0.80)

    # --- cross ---
    slo_allowed: jax.Array   # bool[T, S] tier supports SLO class (constraint 4)
    avoid: jax.Array         # bool[N, T] dynamic avoid matrix (hierarchy feedback)

    # --- knobs ---
    move_frac: jax.Array     # f32[]      movement allowance as fraction of N (constraint 3)
    weights: GoalWeights

    # --- utility curves (Henge-style, arXiv 1802.00082; all-or-none) ---
    # Per-app monotone utility over *delivered* capacity fraction d in [0, 1]:
    #   u(d) = util_weight * clip(1 - util_slope * max(0, util_knee - d), 0, 1)
    # — flat at u_max above the knee (the SLO point), criticality-scaled
    # linear loss below it; util_slope = +inf recovers the binary SLO table
    # as an exact step curve.  ``None`` (the default) disables the fleet-
    # utility goal term entirely: every objective number is bit-identical to
    # a problem without curves.
    util_knee: Optional[jax.Array] = None    # f32[N] delivered frac at the SLO point
    util_slope: Optional[jax.Array] = None   # f32[N] loss rate below the knee
    util_weight: Optional[jax.Array] = None  # f32[N] u_max per app

    @property
    def has_utility(self) -> bool:
        """Static (trace-time) flag: utility curves attached to this problem."""
        return self.util_knee is not None

    @property
    def num_apps(self) -> int:
        return self.demand.shape[0]

    @property
    def num_tiers(self) -> int:
        return self.capacity.shape[0]

    @property
    def num_resources(self) -> int:
        return self.capacity.shape[1]

    @property
    def num_valid(self) -> jax.Array:
        """Count of real (non-padding) apps — N for unpadded problems."""
        return jnp.sum(self.valid.astype(jnp.int32))

    @property
    def move_budget(self) -> jax.Array:
        """Constraint 3: at most ceil(move_frac * N) apps may move.

        Counts *valid* apps only so bucket padding never inflates the budget.
        """
        return jnp.ceil(self.move_frac * self.num_valid).astype(jnp.int32)

    def feasible_mask(self) -> jax.Array:
        """bool[N, T]: app n may be placed in tier t (SLO + avoid only;

        capacity/task feasibility is assignment-dependent and handled by the
        solvers' move masking).  Padding rows (``valid == False``) collapse to
        home-tier-only so they can never move and OptimalSearch's softmax over
        the masked logits stays finite on every row."""
        slo_ok = self.slo_allowed[:, self.slo].T  # [N, T]
        feas = slo_ok & ~self.avoid
        home = jnp.arange(self.num_tiers)[None, :] == self.assignment0[:, None]
        return jnp.where(self.valid[:, None], feas, home)

    def with_avoid(self, extra_avoid: jax.Array) -> "Problem":
        """Return a copy with additional (app, tier) avoid pairs OR-ed in.

        This is the §3.4 feedback channel: rejections from lower-level
        schedulers become avoid constraints "similar to Constraint 3".
        It also carries the region pre-mask (``hierarchy.cooperate`` with
        ``CoopConfig(premask=True)`` folds the whole [N, T] region-feasibility
        matrix in before the first solve, keeping the home column open) —
        the solver then never proposes a region-infeasible move.
        """
        return dataclasses.replace(self, avoid=self.avoid | extra_avoid)

    def with_assignment0(self, assignment: jax.Array) -> "Problem":
        return dataclasses.replace(self, assignment0=assignment)


def tier_loads(problem: Problem, assignment: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Aggregate per-tier loads for an assignment.

    Returns (util f32[T, R], tasks f32[T]).  segment_sum keeps this O(N).
    The validity mask zeroes bucket-padding rows (their demand is already
    zero by construction; masking keeps the invariant even for hand-built
    padded problems).
    """
    T = problem.num_tiers
    w = problem.valid.astype(problem.demand.dtype)
    util = jax.ops.segment_sum(problem.demand * w[:, None], assignment,
                               num_segments=T)
    tasks = jax.ops.segment_sum(problem.tasks * w, assignment, num_segments=T)
    return util, tasks


def utilization_fraction(problem: Problem, assignment: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tier utilization as fraction of capacity — the quantity plotted in
    the paper's Fig. 3 ("percentage relative to each tier's capacity limit")."""
    util, tasks = tier_loads(problem, assignment)
    return util / problem.capacity, tasks / problem.task_limit


def make_problem(
    demand: np.ndarray,
    tasks: np.ndarray,
    slo: np.ndarray,
    criticality: np.ndarray,
    assignment0: np.ndarray,
    capacity: np.ndarray,
    task_limit: np.ndarray,
    slo_allowed: np.ndarray,
    *,
    ideal_frac: float | np.ndarray = 0.70,
    ideal_task_frac: float | np.ndarray = 0.80,
    move_frac: float = 0.10,
    avoid: Optional[np.ndarray] = None,
    weights: Optional[GoalWeights] = None,
    util_knee: Optional[np.ndarray] = None,
    util_slope: Optional[np.ndarray] = None,
    util_weight: Optional[np.ndarray] = None,
) -> Problem:
    """Construct a Problem from host arrays with paper-default knobs.

    Defaults follow the paper: 70% ideal resource utilization, 80% ideal task
    count, 10% movement bound.
    """
    demand = jnp.asarray(demand, jnp.float32)
    N = demand.shape[0]
    capacity = jnp.asarray(capacity, jnp.float32)
    T = capacity.shape[0]
    if np.isscalar(ideal_frac):
        ideal_frac = jnp.full((T, NUM_RESOURCES), float(ideal_frac), jnp.float32)
    else:
        ideal_frac = jnp.asarray(ideal_frac, jnp.float32)
    if np.isscalar(ideal_task_frac):
        ideal_task_frac = jnp.full((T,), float(ideal_task_frac), jnp.float32)
    else:
        ideal_task_frac = jnp.asarray(ideal_task_frac, jnp.float32)
    if avoid is None:
        avoid = jnp.zeros((N, T), bool)
    else:
        avoid = jnp.asarray(avoid, bool)
    curves = (util_knee, util_slope, util_weight)
    if any(c is not None for c in curves):
        if any(c is None for c in curves):
            raise ValueError("utility curves need all of util_knee/util_slope/"
                             "util_weight (or none of them)")
        curves = tuple(jnp.asarray(c, jnp.float32) for c in curves)
    util_knee, util_slope, util_weight = curves
    return Problem(
        demand=demand,
        tasks=jnp.asarray(tasks, jnp.float32),
        slo=jnp.asarray(slo, jnp.int32),
        criticality=jnp.asarray(criticality, jnp.float32),
        assignment0=jnp.asarray(assignment0, jnp.int32),
        valid=jnp.ones((N,), bool),
        capacity=capacity,
        task_limit=jnp.asarray(task_limit, jnp.float32),
        ideal_frac=ideal_frac,
        ideal_task_frac=ideal_task_frac,
        slo_allowed=jnp.asarray(slo_allowed, bool),
        avoid=avoid,
        move_frac=jnp.float32(move_frac),
        weights=weights or GoalWeights.default(),
        util_knee=util_knee,
        util_slope=util_slope,
        util_weight=util_weight,
    )


# --- shape-bucketed compilation caching -----------------------------------

MIN_BUCKET = 256


def bucket_size(n: int, minimum: int = MIN_BUCKET) -> int:
    """Smallest power-of-two bucket >= n (and >= ``minimum``).

    Buckets bound the number of distinct compiled executables to
    O(log N_max) as the live app count drifts across telemetry ticks.
    """
    b = max(int(minimum), 1)
    while b < n:
        b *= 2
    return b


def pad_problem(problem: Problem, bucket: Optional[int] = None) -> Problem:
    """Pad the app axis to a static bucket with inert (valid=False) rows.

    Padding rows have zero demand/tasks/criticality, live at tier 0, and are
    pinned home by ``feasible_mask``; ``move_budget``/``tier_loads`` ignore
    them.  Solving the padded problem yields the same trajectory as the
    original restricted to the first N rows.
    """
    N = problem.num_apps
    b = bucket_size(N) if bucket is None else int(bucket)
    if b == N:
        return problem
    if b < N:
        raise ValueError(f"bucket {b} smaller than num_apps {N}")
    pad = b - N

    def padn(x, value=0):
        cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, cfg, constant_values=value)

    extra = {}
    if problem.has_utility:
        # Inert rows carry zero u_max, so they contribute to neither the
        # delivered- nor the achievable-utility sum; knee=1/slope=0 keeps the
        # padded curves well-formed.
        extra = dict(
            util_knee=padn(problem.util_knee, 1.0),
            util_slope=padn(problem.util_slope, 0.0),
            util_weight=padn(problem.util_weight, 0.0),
        )
    return dataclasses.replace(
        problem,
        demand=padn(problem.demand),
        tasks=padn(problem.tasks),
        slo=padn(problem.slo),
        criticality=padn(problem.criticality),
        assignment0=padn(problem.assignment0),
        valid=padn(problem.valid, False),
        avoid=padn(problem.avoid, False),
        **extra,
    )
