"""Data collection (paper §3.1) — metadata store + resource monitoring.

The paper's pipeline: app metadata store -> SLO/criticality scores + resource
monitoring endpoints -> live cpu/mem/task sampling -> *peak* (p99) utilization
used for balancing, plus tier limits/ideal conditions.

Meta's live tier data is proprietary, so this module provides:
  * ``ResourceMonitor`` — a synthetic per-app time-series endpoint whose p99
    is what the balancer consumes (mirrors "collecting peak resource
    utilization (99th percentile) ... to account for application scaling
    during execution"),
  * ``generate_cluster`` — a 5-tier workload calibrated to the paper's
    experiment setup (§4): the exact SLO->tier table, 70% ideal resource
    utilization, 80% ideal task count, heavy-tailed app demands, and an
    initial imbalance with tier 3 hot (Fig. 3's red bars).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.problem import NUM_RESOURCES, GoalWeights, Problem, make_problem

# Paper §4 experiment setup: "5 tiers, belonging to the following SLO
# mappings: SLO1: tier 1,2,3; SLO2: tier 1,2,3; SLO3: tier 1..5; SLO4: tier 4,5"
PAPER_SLO_TABLE = np.array(
    #        SLO1   SLO2   SLO3   SLO4
    [[True,  True,  True,  False],   # tier 1
     [True,  True,  True,  False],   # tier 2
     [True,  True,  True,  False],   # tier 3
     [False, False, True,  True],    # tier 4
     [False, False, True,  True]],   # tier 5
)

# Initial utilization fractions per tier, shaped after Fig. 3's red bars:
# tier 3 is hot (over the 70% ideal line), tiers 4-5 cold.
FIG3_INITIAL_UTIL = np.array([0.62, 0.55, 0.93, 0.38, 0.30])


@dataclasses.dataclass
class ClusterState:
    """Everything the SPTLB data-collection stage produces (Fig. 1, step 1)."""

    problem: Problem
    app_names: list[str]
    tier_names: list[str]
    # Hierarchy-relevant metadata (consumed by core/hierarchy.py):
    app_region: np.ndarray        # i32[N] data-source region per app
    tier_regions: np.ndarray      # bool[T, G] regions with hosts per tier
    region_latency: np.ndarray    # f32[G, G] inter-region latency (ms)
    hosts_per_tier: np.ndarray    # i32[T]
    host_capacity: np.ndarray     # f32[R] per-host capacity
    # Optional per-app data-shard co-location: f32[N, T] share of the app's
    # shard mass hosted in each tier's regions (consumed by the shard
    # locality scheduler level and the SLO scorecard).  None derives the
    # matrix from geometry via ``shard_affinity_of``.
    shard_affinity: np.ndarray | None = None
    # External tick at which this telemetry was collected (the health
    # monitor scores ``now - collected_at`` as staleness; producers that
    # never re-stamp it simply read as always-fresh at the default 0 when
    # ``now`` is also left at its default).
    collected_at: int = 0
    # Memoized hierarchy precomputes (region worst-latency matrix, overlap
    # avoid, ...) keyed by the deriving function — see core/hierarchy.py.
    # ``init=False`` so every ``dataclasses.replace`` (capacity events,
    # applied rebalances) starts from an empty cache: entries can only
    # outlive the exact field values they were derived from if a caller
    # mutates an array in place, which nothing in the tree does.
    _cache: dict = dataclasses.field(init=False, default_factory=dict,
                                     repr=False, compare=False)


class ResourceMonitor:
    """Synthetic per-app resource endpoint; the collector takes p99 samples."""

    def __init__(self, base_demand: np.ndarray, seed: int = 0):
        self.base = base_demand            # f32[N, R] mean demand
        self.rng = np.random.default_rng(seed)

    def sample_p99(self, num_samples: int = 200) -> np.ndarray:
        """p99 over a lognormal-burst time series — "peak resource
        utilization (99th percentile) ... to account for application
        scaling during execution" (§3.1)."""
        N, R = self.base.shape
        bursts = self.rng.lognormal(mean=0.0, sigma=0.35, size=(num_samples, N, R))
        series = self.base[None] * bursts
        return np.percentile(series, 99, axis=0).astype(np.float32)


# Shard-distribution decay: an app's shard mass concentrates on its data
# region and falls off exponentially with ring distance (per hop).
SHARD_DECAY_HOPS = 1.0


def shard_affinity_of(cluster: ClusterState) -> np.ndarray:
    """f32[N, T] data-shard affinity: the share of each app's shard mass
    co-located with each tier's regions.

    A stream job's state shards live near its data source, so the per-app
    shard distribution over regions decays exponentially with ring distance
    from ``app_region``; a tier's affinity is the shard mass its regions
    hold.  ``cluster.shard_affinity`` (when telemetry collected a real
    matrix) takes precedence; the derived matrix depends only on geometry
    and is memoized on ``ClusterState._cache`` (any ``dataclasses.replace``
    of the cluster rebuilds it — the standing invalidation contract).
    """
    if cluster.shard_affinity is not None:
        return np.asarray(cluster.shard_affinity, np.float32)
    cache = cluster._cache
    if "shard_affinity" not in cache:
        G = cluster.region_latency.shape[0]
        ring = np.abs(np.arange(G)[:, None] - np.arange(G)[None, :])
        ring = np.minimum(ring, G - ring)
        mass = np.exp(-ring / SHARD_DECAY_HOPS)             # [G, G]
        mass = mass / mass.sum(axis=1, keepdims=True)
        shard_frac = mass[cluster.app_region]               # [N, G]
        affinity = shard_frac @ cluster.tier_regions.astype(np.float32).T
        cache["shard_affinity"] = affinity.astype(np.float32)
    return cache["shard_affinity"]


def sample_app_population(
    rng: np.random.Generator,
    num_apps: int,
    *,
    num_slo_classes: int = PAPER_SLO_TABLE.shape[1],
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Draw (base_demand, tasks, slo, criticality) for ``num_apps`` apps.

    The paper-calibrated per-app distributions, factored out of
    ``generate_cluster`` so the fleet simulator's workload engine
    (``repro.sim.workload``) draws arrivals from exactly the same
    population.  Draw order on ``rng`` is part of the contract: it matches
    the historical ``generate_cluster`` sequence so seeded clusters stay
    bit-identical across the refactor.

    Demands are heavy-tailed (streaming workloads are skewed): cpu, mem and
    task count are drawn (near-)independently — a stream job can be
    compute-bound, state-bound (joins/windows hold memory), or fan-out-bound
    (many small tasks).  Independence is what makes the single-objective
    greedy baseline fail on the other two objectives (Fig. 3) instead of
    balancing them by accident.
    """
    mean_cpu = rng.lognormal(mean=1.2, sigma=0.9, size=num_apps)     # cores
    mean_mem = rng.lognormal(mean=1.8, sigma=0.9, size=num_apps)     # GB
    base = np.stack([mean_cpu, mean_mem], axis=1).astype(np.float32)
    tasks = np.maximum(1, rng.poisson(lam=rng.lognormal(1.6, 0.7, size=num_apps))
                       ).astype(np.float32)
    p = np.array([0.2, 0.2, 0.45, 0.15])
    if num_slo_classes != p.size:          # generic fallback (property tests)
        p = np.full(num_slo_classes, 1.0 / num_slo_classes)
    slo = rng.choice(num_slo_classes, size=num_apps, p=p).astype(np.int32)
    criticality = rng.beta(2.0, 5.0, size=num_apps).astype(np.float32)
    return base, tasks, slo, criticality


def generate_cluster(
    num_apps: int = 400,
    num_tiers: int = 5,
    num_regions: int = 6,
    *,
    seed: int = 0,
    move_frac: float = 0.10,
    weights: GoalWeights | None = None,
    initial_util: np.ndarray | None = None,
) -> ClusterState:
    """Generate a paper-calibrated cluster + workload."""
    rng = np.random.default_rng(seed)
    T = num_tiers
    S = PAPER_SLO_TABLE.shape[1]
    if T == 5:
        slo_allowed = PAPER_SLO_TABLE
    else:  # generic fallback for property tests with arbitrary tier counts
        slo_allowed = rng.random((T, S)) < 0.7
        slo_allowed[:, 2] = True  # keep one universal SLO class

    # --- apps: the shared paper-calibrated population (the sim's workload
    # engine draws arrivals from the same distributions) ---
    base, tasks, slo, criticality = sample_app_population(
        rng, num_apps, num_slo_classes=S)
    monitor = ResourceMonitor(base, seed=seed + 1)
    demand = monitor.sample_p99()

    # --- initial assignment: SLO-respecting, imbalanced like Fig. 3 ---
    util_target = (initial_util if initial_util is not None
                   else FIG3_INITIAL_UTIL[:T] if T <= 5
                   else rng.uniform(0.25, 0.95, size=T))
    tier_weight = np.asarray(util_target, np.float64)
    assignment0 = np.zeros(num_apps, np.int32)
    for n in range(num_apps):
        ok = np.where(slo_allowed[:, slo[n]])[0]
        w = tier_weight[ok] / tier_weight[ok].sum()
        assignment0[n] = rng.choice(ok, p=w)

    # --- tiers: capacities sized so initial utilization ≈ util_target ---
    util0 = np.zeros((T, NUM_RESOURCES), np.float32)
    tasks0 = np.zeros(T, np.float32)
    np.add.at(util0, assignment0, demand)
    np.add.at(tasks0, assignment0, tasks)
    capacity = (util0 / np.asarray(util_target)[:, None]).astype(np.float32)
    capacity = np.maximum(capacity, demand.max(axis=0, keepdims=True) * 1.5)
    task_limit = np.maximum(tasks0 / np.asarray(util_target), tasks.max() * 2).astype(np.float32)

    problem = make_problem(
        demand=demand, tasks=tasks, slo=slo, criticality=criticality,
        assignment0=assignment0, capacity=capacity, task_limit=task_limit,
        slo_allowed=slo_allowed, move_frac=move_frac, weights=weights,
    )

    # --- hierarchy metadata (regions, hosts) ---
    # Geography: regions sit on a ring (think geo-distributed DCs); latency
    # grows with ring distance (~4ms intra-region, ~+14ms per hop).  Tiers
    # occupy *contiguous arcs* (real tiers are geo-located), so neighbouring
    # tiers overlap in regions and far tiers do not — this is what makes the
    # no_cnst / w_cnst / manual_cnst network trade-off (Fig. 4) non-trivial.
    G = num_regions
    ring_dist = np.abs(np.arange(G)[:, None] - np.arange(G)[None, :])
    ring_dist = np.minimum(ring_dist, G - ring_dist)
    lat = 4.0 + 14.0 * ring_dist + rng.uniform(0, 3, size=(G, G))
    lat = (lat + lat.T) / 2
    tier_regions = np.zeros((T, G), bool)
    for t in range(T):
        start = int(round(t * G / T)) % G
        arc = rng.integers(2, 4)
        tier_regions[t, [(start + j) % G for j in range(arc)]] = True
    # Apps were originally placed near their data source: sample the data
    # region from the initial tier's regions (with a little drift).
    app_region = np.zeros(num_apps, np.int32)
    for n in range(num_apps):
        opts = np.where(tier_regions[assignment0[n]])[0]
        if rng.random() < 0.85:
            app_region[n] = rng.choice(opts)
        else:
            app_region[n] = rng.choice(G)
    hosts_per_tier = rng.integers(40, 120, size=T).astype(np.int32)
    host_capacity = (capacity.sum(axis=0) / hosts_per_tier.sum() * 1.6).astype(np.float32)

    return ClusterState(
        problem=problem,
        app_names=[f"app_{i:05d}" for i in range(num_apps)],
        tier_names=[f"tier_{t + 1}" for t in range(T)],
        app_region=app_region,
        tier_regions=tier_regions,
        region_latency=lat.astype(np.float32),
        hosts_per_tier=hosts_per_tier,
        host_capacity=host_capacity,
    )
