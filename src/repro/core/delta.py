"""Exact O(N*T) delta-cost evaluation for all single-app candidate moves.

This is the LocalSearch hot-spot: at Meta scale (1e5 apps x 1e2 tiers) each
solver iteration scores every (app, tier) candidate.  The math below computes
the *exact* change of the scalarized objective (goals.objective) if app n is
re-assigned to tier t, in closed form from per-tier sufficient statistics —
no re-aggregation over apps.

The flat-array signature exists so that:
  * solver_local.py calls it through kernels/ops.py (XLA or Pallas impl),
  * kernels/ref.py re-exports it as the oracle for the Pallas kernel tests.

Derivation (per resource r, moving n: a -> t, load fractions f):
  f_a' = f_a - d[n,r]/C[a,r],   f_t' = f_t + d[n,r]/C[t,r]
  balance  = sum_u (f_u - mean)^2 = sum_u f_u^2 - T * mean^2
  d(sum f^2) = f_a'^2 - f_a^2 + f_t'^2 - f_t^2
  d(mean)    = (d[n,r]/C[t,r] - d[n,r]/C[a,r]) / T
  d(balance) = d(sum f^2) - T * ((mean + d(mean))^2 - mean^2)
  d(hinge)   = h(f_a')^2 - h(f_a)^2 + h(f_t')^2 - h(f_t)^2,  h(x)=max(0, x-ideal)
Movement / criticality terms flip with the move indicator delta.

Three entry points share the math:
  * ``move_delta_cost``     — the full [N, T] candidate sweep,
  * ``single_move_delta``   — one (app, tier) candidate re-evaluated against a
                              *partially updated* state; the incremental
                              re-check inside the batched top-k commit scan
                              (solver_local applies k moves per sweep and must
                              keep every accepted move strictly improving),
  * ``move_best_per_app``   — sweep + feasibility mask + per-app (score, tier)
                              argmin reduction fused in one jitted call; the
                              XLA oracle for kernels/move_eval.py's fused-best
                              Pallas kernel (output bandwidth N*2 vs N*T).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def move_delta_cost(
    demand: jax.Array,        # f32[N, R]
    tasks: jax.Array,         # f32[N]
    criticality: jax.Array,   # f32[N]
    assignment: jax.Array,    # i32[N] current
    assignment0: jax.Array,   # i32[N] original
    capacity: jax.Array,      # f32[T, R]
    task_limit: jax.Array,    # f32[T]
    ideal_frac: jax.Array,    # f32[T, R]
    ideal_task_frac: jax.Array,  # f32[T]
    util: jax.Array,          # f32[T, R] current absolute loads
    tier_tasks: jax.Array,    # f32[T]    current task loads
    weights: jax.Array,       # f32[5] (under_ideal, resource_balance,
                              #         task_balance, movement, criticality)
) -> jax.Array:
    """Returns delta[N, T]: objective change if app n moves to tier t.

    delta[n, assignment[n]] is exactly 0 (no-op move).
    """
    N, R = demand.shape
    T = capacity.shape[0]
    f = util / capacity                          # [T, R]
    g = tier_tasks / task_limit                  # [T]
    mean_f = jnp.mean(f, axis=0)                 # [R]
    mean_g = jnp.mean(g)

    # Per-app source-tier quantities.
    src = assignment                             # [N]
    C_src = capacity[src]                        # [N, R]
    f_src = f[src]                               # [N, R]
    ideal_src = ideal_frac[src]                  # [N, R]
    d_over_Csrc = demand / C_src                 # [N, R]
    f_src_new = f_src - d_over_Csrc              # [N, R]

    # Destination quantities, broadcast over T.
    d_over_Cdst = demand[:, None, :] / capacity[None, :, :]        # [N, T, R]
    f_dst = f[None, :, :]                                          # [1, T, R]
    f_dst_new = f_dst + d_over_Cdst                                # [N, T, R]

    # --- goal 6: resource balance delta ---
    d_sumsq = (f_src_new[:, None, :] ** 2 - f_src[:, None, :] ** 2
               + f_dst_new ** 2 - f_dst ** 2)                      # [N, T, R]
    d_mean = (d_over_Cdst - d_over_Csrc[:, None, :]) / T           # [N, T, R]
    new_mean = mean_f[None, None, :] + d_mean
    d_balance = d_sumsq - T * (new_mean ** 2 - mean_f[None, None, :] ** 2)
    d_resource_balance = jnp.sum(d_balance, axis=-1)               # [N, T]

    # --- goal 5: under-ideal hinge delta (resources) ---
    def h2(x, ideal):
        h = jnp.maximum(x - ideal, 0.0)
        return h * h

    d_hinge = (h2(f_src_new[:, None, :], ideal_src[:, None, :])
               - h2(f_src[:, None, :], ideal_src[:, None, :])
               + h2(f_dst_new, ideal_frac[None, :, :])
               - h2(f_dst, ideal_frac[None, :, :]))                # [N, T, R]
    d_under_ideal = jnp.sum(d_hinge, axis=-1)                      # [N, T]

    # --- task-count analogues (goals 5 + 7) ---
    K_src = task_limit[src]                                        # [N]
    g_src = g[src]
    gideal_src = ideal_task_frac[src]
    k_over_Ksrc = tasks / K_src
    g_src_new = g_src - k_over_Ksrc

    k_over_Kdst = tasks[:, None] / task_limit[None, :]             # [N, T]
    g_dst = g[None, :]
    g_dst_new = g_dst + k_over_Kdst

    d_sumsq_t = (g_src_new[:, None] ** 2 - g_src[:, None] ** 2
                 + g_dst_new ** 2 - g_dst ** 2)
    d_mean_t = (k_over_Kdst - k_over_Ksrc[:, None]) / T
    new_mean_t = mean_g + d_mean_t
    d_task_balance = d_sumsq_t - T * (new_mean_t ** 2 - mean_g ** 2)

    d_under_ideal = d_under_ideal + (
        h2(g_src_new[:, None], gideal_src[:, None]) - h2(g_src[:, None], gideal_src[:, None])
        + h2(g_dst_new, ideal_task_frac[None, :]) - h2(g_dst, ideal_task_frac[None, :]))

    # --- goals 8 + 9: movement indicator delta ---
    was_moved = (assignment != assignment0).astype(jnp.float32)    # [N]
    will_move = (jnp.arange(T)[None, :] != assignment0[:, None]).astype(jnp.float32)
    d_moved = will_move - was_moved[:, None]                       # [N, T] in {-1, 0, 1}
    total_tasks = jnp.maximum(jnp.sum(tasks), 1.0)
    total_crit = jnp.maximum(jnp.sum(criticality), 1.0)
    d_movement = d_moved * (tasks / total_tasks)[:, None]
    d_criticality = d_moved * (criticality / total_crit)[:, None]

    delta = (weights[0] * d_under_ideal
             + weights[1] * d_resource_balance
             + weights[2] * d_task_balance
             + weights[3] * d_movement
             + weights[4] * d_criticality)

    # Self-moves are exactly zero by construction up to fp error; pin them.
    self_move = jnp.arange(T)[None, :] == assignment[:, None]
    return jnp.where(self_move, 0.0, delta)


def single_move_delta(
    n: jax.Array,             # i32[] candidate app
    t: jax.Array,             # i32[] candidate destination tier
    src: jax.Array,           # i32[] app n's *current* tier
    demand: jax.Array,        # f32[N, R]
    tasks: jax.Array,         # f32[N]
    criticality: jax.Array,   # f32[N]
    assignment0: jax.Array,   # i32[N]
    capacity: jax.Array,      # f32[T, R]
    task_limit: jax.Array,    # f32[T]
    ideal_frac: jax.Array,    # f32[T, R]
    ideal_task_frac: jax.Array,  # f32[T]
    util: jax.Array,          # f32[T, R] *current* absolute loads
    tier_tasks: jax.Array,    # f32[T]
    weights: jax.Array,       # f32[5]
    total_tasks: jax.Array,   # f32[] precomputed sum(tasks) (clamped >= 1)
    total_crit: jax.Array,    # f32[] precomputed sum(criticality) (>= 1)
) -> jax.Array:
    """Exact scalar objective delta for ONE candidate move n: src -> t.

    Same closed forms as ``move_delta_cost`` but O(T*R) instead of O(N*T*R),
    so the batched commit scan can re-score each surviving top-k candidate
    against the state left behind by the moves already accepted this sweep.
    """
    T = capacity.shape[0]
    f = util / capacity                                  # [T, R]
    g = tier_tasks / task_limit                          # [T]
    mean_f = jnp.mean(f, axis=0)
    mean_g = jnp.mean(g)

    def h2(x, ideal):
        h = jnp.maximum(x - ideal, 0.0)
        return h * h

    d = demand[n]                                        # [R]
    dC_src = d / capacity[src]
    dC_dst = d / capacity[t]
    f_src, f_dst = f[src], f[t]
    f_src_new = f_src - dC_src
    f_dst_new = f_dst + dC_dst
    d_sumsq = f_src_new ** 2 - f_src ** 2 + f_dst_new ** 2 - f_dst ** 2
    new_mean = mean_f + (dC_dst - dC_src) / T
    d_resource_balance = jnp.sum(d_sumsq - T * (new_mean ** 2 - mean_f ** 2))
    d_under = jnp.sum(h2(f_src_new, ideal_frac[src]) - h2(f_src, ideal_frac[src])
                      + h2(f_dst_new, ideal_frac[t]) - h2(f_dst, ideal_frac[t]))

    k = tasks[n]
    dK_src = k / task_limit[src]
    dK_dst = k / task_limit[t]
    g_src, g_dst = g[src], g[t]
    g_src_new = g_src - dK_src
    g_dst_new = g_dst + dK_dst
    d_sumsq_t = g_src_new ** 2 - g_src ** 2 + g_dst_new ** 2 - g_dst ** 2
    new_mean_t = mean_g + (dK_dst - dK_src) / T
    d_task_balance = d_sumsq_t - T * (new_mean_t ** 2 - mean_g ** 2)
    d_under = d_under + (h2(g_src_new, ideal_task_frac[src])
                         - h2(g_src, ideal_task_frac[src])
                         + h2(g_dst_new, ideal_task_frac[t])
                         - h2(g_dst, ideal_task_frac[t]))

    was_moved = (src != assignment0[n]).astype(jnp.float32)
    will_move = (t != assignment0[n]).astype(jnp.float32)
    d_moved = will_move - was_moved
    d_movement = d_moved * tasks[n] / total_tasks
    d_criticality = d_moved * criticality[n] / total_crit

    return (weights[0] * d_under
            + weights[1] * d_resource_balance
            + weights[2] * d_task_balance
            + weights[3] * d_movement
            + weights[4] * d_criticality)


def move_best_per_app(
    demand: jax.Array, tasks: jax.Array, criticality: jax.Array,
    assignment: jax.Array, assignment0: jax.Array,
    capacity: jax.Array, task_limit: jax.Array,
    ideal_frac: jax.Array, ideal_task_frac: jax.Array,
    util: jax.Array, tier_tasks: jax.Array, weights: jax.Array,
    feasible: jax.Array,      # bool[N, T] static SLO/avoid/validity mask
    moves_left: jax.Array,    # i32[] remaining movement budget
) -> tuple[jax.Array, jax.Array]:
    """Fused sweep + move-mask + per-app argmin: (best_score[N], best_tier[N]).

    Mask semantics match constraints.move_mask exactly (capacity/task-limit
    headroom with the same 1e-6 tolerance, budget, SLO/avoid, no self-moves);
    infeasible apps get score +inf.  This is the reduction the batched
    LocalSearch actually needs — only the top-k of these N scores is ever
    looked at — and the contract the fused Pallas kernel is tested against.
    """
    from repro.core.constraints import destination_fits

    T = capacity.shape[0]
    delta = move_delta_cost(demand, tasks, criticality, assignment,
                            assignment0, capacity, task_limit, ideal_frac,
                            ideal_task_frac, util, tier_tasks, weights)
    fits = destination_fits(demand, tasks, capacity, task_limit,
                            util, tier_tasks)
    already_moved = assignment != assignment0
    budget_ok = already_moved[:, None] | (moves_left > 0)
    not_self = jnp.arange(T)[None, :] != assignment[:, None]
    mask = feasible & fits & budget_ok & not_self
    scores = jnp.where(mask, delta, jnp.inf)
    best_t = jnp.argmin(scores, axis=1).astype(jnp.int32)
    best_s = jnp.min(scores, axis=1)
    return best_s, best_t
