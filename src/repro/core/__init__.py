"""SPTLB core: the paper's contribution as a composable JAX module."""
from repro.core.problem import (GoalWeights, Problem, bucket_size,
                                make_problem, pad_problem, tier_loads,
                                utilization_fraction)
from repro.core.goals import goal_terms, objective
from repro.core.constraints import Violations, validate
from repro.core.solver_local import LocalSearchConfig, SolveResult, solve_local
from repro.core.solver_optimal import OptimalSearchConfig, solve_optimal
from repro.core.greedy import GreedyConfig, solve_greedy
from repro.core.hierarchy import (CooperationResult, HostScheduler,
                                  RegionScheduler, cooperate)
from repro.core.levels import (CoopConfig, CoopTimings, Hierarchy,
                               SchedulerLevel, ShardLocalityScheduler,
                               register_level)
from repro.core.telemetry import (ClusterState, ResourceMonitor,
                                  generate_cluster, shard_affinity_of)
from repro.core.metrics import (difference_to_balance, network_p99_ms,
                                projected_metrics)
from repro.core.planner import (Advisory, MaintenancePlanner, PlannerConfig,
                                PlanOutlook, move_costs, movement_cost_of)
from repro.core.shedding import LoadShedder, ShedConfig, ShedPlan
from repro.core.sptlb import BalanceDecision, Sptlb, engine_fn
from repro.core.utility import (attach_curves, default_curves,
                                delivered_fractions, fleet_utility,
                                oracle_utility, step_curves, utility_of)
from repro.core.health import (BreakerBoard, BreakerConfig, CircuitBreaker,
                               HealthConfig, TelemetryHealth,
                               TelemetryMonitor)
from repro.core.controller import (BalanceController, ControllerConfig,
                                   FaultToleranceConfig, Mode, TickInput,
                                   TickResult)

__all__ = [
    "Advisory", "MaintenancePlanner", "PlannerConfig", "PlanOutlook",
    "move_costs", "movement_cost_of",
    "GoalWeights", "Problem", "bucket_size", "make_problem", "pad_problem",
    "tier_loads",
    "utilization_fraction", "goal_terms", "objective", "Violations",
    "validate", "LocalSearchConfig", "SolveResult", "solve_local",
    "OptimalSearchConfig", "solve_optimal", "GreedyConfig", "solve_greedy",
    "CooperationResult", "HostScheduler", "RegionScheduler", "cooperate",
    "CoopConfig", "CoopTimings", "Hierarchy", "SchedulerLevel",
    "ShardLocalityScheduler", "register_level",
    "ClusterState", "ResourceMonitor", "generate_cluster",
    "shard_affinity_of",
    "difference_to_balance", "network_p99_ms", "projected_metrics",
    "LoadShedder", "ShedConfig", "ShedPlan",
    "BalanceDecision", "Sptlb", "engine_fn",
    "attach_curves", "default_curves", "delivered_fractions",
    "fleet_utility", "oracle_utility", "step_curves", "utility_of",
    "BreakerBoard", "BreakerConfig", "CircuitBreaker", "HealthConfig",
    "TelemetryHealth", "TelemetryMonitor",
    "BalanceController", "ControllerConfig", "FaultToleranceConfig", "Mode",
    "TickInput", "TickResult",
]
