"""Pluggable scheduler hierarchy: the ``SchedulerLevel`` protocol and stack.

The paper's headline claim is "how to integrate new schedulers into the
hierarchy of the existing ones, allowing multiple schedulers to work
together" — yet until PR 5 the §3.4 loop hardcoded exactly two levels
(region, host) inside ``cooperate``, and every new feature grew another
positional knob on ``cooperate``/``Sptlb.balance``.  This module makes the
integration contract first-class:

  * ``SchedulerLevel`` — the protocol one scheduler tier implements.  The
    cooperation bus (``core.hierarchy.cooperate``) drives any ordered stack
    of levels through the same premask / solve / vet / feedback fixpoint
    that used to be hand-woven for region+host (the premask/vet/feedback
    decomposition the scheduler-taxonomy survey, arXiv 2511.01860, frames
    as the reusable interface between hierarchy tiers):

      - ``premask(problem)``   -> [N, T] avoid contribution folded into the
        solver's mask before the first solve (None: nothing to premask).
        The bus re-opens the home column — staying put is always legal.
      - ``vet(proposal)``      -> i64[K] app ids rejected among
        ``proposal.candidates`` (Fig. 2's accept/reject answer).
      - ``feedback(state)``    -> optional extra [N, T] standing avoid mask
        OR-ed into the bus's base mask after a rejection round (escalation
        beyond the per-(app, dest) constraint the bus already scatters).
      - ``relax(plan, cluster)`` -> maintenance-mode hook: a declared
        ``core.planner.PlanOutlook`` may loosen the level's own contract
        (the region level relaxes latency budgets for residents of a deep
        drain; the shard level relaxes co-location for the same apps).
      - ``counters()``         -> level-specific observability merged into
        ``CoopTimings.levels[name]`` (the host level reports its pack
        dispatch/retrace counters); ``device_time_s()`` is the share of
        the level's wall-clock spent in compiled device dispatches (it
        counts device-side in ``host_side_frac``).

  * ``Hierarchy`` — an ordered stack of level *factories*
    (``cluster -> SchedulerLevel``), bound per cooperation pass.  The
    default stack is region+host, bit-identical to the pre-protocol path;
    ``Hierarchy.from_names("region,host,shard")`` resolves through the
    registry so a plugin level is one ``register_level`` call away.

  * ``CoopConfig`` — the consolidated knob record accepted by
    ``cooperate()``, ``Sptlb.balance()``, and ``ControllerConfig``.  The
    PR-5 deprecated kwarg shims are gone: the config record is the only
    knob surface.

  * ``CoopTimings`` — the typed replacement for the cooperation timings
    dict: per-level sub-dicts keyed by level name, with mapping-style
    ``__getitem__`` back-compat so ``timings["region_s"]``-style readers
    (benchmarks, tests, BENCH baselines) keep working unchanged.

  * ``ShardLocalityScheduler`` — the proof-of-extensibility third level:
    vets moves against per-app data-shard co-location
    (``telemetry.shard_affinity_of``'s [N, T] matrix), with premask,
    rejection-escalation feedback, and a maintenance relax hook — ~100
    lines, no changes to the bus.

Cache-invalidation contract for level authors: anything derived from
cluster geometry belongs in ``ClusterState._cache`` (see
``telemetry.ClusterState``) — every ``dataclasses.replace`` of the cluster
starts a fresh cache, so entries can never outlive the arrays they were
derived from.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal, Mapping, Optional, Union

import numpy as np

Variant = Literal["no_cnst", "w_cnst", "manual_cnst"]

# The default stack: the paper's two lower-level schedulers, in Fig. 2 order.
DEFAULT_LEVELS = ("region", "host")

# Minimum data-shard affinity a placement must keep (share of the app's
# shard mass co-located with the destination tier's regions) unless its
# current placement is already worse — see ShardLocalityScheduler.
SHARD_MIN_AFFINITY = 0.25

# The latency-SLO source of truth.  The region scheduler's default budget
# (ms): placements must keep an app within this worst-case latency of its
# data-source region.  The maintenance relax factor is the default bounded
# degradation granted to residents evacuating a declared deep drain.  Both
# used to be duplicated literals in ``core.hierarchy`` and the level
# implementations below; every consumer (region level, shard level, the
# planner's PlanOutlook default, ``sim.slo`` breach accounting) now reads
# these — and the measured-latency level (``repro.netlat``) overrides them
# with calibrated per-region-pair budgets from streaming percentiles.
REGION_LATENCY_BUDGET_MS = 36.0
RELAX_LATENCY_FACTOR = 1.5


@dataclasses.dataclass
class Proposal:
    """One mapping proposal handed down the stack for vetting.

    ``candidates`` are the moved apps this level must answer for — the ids
    that survived every level above it this round.  ``returners`` (final
    revert fixpoint only) are apps sent home since this level last vetted:
    a level whose accept/reject depends on whole-group state (host packing
    is not monotone under item removal) must re-vet the home tiers those
    returners land in.
    """

    x: np.ndarray  # i64[N] proposed assignment
    x0: np.ndarray  # i64[N] incumbent assignment
    candidates: np.ndarray  # i64[K] movers to vet (ascending app id)
    returners: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64)
    )
    final: bool = False  # True inside the post-loop revert fixpoint


@dataclasses.dataclass
class BusState:
    """What a level sees after a feedback round (``feedback`` hook input)."""

    round: int
    x: np.ndarray  # i64[N] this round's proposal
    x0: np.ndarray  # i64[N] incumbent assignment
    rejections: dict  # level name -> i64[K] ids rejected this round


class SchedulerLevel:
    """Base/no-op implementation of the level protocol (duck-typed: any
    object with these methods and a ``name`` works; subclassing just saves
    boilerplate).  Every hook is optional — the default is 'accept
    everything, constrain nothing'."""

    name: str = "level"

    def premask(self, problem) -> Optional[np.ndarray]:
        """[N, T] avoid contribution folded in before the first solve."""
        return None

    def vet(self, proposal: Proposal) -> np.ndarray:
        """Rejected app ids among ``proposal.candidates`` (i64[K])."""
        return np.empty(0, np.int64)

    def feedback(self, state: BusState) -> Optional[np.ndarray]:
        """Optional extra [N, T] standing avoid mask after a round."""
        return None

    def relax(self, plan, cluster) -> None:
        """Maintenance-mode hook: adapt to a declared PlanOutlook."""

    def counters(self) -> dict:
        """Level-specific observability for ``CoopTimings.levels[name]``."""
        return {}

    def device_time_s(self) -> float:
        """Wall-clock share spent in compiled device dispatches."""
        return 0.0


# -- level registry ----------------------------------------------------------

_REGISTRY: dict[str, Callable] = {}


def register_level(name: str, factory: Callable) -> None:
    """Register a level factory (``cluster -> SchedulerLevel``) under a
    name usable in ``Hierarchy.from_names`` / ``CoopConfig.levels`` /
    ``--levels`` flags."""
    _REGISTRY[name] = factory


def level_factory(name: str) -> Callable:
    if name not in _REGISTRY:
        # The built-in region/host levels live in core.hierarchy, which
        # registers them on import; resolve lazily so `import levels` alone
        # (no hierarchy import yet) still finds them.
        import repro.core.hierarchy  # noqa: F401  (registration side effect)

    if name not in _REGISTRY:
        # The cross-shard fleet coordinator registers from the shard
        # subsystem — same lazy-registration contract as the builtins.
        try:
            import repro.shard  # noqa: F401  (registration side effect)
        except ImportError:
            pass

    if name not in _REGISTRY:
        # The measured-latency level ("netlat") registers from the netlat
        # subsystem — same lazy-registration contract.
        try:
            import repro.netlat  # noqa: F401  (registration side effect)
        except ImportError:
            pass

    if name not in _REGISTRY:
        raise KeyError(f"unknown scheduler level {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


class Hierarchy:
    """An ordered stack of scheduler-level factories.

    ``bind(cluster)`` instantiates the stack for one cooperation pass —
    levels are per-pass objects (they memoize geometry on the cluster's
    cache, carry pack counters, and may be relaxed by a plan), so a
    Hierarchy is reusable across clusters and ticks while its bound levels
    are not.
    """

    def __init__(self, factories):
        self.factories = tuple(factories)

    @classmethod
    def default(cls) -> "Hierarchy":
        return cls.from_names(DEFAULT_LEVELS)

    @classmethod
    def from_names(cls, names) -> "Hierarchy":
        if isinstance(names, str):
            names = [n for n in names.split(",") if n.strip()]
        return cls(tuple(level_factory(str(n).strip()) for n in names))

    def bind(self, cluster) -> list:
        return [factory(cluster) for factory in self.factories]

    def __len__(self) -> int:
        return len(self.factories)


# -- consolidated cooperation config ----------------------------------------


@dataclasses.dataclass(eq=False)
class CoopConfig:
    """Every cooperation/balance knob in one record.

    ``Sptlb.balance(config=CoopConfig(...))`` and
    ``cooperate(..., config=...)`` replace the historical kwarg sprawl
    (variant / max_feedback_rounds / batch_moves / bucket_apps /
    premask_region / restart_rounds / plan / move_cost / cost_budget);
    the PR-5 shims for those keywords have been removed.

    ``timeout_s`` is the cooperation pass's wall-clock budget; None lets
    ``Sptlb.balance`` derive its historical ``3 x engine timeout``.
    ``levels`` names the scheduler stack (registry order matters); None is
    the default region+host stack.  ``plan`` / ``move_cost`` /
    ``cost_budget`` are the per-call dynamic inputs (the controller
    replaces them every tick via ``dataclasses.replace``).  ``breakers``
    is an optional ``core.health.BreakerBoard``: when set, the bus runs
    per-level circuit breakers (bypass + fallback premask for OPEN levels,
    fail-closed vets, half-open probes); None keeps the fault machinery
    completely out of the code path (bit-identical to PR-5 behaviour).
    """

    variant: Variant = "manual_cnst"
    max_rounds: int = 8
    timeout_s: Optional[float] = None
    # Premask folding: a global bool (the historical knob), or a per-level
    # mapping {level_name: bool} — levels absent from the mapping default to
    # True, so {"shard": False} keeps region/host folded while leaving the
    # shard level's feasibility to its interactive vet.  ``premask_for``
    # resolves either form.
    premask: Union[bool, Mapping[str, bool]] = True
    restart_rounds: int = 0
    batch_moves: Optional[int] = None  # engine: top-k commit batch override
    bucket_apps: bool = True  # engine: pow-2 app-bucket jit caching
    levels: Optional[tuple] = None  # level names; None -> DEFAULT_LEVELS
    plan: object = None  # core.planner.PlanOutlook | None
    move_cost: Optional[np.ndarray] = None  # f32[N] per-app move pricing
    cost_budget: float = float("inf")
    breakers: object = None  # core.health.BreakerBoard | None
    # core.shedding.ShedPlan | None.  Unlike ``plan`` (which only steers the
    # solver), an active shed plan is an *actuated* throttle: the bus scales
    # the problem's demand by the delivery caps before the solver sees it
    # AND before the decision is judged — the fleet really serves less.
    shed: object = None

    def premask_for(self, name: str) -> bool:
        """Whether level ``name``'s feasibility is folded pre-solve."""
        if isinstance(self.premask, bool):
            return self.premask
        return bool(self.premask.get(name, True))

    def hierarchy(self, override: Optional[Hierarchy] = None) -> Hierarchy:
        if override is not None:
            return override
        if self.levels is None:
            return Hierarchy.default()
        return Hierarchy.from_names(self.levels)


# -- typed timings with mapping back-compat ----------------------------------

# Legacy per-level counter keys that live at the top level of the flat
# view (and historically existed even for variants that never packed).
_PACK_KEYS = {
    "pack_s": 0.0,
    "pack_dispatches": 0,
    "pack_retraces": 0,
    "resident_overflows": 0,
}


@dataclasses.dataclass
class CoopTimings:
    """Per-pass cooperation observability (replaces the untyped dict).

    Scalar phases/counters are fields; per-level detail lives in
    ``levels[name]`` (``level_s`` host-side glue wall-clock, ``rejections``,
    plus whatever the level's ``counters()`` reports).  Mapping-style
    access keeps every historical key working: ``timings["region_s"]`` /
    ``timings["host_rejections"]`` resolve into the per-level sub-dicts,
    and ``dict(timings)`` flattens to the legacy record (plus ``levels``)
    for JSON benchmarks.
    """

    solve_s: float = 0.0
    feedback_s: float = 0.0
    total_s: float = 0.0
    host_side_frac: float = 0.0
    bus_overhead_frac: float = 0.0
    rounds: int = 1
    restarts: int = 0
    restart_improved: int = 0
    movement_cost: float = 0.0
    budget_trimmed: int = 0
    round_costs: list = dataclasses.field(default_factory=list)
    premask: bool = False
    levels: dict = dataclasses.field(default_factory=dict)
    # Circuit-breaker observability: {} unless CoopConfig.breakers is set,
    # else per-level state/trip/probe snapshots plus this pass's bypasses.
    breakers: dict = dataclasses.field(default_factory=dict)

    # -- construction helpers used by the bus --------------------------------
    @classmethod
    def for_levels(cls, names, **kw) -> "CoopTimings":
        tm = cls(**kw)
        for name in names:
            tm.levels[name] = {"level_s": 0.0, "rejections": 0}
        return tm

    def add_level_time(self, name: str, seconds: float) -> None:
        self.levels.setdefault(name, {"level_s": 0.0, "rejections": 0})
        self.levels[name]["level_s"] += seconds

    def add_rejections(self, name: str, count: int) -> None:
        self.levels.setdefault(name, {"level_s": 0.0, "rejections": 0})
        self.levels[name]["rejections"] += int(count)

    # -- mapping back-compat --------------------------------------------------
    _FIELDS = (
        "solve_s",
        "feedback_s",
        "total_s",
        "host_side_frac",
        "bus_overhead_frac",
        "rounds",
        "restarts",
        "restart_improved",
        "movement_cost",
        "budget_trimmed",
        "round_costs",
        "premask",
        "levels",
        "breakers",
    )

    def _level_key(self, key: str):
        """Resolve '<name>_s' / '<name>_rejections' into the level dicts."""
        for suffix, sub in (("_rejections", "rejections"), ("_s", "level_s")):
            if key.endswith(suffix):
                name = key[: -len(suffix)]
                if name in self.levels:
                    return self.levels[name], sub
        return None

    def __getitem__(self, key: str):
        if key in self._FIELDS:
            return getattr(self, key)
        if key in _PACK_KEYS:
            total = _PACK_KEYS[key]
            for sub in self.levels.values():
                total += sub.get(key, 0)
            return total
        hit = self._level_key(key)
        if hit is not None:
            sub, name = hit
            return sub[name]
        raise KeyError(key)

    def __setitem__(self, key: str, value) -> None:
        if key in self._FIELDS:
            setattr(self, key, value)
            return
        hit = self._level_key(key)
        if hit is not None:
            sub, name = hit
            sub[name] = value
            return
        raise KeyError(key)

    def __contains__(self, key) -> bool:
        try:
            self[key]
        except KeyError:
            return False
        return True

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def keys(self) -> list:
        """The flat legacy view: scalar fields, per-level derived keys,
        pack counters, and the structured ``levels`` record itself."""
        out = list(self._FIELDS)
        out.remove("levels")
        # Keep the flat record stable for fault-free passes: the breakers
        # key only appears once a BreakerBoard actually ran.
        if not self.breakers:
            out.remove("breakers")
        for name in self.levels:
            out += [f"{name}_s", f"{name}_rejections"]
        out += list(_PACK_KEYS)
        out.append("levels")
        return out

    def items(self):
        return [(k, self[k]) for k in self.keys()]

    def as_dict(self) -> dict:
        return {k: self[k] for k in self.keys()}


# -- the proof-of-extensibility third level ----------------------------------


class ShardLocalityScheduler(SchedulerLevel):
    """Vets placements against per-app data-shard co-location.

    A stream job's state shards live near its data source; placing the job
    on a tier holding too little of its shard mass means every window/join
    reads remote state.  The level accepts a move iff the destination
    tier's shard affinity (``telemetry.shard_affinity_of``, [N, T] share of
    the app's shard mass in the tier's regions) stays at or above
    ``min_affinity`` — never demanding more affinity than the incumbent
    placement already provides, so staying home and repairing an already
    misplaced app both stay legal.

    Protocol hooks exercised beyond vet: ``premask`` folds the affinity
    threshold into the solver's avoid mask; ``feedback`` escalates apps the
    level keeps rejecting (>= ``escalate_after`` times) into standing
    avoid rows; ``relax`` lowers the bar by the plan's relax factor for
    residents evacuating a declared deep drain (same bounded-degradation
    contract as the region level's latency relax).
    """

    name = "shard"

    def __init__(
        self,
        cluster,
        min_affinity: float = SHARD_MIN_AFFINITY,
        escalate_after: int = 2,
    ):
        from repro.core.telemetry import shard_affinity_of

        self.cluster = cluster
        self.affinity = shard_affinity_of(cluster)  # f32[N, T]
        self.min_affinity = float(min_affinity)
        self.escalate_after = int(escalate_after)
        self._x0 = np.asarray(cluster.problem.assignment0, np.int64)
        # Per-app acceptance bar: min_affinity, capped by what home already
        # provides (an app whose incumbent tier holds little of its shard
        # mass must stay movable — requiring more than home would strand it).
        self._bar = np.minimum(
            self.min_affinity, self.affinity[np.arange(self._x0.size), self._x0]
        ).astype(np.float32)
        self._reject_counts = np.zeros(self._x0.size, np.int32)
        self._escalated = 0

    def relax(self, plan, cluster) -> None:
        relax_tiers = getattr(plan, "relax_home_tiers", None)
        if plan is None or relax_tiers is None or not np.asarray(relax_tiers).any():
            return
        resident = np.asarray(relax_tiers)[self._x0]
        factor = float(getattr(plan, "relax_latency_factor", RELAX_LATENCY_FACTOR))
        self._bar = np.where(resident, self._bar / factor, self._bar).astype(np.float32)

    def premask(self, problem) -> np.ndarray:
        # Home column re-opened by the bus; everything below the bar is
        # masked before the solver ever proposes it.
        return self.affinity < self._bar[:, None]

    def vet(self, proposal: Proposal) -> np.ndarray:
        c = proposal.candidates
        if c.size == 0:
            return c
        ok = self.affinity[c, proposal.x[c]] >= self._bar[c]
        rejected = c[~ok]
        self._reject_counts[rejected] += 1
        return rejected

    def feedback(self, state: BusState) -> Optional[np.ndarray]:
        """Escalate repeat offenders: once an app has been rejected
        ``escalate_after`` times, every below-bar tier becomes a standing
        avoid row (not just the destinations already tried)."""
        hot = np.where(self._reject_counts >= self.escalate_after)[0]
        if hot.size == 0:
            return None
        self._reject_counts[hot] = -(2**30)  # escalate once per app
        self._escalated += int(hot.size)
        mask = np.zeros(self.affinity.shape, bool)
        mask[hot] = self.affinity[hot] < self._bar[hot, None]
        return mask

    def counters(self) -> dict:
        return {"escalated": self._escalated}


register_level("shard", ShardLocalityScheduler)
