"""Baseline greedy scheduler (paper §4.1) — the stand-in for manual balancing.

Per-objective variants (cpu / mem / task count):
  1. identify the tier with the most resources used given the utilization
     target (used / target) and the least,
  2. identify the largest app (on that objective) in the hot tier that has
     not already been moved,
  3. move it to the tier with the lowest utilization,
  4. loop from 1 until x% of apps moved or timeout.

Faithful notes: the greedy variants respect SLO placement (a human operator
would), but are otherwise single-objective — which is exactly what Fig. 3
punishes them for.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.problem import Problem
from repro.core.solver_local import SolveResult

OBJECTIVES = ("cpu", "mem", "task")


@dataclasses.dataclass(frozen=True)
class GreedyConfig:
    objective: str = "cpu"        # one of OBJECTIVES
    max_steps: int = 10_000       # "timeout"


def solve_greedy(problem: Problem, config: GreedyConfig = GreedyConfig()) -> SolveResult:
    assert config.objective in OBJECTIVES, config.objective
    t0 = time.perf_counter()

    demand = np.asarray(problem.demand)
    tasks = np.asarray(problem.tasks)
    slo = np.asarray(problem.slo)
    capacity = np.asarray(problem.capacity)
    task_limit = np.asarray(problem.task_limit)
    ideal = np.asarray(problem.ideal_frac)
    ideal_task = np.asarray(problem.ideal_task_frac)
    slo_allowed = np.asarray(problem.slo_allowed)
    x = np.asarray(problem.assignment0).copy()
    x0 = np.asarray(problem.assignment0)
    N, T = demand.shape[0], capacity.shape[0]
    budget = int(problem.move_budget)   # same f32 rounding as the solvers

    if config.objective == "task":
        def load_of():
            return np.bincount(x, weights=tasks, minlength=T)
        target = ideal_task * task_limit
        app_size = tasks
    else:
        r = OBJECTIVES.index(config.objective)
        def load_of():
            return np.bincount(x, weights=demand[:, r], minlength=T)
        target = ideal[:, r] * capacity[:, r]
        app_size = demand[:, r]

    moved: set[int] = set()
    steps = 0
    while len(moved) < budget and steps < config.max_steps:
        steps += 1
        load = load_of()
        ratio = load / np.maximum(target, 1e-9)          # used / util target
        src = int(np.argmax(ratio))
        dst = int(np.argmin(ratio))
        if src == dst or ratio[src] <= ratio[dst] + 1e-9:
            break
        # Largest unmoved app (on this objective) in the hot tier that the
        # destination tier's SLO table accepts.
        cand = [n for n in np.where(x == src)[0]
                if n not in moved and slo_allowed[dst, slo[n]]]
        if not cand:
            break
        n = max(cand, key=lambda i: app_size[i])
        # No look-ahead: greedy moves the largest app even when that flips
        # the imbalance — faithful to §4.1 (step 3 is unconditional).
        x[n] = dst
        moved.add(n)

    dt = time.perf_counter() - t0
    from repro.core import goals   # local import to avoid cycles at module load
    import jax.numpy as jnp
    xj = jnp.asarray(x)
    return SolveResult(
        assignment=xj,
        iterations=steps,
        # Greedy is deterministic and ignores warm starts, so any
        # termination is final — re-solving cannot improve it.  (Budget
        # exhaustion is visible via num_moved; reporting it here made the
        # cooperation loop's convergence-continuation re-solve a no-op
        # proposal.)
        converged=True,
        objective=float(goals.objective(problem, xj)),
        num_moved=int(np.sum(x != x0)),
        solve_time_s=dt,
    )
