"""Goal terms (paper §3.2.1, items 5-9) and the scalarized objective.

All goals are "always lower priority to constraints"; hard constraints are
handled in constraints.py / the solvers' move masks.  Each term below is a
pure function of (problem, assignment) so both solvers and the Pallas
move_eval kernel's oracle share a single definition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import Problem, tier_loads
from repro.core.utility import tier_delivery_factor, utility_of

# Fleet-utility goal weight: between goal 5 (under_ideal, 1e4) and goal 6
# (resource_balance, 1e3) in the decade hierarchy — under overload the
# utility term decides *which* apps ride the saturated tiers, outranking
# every balance/movement preference but never the under-ideal hinge that
# drives the overload off in the first place.  The term only exists when
# curves are attached (``Problem.util_*`` is not None); without curves the
# objective is bit-identical to the pre-utility code.
FLEET_UTILITY_WEIGHT = 5e3


def _utility_shortfall(problem: Problem, delivered: jax.Array) -> jax.Array:
    """Normalized fleet-utility loss in [0, 1] (lower is better).

    ``delivered`` maps the per-tier fair-throttle factor onto apps: hard
    assignments index it, the soft relaxation takes an expectation.
    """
    u = utility_of(delivered, problem.util_knee, problem.util_slope,
                   problem.util_weight)
    w = problem.valid.astype(u.dtype)
    max_u = jnp.maximum(jnp.sum(problem.util_weight * w), 1e-9)
    return (max_u - jnp.sum(u * w)) / max_u


def goal_terms(problem: Problem, assignment: jax.Array) -> dict[str, jax.Array]:
    """All five goal terms for an assignment (plus the fleet-utility
    shortfall when curves are attached).  Lower is better for each."""
    util, tasks = tier_loads(problem, assignment)
    util_frac = util / problem.capacity                  # [T, R]
    task_frac = tasks / problem.task_limit               # [T]

    # Goal 5: prefer under the ideal utilization limit (70% default).
    # Hinge^2 — a valid solution can violate it ("allowing for solutions to
    # be provided when multiple tiers [are] under heavy load").
    over = jnp.maximum(util_frac - problem.ideal_frac, 0.0)
    over_t = jnp.maximum(task_frac - problem.ideal_task_frac, 0.0)
    under_ideal = jnp.sum(over * over) + jnp.sum(over_t * over_t)

    # Goal 6: resource usage balanced across tiers — relative to each tier's
    # capacity (paper: "this is relative to each tier, due to statements 1, 4").
    mean_frac = jnp.mean(util_frac, axis=0, keepdims=True)
    resource_balance = jnp.sum((util_frac - mean_frac) ** 2)

    # Goal 7: task count balanced across tiers (relative, statements 2, 3).
    task_balance = jnp.sum((task_frac - jnp.mean(task_frac)) ** 2)

    # Movement indicator.
    moved = (assignment != problem.assignment0).astype(jnp.float32)

    # Goal 8: low downtime — task_count as the cost of movement.
    total_tasks = jnp.maximum(jnp.sum(problem.tasks), 1.0)
    movement_cost = jnp.sum(moved * problem.tasks) / total_tasks

    # Goal 9: high-criticality apps moved less frequently — criticality as a
    # (negative) affinity for the current container.
    total_crit = jnp.maximum(jnp.sum(problem.criticality), 1.0)
    criticality = jnp.sum(moved * problem.criticality) / total_crit

    terms = {
        "under_ideal": under_ideal,
        "resource_balance": resource_balance,
        "task_balance": task_balance,
        "movement_cost": movement_cost,
        "criticality": criticality,
    }
    if problem.has_utility:
        delivered = tier_delivery_factor(util_frac)[assignment]
        terms["utility_shortfall"] = _utility_shortfall(problem, delivered)
    return terms


def objective(problem: Problem, assignment: jax.Array) -> jax.Array:
    """Scalarized multi-objective cost (lower is better)."""
    terms = goal_terms(problem, assignment)
    w = problem.weights
    obj = (w.under_ideal * terms["under_ideal"]
           + w.resource_balance * terms["resource_balance"]
           + w.task_balance * terms["task_balance"]
           + w.movement_cost * terms["movement_cost"]
           + w.criticality * terms["criticality"])
    if problem.has_utility:
        obj = obj + FLEET_UTILITY_WEIGHT * terms["utility_shortfall"]
    return obj


def soft_objective(problem: Problem, probs: jax.Array) -> jax.Array:
    """Relaxed objective over a row-stochastic assignment matrix P[N, T].

    Used by OptimalSearch (solver_optimal.py).  Expectations of the hard
    assignment goals under independent per-app categorical distributions.
    """
    util = probs.T @ problem.demand                      # [T, R] expected load
    tasks = probs.T @ problem.tasks                      # [T]
    util_frac = util / problem.capacity
    task_frac = tasks / problem.task_limit

    over = jnp.maximum(util_frac - problem.ideal_frac, 0.0)
    over_t = jnp.maximum(task_frac - problem.ideal_task_frac, 0.0)
    under_ideal = jnp.sum(over * over) + jnp.sum(over_t * over_t)

    mean_frac = jnp.mean(util_frac, axis=0, keepdims=True)
    resource_balance = jnp.sum((util_frac - mean_frac) ** 2)
    task_balance = jnp.sum((task_frac - jnp.mean(task_frac)) ** 2)

    # P(move) = 1 - P[n, x0_n]
    stay = jnp.take_along_axis(probs, problem.assignment0[:, None], axis=1)[:, 0]
    moved = 1.0 - stay
    total_tasks = jnp.maximum(jnp.sum(problem.tasks), 1.0)
    movement_cost = jnp.sum(moved * problem.tasks) / total_tasks
    total_crit = jnp.maximum(jnp.sum(problem.criticality), 1.0)
    criticality = jnp.sum(moved * problem.criticality) / total_crit

    w = problem.weights
    obj = (w.under_ideal * under_ideal
           + w.resource_balance * resource_balance
           + w.task_balance * task_balance
           + w.movement_cost * movement_cost
           + w.criticality * criticality)
    if problem.has_utility:
        # Expected delivered fraction: each app's categorical mixes the
        # tiers' fair-throttle factors.
        delivered = probs @ tier_delivery_factor(util_frac)
        obj = obj + FLEET_UTILITY_WEIGHT * _utility_shortfall(problem, delivered)
    return obj
