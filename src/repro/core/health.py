"""Control-plane health: telemetry plausibility + per-level circuit breakers.

The paper's controller assumes a fault-free world — fresh telemetry every
tick, every scheduler level answering within budget.  Henge (arXiv
1802.00082) argues graceful degradation under stress must be a *designed,
scored* outcome; this module supplies the two sensing layers the
degraded-mode controller (``core.controller``) consumes:

* **Telemetry health** (``TelemetryMonitor``): per-signal staleness and
  plausibility tracking over the collected ``ClusterState``.  Implausible
  readings (non-finite, negative, or jumping more than
  ``max_jump_factor``x against the last-known-good snapshot) are
  *quarantined* — the sanitized cluster carries the last-known-good value
  instead, inflated by an uncertainty factor that widens with staleness so
  planning against old data stays conservative.  Fresh, plausible
  telemetry passes through **bit-identical** (the parity suite pins this):
  health sensing costs nothing until something is actually wrong.

* **Per-level circuit breakers** (``BreakerBoard``): one breaker per
  scheduler level, owned by the controller and threaded through
  ``CoopConfig.breakers`` into the cooperation bus.  A level that
  repeatedly raises, exceeds its vet budget, or rejects everything trips
  OPEN and is bypassed for ``cooldown_passes`` cooperation passes — its
  conservative fallback premask still constrains the solver, but its
  interactive vet/feedback path is out of the loop.  Exponential-backoff
  HALF_OPEN probes re-admit it: a clean probe pass closes the breaker, a
  failing probe re-opens it with the cooldown doubled (capped).  All
  state/trip/probe counters surface in ``CoopTimings.breakers``.

Time is counted in cooperation *passes* (one per controller trigger), not
wall-clock — mode decisions must be deterministic given the scenario seed,
so nothing in here reads a clock except the optional per-vet wall-clock
budget (``BreakerConfig.level_timeout_s``, off by default).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.telemetry import ClusterState

# Breaker states (strings, not an enum: they go straight into JSON records).
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


# ---------------------------------------------------------------------------
# telemetry health
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Knobs for the telemetry monitor.

    ``stale_after`` is the age (ticks) at which a signal starts losing
    health; ``blind_after`` the age at which it is worth nothing.  A
    reading is implausible when any per-app demand/task entry is
    non-finite, negative, or more than ``max_jump_factor``x its
    last-known-good value (with ``jump_floor`` absolute slack so tiny
    denominators don't quarantine noise).  While telemetry is stale the
    last-known-good demand is inflated by ``uncertainty_growth`` per tick
    of age (capped at ``max_inflation``) — planning against old data
    should over-provision, not under.
    """

    stale_after: int = 1
    blind_after: int = 5
    max_jump_factor: float = 8.0
    jump_floor: float = 1.0
    uncertainty_growth: float = 0.05
    max_inflation: float = 1.5
    # Weight of the quarantined-fraction penalty in the plausibility score:
    # quarantining this fraction of live apps zeroes the signal's health.
    quarantine_blind_frac: float = 0.25


@dataclasses.dataclass
class SignalHealth:
    """Health record for one telemetry signal (demand / tasks / ...)."""

    name: str
    staleness: int = 0
    quarantined: int = 0
    live: int = 0
    score: float = 1.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TelemetryHealth:
    """What the controller consumes: per-signal records + composite score."""

    now: int
    collected_at: int
    signals: dict = dataclasses.field(default_factory=dict)

    @property
    def staleness(self) -> int:
        return max(0, self.now - self.collected_at)

    @property
    def quarantined(self) -> int:
        return sum(s.quarantined for s in self.signals.values())

    @property
    def score(self) -> float:
        """Composite telemetry health in [0, 1]: the worst signal rules
        (one blind signal makes the whole collection untrustworthy)."""
        if not self.signals:
            return 1.0
        return float(min(s.score for s in self.signals.values()))

    def as_dict(self) -> dict:
        return {
            "now": self.now,
            "collected_at": self.collected_at,
            "staleness": self.staleness,
            "score": round(self.score, 4),
            "signals": {k: v.as_dict() for k, v in self.signals.items()},
        }


class TelemetryMonitor:
    """Stateful staleness/plausibility tracker over collected clusters.

    ``ingest(cluster, now)`` returns ``(sanitized_cluster, health)``.  The
    sanitized cluster is the one the controller should plan against:
    quarantined rows carry the last-known-good value, and stale telemetry
    is inflated by the widening uncertainty factor.  When telemetry is
    fresh and plausible the input cluster is returned *unchanged* (same
    object — the parity tests pin this identity).
    """

    def __init__(self, config: HealthConfig = HealthConfig()):
        self.config = config
        self._lkg_demand: Optional[np.ndarray] = None  # f32[N, R]
        self._lkg_tasks: Optional[np.ndarray] = None   # f32[N]
        self.last_health: Optional[TelemetryHealth] = None
        self._external: dict[str, SignalHealth] = {}

    def note_signal(self, health: SignalHealth) -> None:
        """Fold an externally-sensed signal into subsequent health records.

        Producers outside the demand/tasks telemetry path — e.g. the
        measured-latency sketch bank (``repro.netlat``), whose corrupt or
        stale link readings must degrade the composite score the same way
        blind demand telemetry does — publish their ``SignalHealth`` here.
        The record persists until the producer replaces it, so a signal
        that went quiet keeps weighing on the score instead of vanishing.
        """
        self._external[health.name] = health

    # -- scoring helpers ------------------------------------------------------
    def _staleness_score(self, staleness: int) -> float:
        cfg = self.config
        if staleness <= cfg.stale_after:
            return 1.0
        if staleness >= cfg.blind_after:
            return 0.0
        span = max(1, cfg.blind_after - cfg.stale_after)
        return 1.0 - (staleness - cfg.stale_after) / span

    def _inflation(self, staleness: int) -> float:
        cfg = self.config
        return float(min(cfg.max_inflation,
                         (1.0 + cfg.uncertainty_growth) ** max(0, staleness)))

    def _quarantine(self, values: np.ndarray, lkg: Optional[np.ndarray],
                    live: np.ndarray) -> np.ndarray:
        """bool[N] rows whose reading is implausible vs the last-known-good."""
        cfg = self.config
        flat_bad = ~np.isfinite(values) | (values < 0)
        bad = flat_bad.any(axis=1) if values.ndim > 1 else flat_bad
        if lkg is not None:
            ref = np.abs(lkg) + cfg.jump_floor
            jump = np.abs(values - lkg) > (cfg.max_jump_factor - 1.0) * ref
            bad = bad | (jump.any(axis=1) if jump.ndim > 1 else jump)
        return bad & live

    def ingest(self, cluster: ClusterState, now: int,
               collected_at: Optional[int] = None
               ) -> tuple[ClusterState, TelemetryHealth]:
        cfg = self.config
        collected = int(cluster.collected_at if collected_at is None
                        else collected_at)
        staleness = max(0, int(now) - collected)
        p = cluster.problem
        demand = np.asarray(p.demand, np.float32)
        tasks = np.asarray(p.tasks, np.float32)
        live = np.asarray(p.valid, bool)
        n_live = max(1, int(live.sum()))

        q_demand = self._quarantine(demand, self._lkg_demand, live)
        q_tasks = self._quarantine(tasks, self._lkg_tasks, live)

        stale_score = self._staleness_score(staleness)

        def plaus_score(quarantined: int) -> float:
            frac = quarantined / n_live
            return float(max(0.0, 1.0 - frac / cfg.quarantine_blind_frac)
                         if cfg.quarantine_blind_frac > 0 else float(frac == 0))

        health = TelemetryHealth(now=int(now), collected_at=collected)
        health.signals["demand"] = SignalHealth(
            "demand", staleness, int(q_demand.sum()), n_live,
            round(stale_score * plaus_score(int(q_demand.sum())), 4))
        health.signals["tasks"] = SignalHealth(
            "tasks", staleness, int(q_tasks.sum()), n_live,
            round(stale_score * plaus_score(int(q_tasks.sum())), 4))
        health.signals.update(self._external)

        dirty = bool(q_demand.any() or q_tasks.any())
        inflation = self._inflation(staleness)
        inflate = staleness > cfg.stale_after and inflation > 1.0
        if dirty or inflate:
            demand = demand.copy()
            tasks = tasks.copy()
            if self._lkg_demand is not None:
                demand[q_demand] = self._lkg_demand[q_demand]
            else:  # no history yet: zero the implausible rows (conservative)
                demand[q_demand] = 0.0
            if self._lkg_tasks is not None:
                tasks[q_tasks] = self._lkg_tasks[q_tasks]
            else:
                tasks[q_tasks] = 0.0
            if inflate:
                # Old data plans conservatively: every live app's demand is
                # widened by the uncertainty factor, so headroom decisions
                # made blind over-provision instead of over-committing.
                demand = demand * np.where(live, inflation, 1.0)[:, None]
            sanitized = dataclasses.replace(
                cluster,
                problem=dataclasses.replace(
                    p, demand=jnp.asarray(demand.astype(np.float32)),
                    tasks=jnp.asarray(tasks.astype(np.float32))))
        else:
            sanitized = cluster  # fresh + plausible: identity (parity-pinned)

        # Last-known-good only advances on *fresh* collections — a frozen
        # cluster re-ingested during a blackout must not launder its own
        # stale values into the baseline (staleness == 0 means the caller
        # vouches this is a new collection).
        if staleness == 0:
            good_d = demand.copy() if dirty else np.array(demand, copy=True)
            good_t = tasks.copy() if dirty else np.array(tasks, copy=True)
            self._lkg_demand = good_d
            self._lkg_tasks = good_t
        self.last_health = health
        return sanitized, health


# ---------------------------------------------------------------------------
# per-level circuit breakers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Trip/recovery policy for one scheduler level's breaker.

    ``fail_threshold`` consecutive failing cooperation passes (an
    exception from any hook, or a vet exceeding ``level_timeout_s``) trip
    the breaker; ``reject_all_threshold`` consecutive passes in which the
    level rejected every candidate it saw trip it too (a level vetoing
    everything has effectively failed even if it answers politely).  An
    OPEN breaker bypasses the level for ``cooldown_passes`` passes, then
    runs one HALF_OPEN probe pass: clean closes it, failing re-opens with
    the cooldown doubled up to ``max_cooldown``.  ``level_timeout_s`` is
    None by default — wall-clock vet budgets are machine-dependent, so the
    deterministic sim leaves them off.
    """

    fail_threshold: int = 3
    reject_all_threshold: int = 3
    cooldown_passes: int = 2
    backoff_factor: float = 2.0
    max_cooldown: int = 16
    level_timeout_s: Optional[float] = None


@dataclasses.dataclass
class CircuitBreaker:
    """One level's breaker.  Driven by the cooperation bus via
    ``begin_pass`` / ``note_*`` / ``end_pass``; persists across passes on
    the controller-owned ``BreakerBoard``."""

    name: str
    config: BreakerConfig = dataclasses.field(default_factory=BreakerConfig)
    state: str = CLOSED
    fail_streak: int = 0
    reject_all_streak: int = 0
    cooldown_left: int = 0
    cooldown: int = 0
    trips: int = 0
    probes: int = 0
    failures: int = 0
    # per-pass scratch
    _pass_failed: bool = dataclasses.field(default=False, repr=False)
    _pass_vetted: int = dataclasses.field(default=0, repr=False)
    _pass_rejected_all: bool = dataclasses.field(default=True, repr=False)

    def begin_pass(self) -> str:
        """Advance the breaker clock one cooperation pass; returns the
        effective state for this pass (OPEN = bypass the level)."""
        self._pass_failed = False
        self._pass_vetted = 0
        self._pass_rejected_all = True
        if self.state == OPEN:
            self.cooldown_left -= 1
            if self.cooldown_left <= 0:
                self.state = HALF_OPEN
                self.probes += 1
        return self.state

    @property
    def bypassed(self) -> bool:
        return self.state == OPEN

    def note_failure(self) -> None:
        """An exception or vet-budget overrun inside this pass."""
        self._pass_failed = True
        self.failures += 1

    def note_vet(self, candidates: int, rejected: int) -> None:
        if candidates <= 0:
            return
        self._pass_vetted += candidates
        if rejected < candidates:
            self._pass_rejected_all = False

    def _trip(self) -> None:
        self.state = OPEN
        self.trips += 1
        base = self.config.cooldown_passes
        self.cooldown = (base if self.cooldown == 0 else
                         min(self.config.max_cooldown,
                             int(round(self.cooldown
                                       * self.config.backoff_factor))))
        self.cooldown_left = self.cooldown

    def end_pass(self) -> None:
        if self.state == OPEN:
            return
        rejected_all = self._pass_failed or (self._pass_vetted > 0
                                             and self._pass_rejected_all)
        if self.state == HALF_OPEN:
            if self._pass_failed or (self._pass_vetted > 0
                                     and self._pass_rejected_all):
                self._trip()          # probe failed: re-open, backoff doubles
            else:
                self.state = CLOSED   # clean probe: back in the stack
                self.fail_streak = 0
                self.reject_all_streak = 0
                self.cooldown = 0
            return
        # CLOSED bookkeeping
        self.fail_streak = self.fail_streak + 1 if self._pass_failed else 0
        if self._pass_vetted > 0:
            self.reject_all_streak = (self.reject_all_streak + 1
                                      if rejected_all else 0)
        if (self.fail_streak >= self.config.fail_threshold
                or self.reject_all_streak >= self.config.reject_all_threshold):
            self._trip()

    def snapshot(self) -> dict:
        return {"state": self.state, "trips": self.trips,
                "probes": self.probes, "failures": self.failures,
                "fail_streak": self.fail_streak,
                "reject_all_streak": self.reject_all_streak,
                "cooldown_left": max(0, self.cooldown_left)}


class BreakerBoard:
    """Per-level breakers keyed by level name, plus the fallback-premask
    cache an OPEN level is bypassed with.  Owned by the controller (state
    persists across ticks); handed to the bus via ``CoopConfig.breakers``.
    """

    def __init__(self, config: BreakerConfig = BreakerConfig()):
        self.config = config
        self.breakers: dict[str, CircuitBreaker] = {}
        self._premask_cache: dict[str, np.ndarray] = {}

    def breaker(self, name: str) -> CircuitBreaker:
        if name not in self.breakers:
            self.breakers[name] = CircuitBreaker(name, self.config)
        return self.breakers[name]

    def cache_premask(self, name: str, premask) -> None:
        if premask is not None:
            self._premask_cache[name] = np.asarray(premask, bool)

    def cached_premask(self, name: str) -> Optional[np.ndarray]:
        return self._premask_cache.get(name)

    @property
    def open_levels(self) -> list[str]:
        return [n for n, b in self.breakers.items() if b.state == OPEN]

    @property
    def trips(self) -> int:
        return sum(b.trips for b in self.breakers.values())

    def health_factor(self) -> float:
        """[0, 1] contribution to the controller's composite health score:
        1.0 with every breaker closed, degrading with the open fraction
        (floored — an open breaker means *degraded*, not dead: the level's
        fallback premask still constrains)."""
        if not self.breakers:
            return 1.0
        n_open = sum(1 for b in self.breakers.values() if b.state != CLOSED)
        return max(0.3, 1.0 - 0.5 * n_open / len(self.breakers))

    def snapshot(self) -> dict:
        return {name: b.snapshot() for name, b in self.breakers.items()}
