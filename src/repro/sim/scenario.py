"""Scenario library: named, declarative fleet trajectories.

A ``Scenario`` is a workload configuration plus a list of timed events
(``sim.events``) over a fixed tick horizon.  The registry holds the five
canonical trajectories the balancing controller is scored on
(``benchmarks/sim_scenarios.py`` -> ``BENCH_sim.json``):

  * ``steady_diurnal`` — day/night sinusoid + burst noise, no surprises;
    the controller should mostly *hold* balance at low movement cost,
  * ``flash_crowd``    — heavy-tailed demand spikes on a random app subset
    (plus a low ambient ignition rate) that decay back over ~a dozen ticks,
  * ``tier_drain``     — maintenance: one tier's capacity staircases to ~0
    and back; the controller must evacuate ahead of the ramp and refill
    after (Madsen et al.'s live-reconfiguration cost, arXiv 1602.03770),
  * ``region_outage``  — a region's hosts vanish: overlapping tiers lose
    capacity share and SLO eligibility and the region goes latency-dark,
    stressing the §3.4 cooperation path (premask + avoid feedback),
  * ``churn_heavy``    — app arrivals/retirements churn the fleet over a
    1.5x standby pool; shapes stay fixed (valid-mask padding), so the
    whole trajectory reuses one compiled solver per pow-2 bucket.

Builders take (num_apps, ticks, seed) so benchmarks can run the same
scenario at smoke and fleet scale; event times scale with the horizon.

Adding a scenario:

    @scenario("my_case", "one-line description")
    def _my_case(num_apps, ticks, seed):
        return Scenario(..., events=(CapacityScale(at=ticks // 3, ...),))

and it is immediately runnable via ``sim.harness.run_scenario`` /
``examples/simulate_fleet.py --scenario my_case``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.sim.events import (CapacityScale, ChurnRate, FlashCrowd,
                              JitterStorm, LinkDegrade, LinkRestore,
                              RegionOutage, RegionRestore, ShardSkew,
                              SolverBrownout, TelemetryBlackout, TimedEvent)
from repro.sim.workload import WorkloadConfig


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    ticks: int
    num_apps: int                  # live apps at t=0
    workload: WorkloadConfig
    events: tuple[TimedEvent, ...] = ()
    pool_frac: float = 1.0         # standby pool: Nmax = num_apps * pool_frac
    arrival_rate: float = 0.0      # expected arrivals per tick at t=0
    retire_rate: float = 0.0       # per-app retirement prob per tick at t=0
    # Trajectory-level movement (downtime) budget in core.planner.move_costs
    # units — the mean live app costs 1.0, so a budget of k buys ~k average
    # moves over the whole run.  None leaves movement priced but uncapped.
    move_budget: float | None = None
    # Scheduler-level stack for the controller's cooperation bus (names in
    # the ``core.levels`` registry, e.g. ("region", "host", "shard")).
    # None keeps the default region+host stack.
    levels: tuple[str, ...] | None = None
    # t=0 utilization as a multiple of the Fig. 3 calibration.  Dynamic
    # scenarios need headroom the one-shot experiment didn't: at the Fig. 3
    # levels the *perfectly balanced* cluster already sits at ~0.57 mean
    # utilization, so any diurnal peak pushes every tier over the 0.70
    # ideal line no matter what the controller does.  0.75 leaves the
    # balanced state under ideal through normal swings — violation ticks
    # then measure imbalance, not global overload.
    util_scale: float = 0.75
    # Chaos scenario: contains control-plane fault windows (the harness
    # defaults the controller to the fault-tolerant CHAOS_CONTROLLER and
    # routes telemetry through the observed channel).  ``strip_chaos``
    # clears this on the oracle twin.
    chaos: bool = False
    # Overload scenario: offered demand exceeds fleet capacity somewhere in
    # the trajectory.  The harness scores delivered utility against the
    # fractional-knapsack oracle and runs the admission/shedding machinery
    # (``run_overload_pair``: utility policy vs the binary-SLO baseline).
    overload: bool = False
    # Sharded fleet solver (repro.shard): route the controller's solves
    # through an S-shard partitioned batched pass with coordinator-granted
    # boundary migrations.  None keeps the global Sptlb path.
    shards: int | None = None
    # Network-degraded scenario: contains link events the static latency
    # constant cannot see.  The harness arms the measurement plane (sketch
    # bank + per-tick prober) and ``run_netlat_pair`` scores the measured
    # netlat+host stack against the static-budget twin.
    netlat: bool = False
    seed: int = 0

    @property
    def max_apps(self) -> int:
        return max(self.num_apps, int(round(self.num_apps * self.pool_frac)))

    @property
    def declared_events(self) -> tuple:
        """The advisory channel: ``core.planner.Advisory`` records for every
        announced maintenance event (drain staircases, outage windows).
        Surprise events (flash crowds, churn re-rates) never declare."""
        return tuple(adv for adv in (e.declare() for e in self.events)
                     if adv is not None)


_REGISTRY: dict[str, tuple[str, Callable[..., Scenario]]] = {}


def scenario(name: str, description: str):
    def wrap(builder):
        _REGISTRY[name] = (description, builder)
        return builder
    return wrap


def list_scenarios() -> dict[str, str]:
    """name -> one-line description, in registration order."""
    return {name: desc for name, (desc, _) in _REGISTRY.items()}


def get_scenario(name: str, *, num_apps: int = 400, ticks: int = 160,
                 seed: int = 0) -> Scenario:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown scenario {name!r}; have {sorted(_REGISTRY)}")
    desc, builder = _REGISTRY[name]
    sc = builder(num_apps, ticks, seed)
    return dataclasses.replace(sc, name=name, description=desc)


def _ramp(tier: int, start: int, end: int, lo: float, hi: float,
          steps: int = 6) -> list[CapacityScale]:
    """A capacity staircase from ``lo`` to ``hi`` over [start, end)."""
    steps = max(1, min(steps, end - start))
    out = []
    for i in range(steps):
        frac = (i + 1) / steps
        out.append(CapacityScale(
            at=start + round(i * (end - start) / steps),
            tier=tier, scale=lo + frac * (hi - lo)))
    return out


@scenario("steady_diurnal", "day/night sinusoid + burst noise, no events")
def _steady_diurnal(num_apps: int, ticks: int, seed: int) -> Scenario:
    return Scenario(
        name="steady_diurnal", description="", ticks=ticks,
        num_apps=num_apps, seed=seed,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.35, burst_sigma=0.12))


@scenario("flash_crowd", "heavy-tailed demand spikes that decay over ticks")
def _flash_crowd(num_apps: int, ticks: int, seed: int) -> Scenario:
    return Scenario(
        name="flash_crowd", description="", ticks=ticks,
        num_apps=num_apps, seed=seed,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.20, burst_sigma=0.12,
                                flash_prob=0.0015, flash_mag=5.0,
                                flash_decay=0.88),
        events=(FlashCrowd(at=ticks // 4, frac=0.08, magnitude=6.0),
                FlashCrowd(at=(5 * ticks) // 8, frac=0.05, magnitude=8.0)))


@scenario("tier_drain", "maintenance: a tier's capacity ramps to ~0 and back")
def _tier_drain(num_apps: int, ticks: int, seed: int) -> Scenario:
    # Drain the paper's hot tier (tier 3, index 2): the hardest case — it
    # starts over ideal, so the evacuation fights the initial imbalance.
    t0, t1 = ticks // 5, (2 * ticks) // 5
    t2, t3 = (3 * ticks) // 5, (4 * ticks) // 5
    events = (_ramp(2, t0, t1, 1.0, 0.05)       # drain staircase
              + _ramp(2, t2, t3, 0.05, 1.0))    # restore staircase
    return Scenario(
        name="tier_drain", description="", ticks=ticks,
        num_apps=num_apps, seed=seed,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.15, burst_sigma=0.10),
        events=tuple(events),
        # Maintenance is the scenario where movement is priced for real:
        # the budget covers evacuating the hot tier and refilling it after
        # the restore (~2 round trips of its population), with headroom for
        # the diurnal rebalancing a run this long needs anyway.
        move_budget=2.0 * num_apps)


@scenario("region_outage", "a region goes dark: capacity + SLO eligibility "
                           "loss on overlapping tiers (stresses §3.4)")
def _region_outage(num_apps: int, ticks: int, seed: int) -> Scenario:
    return Scenario(
        name="region_outage", description="", ticks=ticks,
        num_apps=num_apps, seed=seed,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.15, burst_sigma=0.10),
        events=(RegionOutage(at=ticks // 4, region=0),
                RegionRestore(at=(3 * ticks) // 4, region=0)))


@scenario("shard_skew", "data-shard hotspot: demand piles onto apps whose "
                        "shards sit in one region (runs the three-level "
                        "region+host+shard stack)")
def _shard_skew(num_apps: int, ticks: int, seed: int) -> Scenario:
    # The repair moves for a shard hotspot are the constrained kind: the
    # spiking apps' state lives in the hot region, so the shard locality
    # level only accepts destinations that still hold their shard mass.
    # Two staggered hotspots on different regions force the controller to
    # rebalance *within* each shard neighbourhood rather than spraying the
    # load fleet-wide.
    return Scenario(
        name="shard_skew", description="", ticks=ticks,
        num_apps=num_apps, seed=seed,
        levels=("region", "host", "shard"),
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.20, burst_sigma=0.12,
                                flash_decay=0.88),
        events=(ShardSkew(at=ticks // 4, region=2, magnitude=5.0),
                ShardSkew(at=(5 * ticks) // 8, region=4, magnitude=6.0)))


# ---------------------------------------------------------------------------
# chaos family: control-plane fault windows (PR 6 degraded-mode acceptance)
# ---------------------------------------------------------------------------

def _chaos_window(ticks: int) -> tuple[int, int]:
    """(start, duration) for a fault window: late enough that the
    controller has settled, long enough that telemetry staleness crosses
    the blind threshold (HealthConfig.blind_after=5), early enough that
    the post-fault tail covers the hysteretic recovery to NORMAL
    (~recover_ticks per mode step)."""
    return max(2, ticks // 4), max(5, ticks // 5)


@scenario("telemetry_blackout", "collection stops mid-run while a surprise "
                                "flash crowd hits: the controller must "
                                "degrade to SAFE instead of balancing blind")
def _telemetry_blackout(num_apps: int, ticks: int, seed: int) -> Scenario:
    t0, dur = _chaos_window(ticks)
    return Scenario(
        name="telemetry_blackout", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, chaos=True,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.20, burst_sigma=0.12,
                                flash_decay=0.88),
        events=(
            # A visible crowd before the lights go out...
            FlashCrowd(at=max(0, t0 - 2), frac=0.06, magnitude=5.0),
            TelemetryBlackout(at=t0, ticks=dur),
            # ...and an invisible one while they are out: the truth drifts
            # away from the frozen snapshot the controller keeps re-reading.
            FlashCrowd(at=t0 + 2, frac=0.06, magnitude=6.0),
        ))


@scenario("solver_brownout", "the solver fleet loses its compute budget "
                             "during a flash crowd: cooperation passes time "
                             "out and solver distress drives the mode down")
def _solver_brownout(num_apps: int, ticks: int, seed: int) -> Scenario:
    t0, dur = _chaos_window(ticks)
    return Scenario(
        name="solver_brownout", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, chaos=True,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.20, burst_sigma=0.12,
                                flash_decay=0.90),
        events=(
            # The crowd lands just before the brownout so the controller
            # keeps being *asked* to solve while it cannot.
            FlashCrowd(at=max(0, t0 - 1), frac=0.10, magnitude=7.0),
            SolverBrownout(at=t0, ticks=dur),
            FlashCrowd(at=t0 + dur // 2, frac=0.05, magnitude=6.0),
        ))


@scenario("cascading_outage", "blackout, then a region dies unseen, then a "
                              "flash crowd on recovery: the worst day the "
                              "degraded-mode control plane is designed for")
def _cascading_outage(num_apps: int, ticks: int, seed: int) -> Scenario:
    t0, dur = _chaos_window(ticks)
    return Scenario(
        name="cascading_outage", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, chaos=True,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.15, burst_sigma=0.10,
                                flash_decay=0.88),
        events=(
            TelemetryBlackout(at=t0, ticks=dur),
            # The outage strikes while the controller is blind (and, by
            # then, in SAFE holding still — the frozen snapshot shows no
            # strands, so it must not guess).  Unannounced: a surprise has
            # no advisory.
            RegionOutage(at=t0 + dur // 2, region=0, announced=False),
            # Telemetry returns at t0+dur: the controller finally sees the
            # stranded apps and evacuates them under SAFE/CONSERVATIVE
            # movement restrictions while its health score recovers...
            FlashCrowd(at=t0 + dur + 2, frac=0.05, magnitude=6.0),
            # ...and the region comes back late in the run.
            RegionRestore(at=max(t0 + dur + 3, (3 * ticks) // 4),
                          announced=False),
        ))


# ---------------------------------------------------------------------------
# overload family: offered demand exceeds capacity (PR 7 admission/shedding)
# ---------------------------------------------------------------------------

@scenario("overload_surge", "sustained arrival surge past fleet capacity: "
                            "admission control + utility shedding decide "
                            "who rides the saturated tiers")
def _overload_surge(num_apps: int, ticks: int, seed: int) -> Scenario:
    # A 2x standby pool filling at ~8%/tick: offered demand roughly doubles
    # over the first half of the run, far past what the t=0-calibrated
    # capacity serves.  The surge abates late, so hysteretic re-admission
    # gets a recovery window to prove itself on.
    return Scenario(
        name="overload_surge", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, overload=True,
        pool_frac=2.0, arrival_rate=max(1.0, 0.01 * num_apps),
        retire_rate=0.004, util_scale=1.0,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.15, burst_sigma=0.10),
        events=(ChurnRate(at=ticks // 6,
                          arrival_rate=max(6.0, 0.12 * num_apps),
                          retire_rate=0.0005),
                ChurnRate(at=(3 * ticks) // 4,
                          arrival_rate=0.0, retire_rate=0.03)),
        move_budget=2.0 * num_apps)


@scenario("overload_flash", "utility-skewed flash crowd: low-criticality "
                            "apps spike past capacity — shedding them is "
                            "cheap in utility, stranding is not")
def _overload_flash(num_apps: int, ticks: int, seed: int) -> Scenario:
    return Scenario(
        name="overload_flash", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, overload=True, util_scale=1.0,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.15, burst_sigma=0.10,
                                flash_decay=0.93),
        events=(FlashCrowd(at=ticks // 4, frac=0.45, magnitude=6.0,
                           crit_below=0.35),
                FlashCrowd(at=(5 * ticks) // 8, frac=0.30, magnitude=8.0,
                           crit_below=0.35)),
        move_budget=2.0 * num_apps)


@scenario("overload_capacity_loss", "capacity loss during a surge while "
                                    "telemetry blacks out: overload "
                                    "composing with control-plane chaos")
def _overload_capacity_loss(num_apps: int, ticks: int, seed: int) -> Scenario:
    t0 = ticks // 3
    dur = max(4, ticks // 6)
    return Scenario(
        name="overload_capacity_loss", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, overload=True, chaos=True,
        pool_frac=1.5, arrival_rate=max(1.0, 0.01 * num_apps),
        retire_rate=0.003, util_scale=0.95,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.15, burst_sigma=0.10,
                                flash_decay=0.90),
        events=(ChurnRate(at=ticks // 8,
                          arrival_rate=max(3.0, 0.05 * num_apps),
                          retire_rate=0.001),
                # The fleet shrinks mid-surge — unannounced — and the
                # controller loses its telemetry right after: shedding has
                # to run on the sanitized last-known-good view.
                CapacityScale(at=t0, tier=0, scale=0.45, announced=False),
                CapacityScale(at=t0 + 1, tier=3, scale=0.55,
                              announced=False),
                TelemetryBlackout(at=t0 + 2, ticks=dur),
                FlashCrowd(at=t0 + dur + 2, frac=0.15, magnitude=5.0,
                           crit_below=0.5),
                CapacityScale(at=(3 * ticks) // 4, tier=0, scale=1.0,
                              announced=False),
                CapacityScale(at=(3 * ticks) // 4, tier=3, scale=1.0,
                              announced=False)),
        move_budget=2.0 * num_apps)


# ---------------------------------------------------------------------------
# network_degraded family: link weather the static 36 ms constant can't see
# (PR 10 measured-latency acceptance)
# ---------------------------------------------------------------------------

def _netlat_workload(ticks: int) -> WorkloadConfig:
    return WorkloadConfig(period=max(16, ticks // 2),
                          diurnal_amp=0.20, burst_sigma=0.10)


@scenario("network_degraded_slow_links", "adjacent-region links degrade to "
          "~1.8x (still under the 36 ms constant): only measured per-pair "
          "budgets see it and steer placements off the slow paths")
def _network_slow_links(num_apps: int, ticks: int, seed: int) -> Scenario:
    # One-hop links sit at ~19 ms as built; 1.8x lands them near ~34 ms —
    # inside the static budget (the region level stays blind) but far
    # outside a calibrated ~1.25 x baseline budget.  Degrading the links
    # around region 1 makes every tier arc through it a measured no-go.
    t0, t1 = ticks // 4, (3 * ticks) // 4
    return Scenario(
        name="network_degraded_slow_links", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, netlat=True,
        workload=_netlat_workload(ticks),
        events=(LinkDegrade(at=t0, src=0, dst=1, factor=1.8),
                LinkDegrade(at=t0, src=1, dst=2, factor=1.8),
                LinkDegrade(at=t0 + 2, src=2, dst=3, factor=1.7),
                LinkRestore(at=t1, src=0, dst=1),
                LinkRestore(at=t1, src=1, dst=2),
                LinkRestore(at=t1, src=2, dst=3)))


@scenario("network_degraded_asymmetric", "one direction of a link degrades "
          "(routing detour): the per-pair sketch matrix is direction-aware "
          "where the symmetric constant never was")
def _network_asymmetric(num_apps: int, ticks: int, seed: int) -> Scenario:
    t0, t1 = ticks // 4, (3 * ticks) // 4
    return Scenario(
        name="network_degraded_asymmetric", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, netlat=True,
        workload=_netlat_workload(ticks),
        events=(LinkDegrade(at=t0, src=0, dst=1, factor=1.9,
                            symmetric=False),
                LinkDegrade(at=t0 + 1, src=3, dst=4, factor=1.8,
                            symmetric=False),
                LinkRestore(at=t1, src=0, dst=1, symmetric=False),
                LinkRestore(at=t1, src=3, dst=4, symmetric=False)))


@scenario("network_degraded_jitter", "a fleet-wide jitter storm fattens "
          "every pair's tail: live p99 estimates breach calibrated budgets "
          "while the mean barely moves")
def _network_jitter(num_apps: int, ticks: int, seed: int) -> Scenario:
    t0 = ticks // 4
    dur = max(6, ticks // 3)
    return Scenario(
        name="network_degraded_jitter", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, netlat=True,
        workload=_netlat_workload(ticks),
        events=(JitterStorm(at=t0, ticks=dur, sigma=0.45, seed=seed + 5),
                # A crowd mid-storm makes the controller *want* to move —
                # the measured stack must route its repairs around the
                # fattened tails instead of through them.
                FlashCrowd(at=t0 + dur // 3, frac=0.08, magnitude=5.0)))


@scenario("churn_heavy", "app arrivals/retirements over a standby pool "
                         "(valid-mask padding keeps shapes static)")
def _churn_heavy(num_apps: int, ticks: int, seed: int) -> Scenario:
    return Scenario(
        name="churn_heavy", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, pool_frac=1.5,
        arrival_rate=max(1.0, 0.01 * num_apps),
        retire_rate=0.008,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.25, burst_sigma=0.12),
        events=(ChurnRate(at=ticks // 2,
                          arrival_rate=max(2.0, 0.02 * num_apps)),))


@scenario("fleet_scale", "sharded solver path: the controller rebalances "
                         "through the S-shard partitioned batched pass")
def _fleet_scale(num_apps: int, ticks: int, seed: int) -> Scenario:
    """The ``repro.shard`` subsystem under trajectory load: every triggered
    solve partitions the fleet, solves all shards under one vmap, merges,
    and lets the FleetCoordinator grant priced boundary migrations.  The
    workload mixes a diurnal swing with a mid-run surprise crowd so shard
    saturation actually occurs; scorecard semantics are identical to the
    global path (the BalanceDecision contract is shared)."""
    return Scenario(
        name="fleet_scale", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, shards=2,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.25, burst_sigma=0.10),
        events=(FlashCrowd(at=ticks // 3, frac=0.10, magnitude=4.0),),
        move_budget=2.0 * num_apps)


@scenario("fleet_scale_surge", "declared flash crowd over the sharded path: "
                               "the demand advisory phases headroom in ahead")
def _fleet_scale_surge(num_apps: int, ticks: int, seed: int) -> Scenario:
    """Demand-side anticipation end-to-end: the crowd is *announced*
    (``FlashCrowd(announced=True)`` -> SHED advisory with an offered-demand
    factor > 1), so the planner tightens capacity targets as the spike
    approaches and the sharded solver packs headroom in before it lands —
    the demand-side mirror of tier_drain's declared evacuation."""
    return Scenario(
        name="fleet_scale_surge", description="", ticks=ticks,
        num_apps=num_apps, seed=seed, shards=2,
        workload=WorkloadConfig(period=max(16, ticks // 2),
                                diurnal_amp=0.20, burst_sigma=0.08),
        events=(FlashCrowd(at=ticks // 3, frac=0.20, magnitude=4.0,
                           announced=True),
                FlashCrowd(at=(2 * ticks) // 3, frac=0.10, magnitude=3.0,
                           announced=True)),
        move_budget=2.0 * num_apps)
