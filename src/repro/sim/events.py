"""Timed fleet events: declarative ClusterState rewrites for scenarios.

Every event is a frozen dataclass with an ``at`` tick and an ``apply`` that
rewrites the running ``FleetState`` — capacity scales, region outages, flash
crowds, churn re-rates.  Events never mutate arrays in place: cluster
changes go through ``dataclasses.replace`` (which resets the memoized
hierarchy precomputes on ``ClusterState._cache``, the standing invalidation
contract), and workload changes go through the traced-state helpers in
``sim.workload`` (no retrace).

``FleetState.refresh`` is the single place the *effective* cluster is
recomputed from the base (as-built) arrays plus the standing knobs
(per-tier capacity scale, down regions).  Events only edit knobs and call
``refresh`` — so stacked events compose and restores are exact.

Chaos events (``ControlPlaneFault`` subclasses) are different in kind:
they fault the *control plane* — the telemetry channel, the solver's
wall-clock, a scheduler level — never the cluster itself.  They set the
fleet's chaos-window knobs, which the harness reads every tick to shape
what the controller *observes* (frozen or corrupted telemetry) and how it
*solves* (zeroed solver budget, a faulty level wrapper).  The true
cluster, scored by the SLO accountant, is untouched; and a fault in your
own control plane does not announce itself, so none of them declare an
advisory.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import planner as P
from repro.core.telemetry import ClusterState
from repro.sim import workload as W

# A down region's latency: far beyond any plausible budget, but finite so
# solver arithmetic stays NaN-free.
OUTAGE_LATENCY_MS = 1e6
# Floor on the per-tier capacity scale: utilization fractions divide by
# capacity, so a drained tier keeps a sliver instead of reaching exactly 0.
MIN_TIER_SCALE = 0.02


@dataclasses.dataclass
class FleetState:
    """The harness's mutable world: effective cluster + workload + knobs."""

    cluster: ClusterState
    wl: W.WorkloadState
    wl_cfg: W.WorkloadConfig
    # As-built arrays the knobs are applied against:
    base_capacity: np.ndarray      # f32[T, R]
    base_task_limit: np.ndarray    # f32[T]
    base_hosts: np.ndarray         # i32[T]
    base_slo_allowed: np.ndarray   # bool[T, S]
    base_latency: np.ndarray       # f32[G, G]
    # Standing knobs (events edit these, then call refresh):
    tier_scale: np.ndarray         # f32[T] capacity scale per tier
    down_regions: set = dataclasses.field(default_factory=set)
    # Network knobs (``LinkDegrade``/``JitterStorm`` edit these): a standing
    # per-pair latency multiplier, and a jitter-storm window during which
    # the effective matrix additionally wobbles per tick.  ``link_factor``
    # stays None until a link event first fires (the common case pays
    # nothing).  Jitter is a pure function of (jitter_seed, tick) so a
    # trajectory and its oracle twin see bit-identical latency.
    link_factor: np.ndarray | None = None  # f32[G, G] multiplier
    jitter_until: int = 0
    jitter_sigma: float = 0.0
    jitter_seed: int = 0
    tick: int = 0                  # harness-advanced; jitter reads it
    # Advisory channel (``core.planner.Advisory``): the maintenance events
    # this trajectory has *declared* in advance.  The harness hands it to
    # the controller's planner; surprises (flash crowds, churn) never
    # appear here.
    declared_events: tuple = ()
    rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(0))
    # Chaos windows (``ControlPlaneFault`` events set these; the harness
    # reads them each tick).  ``*_until`` are exclusive end ticks: the
    # fault is active while ``tick < until``.
    blackout_until: int = 0        # observed telemetry frozen
    corrupt_until: int = 0         # observed demand rows corrupted
    corrupt_frac: float = 0.0
    corrupt_magnitude: float = 0.0
    brownout_until: int = 0        # controller solver wall-clock zeroed
    level_fault_until: int = 0     # a scheduler level wrapped faulty
    level_fault_level: str = ""
    level_fault_mode: str = "raise"
    # Corruption draws its own generator: the main ``rng`` feeds workload
    # events (flash-crowd target choice) that must stay identical between
    # the chaos run and its fault-free oracle twin, so chaos must never
    # advance it.
    chaos_rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(1))

    def refresh(self) -> None:
        """Recompute the effective cluster from base arrays + knobs."""
        c = self.cluster
        G = self.base_latency.shape[0]
        scale = np.maximum(self.tier_scale, MIN_TIER_SCALE)
        slo_allowed = self.base_slo_allowed.copy()
        lat = self.base_latency.copy()
        if self.link_factor is not None:
            lat = lat * self.link_factor
        if self.jitter_active(self.tick):
            # Per-tick wobble, only ever slowing links (a storm never makes
            # a link faster than its standing latency).
            jrng = np.random.default_rng([self.jitter_seed, self.tick])
            lat = lat * np.maximum(
                1.0, jrng.lognormal(0.0, self.jitter_sigma, size=lat.shape))
        if self.down_regions:
            down = np.zeros(G, bool)
            down[list(self.down_regions)] = True
            affected = (c.tier_regions & down).any(axis=1)
            # An affected tier loses the capacity share its down regions
            # carried (hosts are spread over the tier's regions)...
            total = np.maximum(1, c.tier_regions.sum(axis=1))
            live_share = (c.tier_regions & ~down).sum(axis=1) / total
            scale = scale * np.where(affected, live_share, 1.0)
            scale = np.maximum(scale, MIN_TIER_SCALE)
            # ...and its SLO eligibility: placements there can no longer
            # honour the latency the SLO class promises (§3.4 — this is
            # what pushes work through the cooperation path).
            slo_allowed[affected] = False
            # The region itself becomes unreachable: the region scheduler's
            # worst-latency matrix sees OUTAGE_LATENCY_MS through it, so
            # every tier containing the region fails the latency budget.
            lat[down, :] = OUTAGE_LATENCY_MS
            lat[:, down] = OUTAGE_LATENCY_MS
        cap = (self.base_capacity * scale[:, None]).astype(np.float32)
        klim = (self.base_task_limit * scale).astype(np.float32)
        hosts = np.maximum(1, np.round(self.base_hosts * scale)).astype(np.int32)
        problem = dataclasses.replace(
            self.cluster.problem,
            capacity=jnp.asarray(cap),
            task_limit=jnp.asarray(klim),
            slo_allowed=jnp.asarray(slo_allowed))
        self.cluster = dataclasses.replace(
            self.cluster, problem=problem, hosts_per_tier=hosts,
            region_latency=lat.astype(np.float32))

    def jitter_active(self, tick: int) -> bool:
        return self.jitter_sigma > 0.0 and tick < self.jitter_until


@dataclasses.dataclass(frozen=True)
class TimedEvent:
    """Base: fires once when the harness reaches tick ``at``.

    Maintenance-class events (capacity scales, region outage windows) are
    scheduled in the real world, so they default to ``announced=True`` and
    publish themselves on the advisory channel via ``declare``; surprises
    (flash crowds, churn re-rates) return None and are never declared.
    """

    at: int

    def apply(self, fleet: FleetState) -> None:  # pragma: no cover
        raise NotImplementedError

    def declare(self):
        """The ``core.planner.Advisory`` for this event, or None."""
        return None


@dataclasses.dataclass(frozen=True)
class CapacityScale(TimedEvent):
    """Set a tier's capacity scale relative to as-built (drains/restores).

    Maintenance drains are ramps: a scenario emits a staircase of these
    (tier_drain in ``sim.scenario``), each one a small step, so the
    controller sees a moving target rather than a cliff.
    """

    tier: int = 0
    scale: float = 1.0
    announced: bool = True

    def apply(self, fleet: FleetState) -> None:
        fleet.tier_scale[self.tier] = self.scale
        fleet.refresh()

    def declare(self):
        if not self.announced:
            return None
        return P.Advisory(at=self.at, kind=P.CAPACITY, tier=self.tier,
                          scale=self.scale)


@dataclasses.dataclass(frozen=True)
class RegionOutage(TimedEvent):
    """A region's hosts drop out: overlapping tiers lose the capacity share
    and the SLO eligibility, and the region becomes latency-unreachable."""

    region: int = 0
    announced: bool = True

    def apply(self, fleet: FleetState) -> None:
        fleet.down_regions.add(self.region)
        fleet.refresh()

    def declare(self):
        if not self.announced:
            return None
        return P.Advisory(at=self.at, kind=P.OUTAGE, region=self.region)


@dataclasses.dataclass(frozen=True)
class RegionRestore(TimedEvent):
    region: int = 0
    announced: bool = True

    def apply(self, fleet: FleetState) -> None:
        fleet.down_regions.discard(self.region)
        fleet.refresh()

    def declare(self):
        if not self.announced:
            return None
        return P.Advisory(at=self.at, kind=P.RESTORE, region=self.region)


@dataclasses.dataclass(frozen=True)
class FlashCrowd(TimedEvent):
    """Spike a random ``frac`` of the live apps to ``magnitude``x demand;
    the workload step decays them back geometrically.

    ``crit_below`` restricts the crowd to apps under that criticality — the
    utility-skewed overload case: the spike lands on low-utility demand, so
    a utility-aware controller can shed its way out while the binary-SLO
    baseline sees an undifferentiated overload.

    ``announced=True`` makes the crowd a *declared* demand event (a planned
    product launch, a scheduled broadcast): it publishes a SHED advisory
    whose ``scale`` is the fleet-wide offered-demand factor
    (``1 + frac * (magnitude - 1)``), and the planner phases capacity
    headroom in ahead of it the way maintenance phases capacity out.  The
    default stays False — surprise crowds never declare.
    """

    frac: float = 0.05
    magnitude: float = 6.0
    crit_below: float | None = None
    announced: bool = False

    def declare(self):
        if not self.announced:
            return None
        return P.Advisory(at=self.at, kind=P.SHED,
                          scale=1.0 + self.frac * (self.magnitude - 1.0))

    def apply(self, fleet: FleetState) -> None:
        live = np.asarray(fleet.wl.valid).copy()
        if self.crit_below is not None:
            crit = np.asarray(fleet.cluster.problem.criticality)
            live &= crit < self.crit_below
        live = np.where(live)[0]
        if live.size == 0:
            return
        k = max(1, int(round(self.frac * live.size)))
        ids = fleet.rng.choice(live, size=min(k, live.size), replace=False)
        fleet.wl = W.inject_flash_crowd(fleet.wl, ids, self.magnitude)


@dataclasses.dataclass(frozen=True)
class ShardSkew(TimedEvent):
    """A data-shard hotspot: demand spikes on the apps whose shard mass is
    anchored in one region (their state lives there, so the load cannot be
    shed by moving them far away — the shard locality level constrains the
    controller's repair moves).  Decays back like a flash crowd; data
    hotspots are surprises, so the event never declares an advisory."""

    region: int = 0
    magnitude: float = 5.0

    def apply(self, fleet: FleetState) -> None:
        live = np.asarray(fleet.wl.valid)
        ids = np.where(live & (fleet.cluster.app_region == self.region))[0]
        if ids.size:
            fleet.wl = W.inject_flash_crowd(fleet.wl, ids, self.magnitude)


@dataclasses.dataclass(frozen=True)
class ChurnRate(TimedEvent):
    """Re-rate arrivals/retirements (traced workload state — no retrace)."""

    arrival_rate: float | None = None
    retire_rate: float | None = None

    def apply(self, fleet: FleetState) -> None:
        fleet.wl = W.set_churn_rates(
            fleet.wl, arrival_rate=self.arrival_rate,
            retire_rate=self.retire_rate)


# ---------------------------------------------------------------------------
# network events (what the measured-latency control plane exists for)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkDegrade(TimedEvent):
    """A WAN link (region pair) degrades: the effective latency between
    ``src`` and ``dst`` becomes ``factor``x its as-built value (a routing
    detour, a congested peering point).  Network weather is a surprise —
    no advisory; only the measurement plane can see it."""

    src: int = 0
    dst: int = 1
    factor: float = 4.0
    symmetric: bool = True

    def apply(self, fleet: FleetState) -> None:
        if fleet.link_factor is None:
            fleet.link_factor = np.ones_like(fleet.base_latency)
        fleet.link_factor[self.src, self.dst] = self.factor
        if self.symmetric:
            fleet.link_factor[self.dst, self.src] = self.factor
        fleet.refresh()


@dataclasses.dataclass(frozen=True)
class LinkRestore(TimedEvent):
    """The degraded link heals: the pair's multiplier returns to 1."""

    src: int = 0
    dst: int = 1
    symmetric: bool = True

    def apply(self, fleet: FleetState) -> None:
        if fleet.link_factor is None:
            return
        fleet.link_factor[self.src, self.dst] = 1.0
        if self.symmetric:
            fleet.link_factor[self.dst, self.src] = 1.0
        fleet.refresh()


@dataclasses.dataclass(frozen=True)
class JitterStorm(TimedEvent):
    """``ticks`` ticks of fleet-wide latency jitter: every pair's effective
    latency wobbles per tick by a lognormal factor (floored at 1 — storms
    only slow links).  Deterministic per (seed, tick), so the trajectory
    and its oracle twin observe identical weather."""

    ticks: int = 6
    sigma: float = 0.35
    seed: int = 0

    @property
    def until(self) -> int:
        return self.at + self.ticks

    def apply(self, fleet: FleetState) -> None:
        fleet.jitter_until = max(fleet.jitter_until, self.until)
        fleet.jitter_sigma = self.sigma
        fleet.jitter_seed = self.seed
        fleet.refresh()


# ---------------------------------------------------------------------------
# control-plane chaos events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ControlPlaneFault(TimedEvent):
    """Base for chaos events: a fault window over the *control plane*.

    Sets fleet chaos knobs for ``ticks`` ticks starting at ``at``; the true
    cluster is never touched and no advisory is ever declared (``declare``
    stays None — surprises by construction).
    """

    ticks: int = 4

    @property
    def until(self) -> int:
        return self.at + self.ticks


@dataclasses.dataclass(frozen=True)
class TelemetryBlackout(ControlPlaneFault):
    """The collection pipeline stops: the controller keeps re-reading the
    last snapshot it got (with its original ``collected_at`` stamp), so
    observed staleness grows tick by tick while the true fleet drifts."""

    def apply(self, fleet: FleetState) -> None:
        fleet.blackout_until = max(fleet.blackout_until, self.until)


@dataclasses.dataclass(frozen=True)
class TelemetryCorruption(ControlPlaneFault):
    """A ``frac`` of live apps report garbage demand (``magnitude``x their
    real reading) each tick of the window — fresh-but-implausible
    telemetry, the case the monitor's quarantine exists for."""

    frac: float = 0.15
    magnitude: float = 50.0

    def apply(self, fleet: FleetState) -> None:
        fleet.corrupt_until = max(fleet.corrupt_until, self.until)
        fleet.corrupt_frac = self.frac
        fleet.corrupt_magnitude = self.magnitude


@dataclasses.dataclass(frozen=True)
class SolverBrownout(ControlPlaneFault):
    """The solver fleet loses its compute budget: the controller's
    wall-clock allowance drops to zero, so cooperation passes exit on
    timeout with whatever the first (minimal) solve produced."""

    def apply(self, fleet: FleetState) -> None:
        fleet.brownout_until = max(fleet.brownout_until, self.until)


@dataclasses.dataclass(frozen=True)
class LevelFault(ControlPlaneFault):
    """A scheduler level goes bad: every hook raises (``mode='raise'``) or
    its vet rejects every candidate (``mode='reject_all'``) — the two
    deterministic failure shapes the per-level circuit breakers trip on.
    Wall-clock hangs are deliberately not simulated (the sim must stay
    machine-independent); ``BreakerConfig.level_timeout_s`` covers those
    in production."""

    level: str = "host"
    mode: str = "raise"            # "raise" | "reject_all"

    def apply(self, fleet: FleetState) -> None:
        fleet.level_fault_until = max(fleet.level_fault_until, self.until)
        fleet.level_fault_level = self.level
        fleet.level_fault_mode = self.mode


class FaultyLevel:
    """Wraps a real ``SchedulerLevel`` in a deterministic failure mode.

    ``raise``: premask/vet/feedback raise (the bus's breaker mediator
    fails the pass closed — all candidates rejected, fallback premask).
    ``reject_all``: the level answers politely but vetoes every candidate
    (what ``BreakerConfig.reject_all_threshold`` exists for).
    """

    def __init__(self, inner, mode: str = "raise"):
        assert mode in ("raise", "reject_all"), mode
        self.inner = inner
        self.name = inner.name
        self.mode = mode

    def _fault(self, hook: str):
        raise RuntimeError(f"chaos: level {self.name!r} {hook} fault")

    def premask(self, problem):
        if self.mode == "raise":
            self._fault("premask")
        return self.inner.premask(problem)

    def vet(self, proposal):
        if self.mode == "raise":
            self._fault("vet")
        return np.asarray(proposal.candidates, np.int64)

    def feedback(self, state):
        if self.mode == "raise":
            self._fault("feedback")
        return None

    def relax(self, plan, cluster) -> None:
        self.inner.relax(plan, cluster)

    def counters(self) -> dict:
        return self.inner.counters()

    def device_time_s(self) -> float:
        return self.inner.device_time_s()


def faulty_hierarchy(level_names, fault_level: str, mode: str = "raise"):
    """A ``core.levels.Hierarchy`` with ``fault_level`` wrapped in
    ``FaultyLevel`` — what the harness swaps into the controller's
    ``hierarchy_override`` for the duration of a ``LevelFault`` window."""
    from repro.core.levels import DEFAULT_LEVELS, Hierarchy, level_factory

    names = tuple(level_names) if level_names else DEFAULT_LEVELS

    def wrap(name):
        factory = level_factory(name)
        if name != fault_level:
            return factory
        return lambda cluster: FaultyLevel(factory(cluster), mode)

    return Hierarchy(tuple(wrap(n) for n in names))


def events_at(events, tick: int):
    """The scenario's events firing at this tick, in declaration order."""
    return [e for e in events if e.at == tick]
