"""Timed fleet events: declarative ClusterState rewrites for scenarios.

Every event is a frozen dataclass with an ``at`` tick and an ``apply`` that
rewrites the running ``FleetState`` — capacity scales, region outages, flash
crowds, churn re-rates.  Events never mutate arrays in place: cluster
changes go through ``dataclasses.replace`` (which resets the memoized
hierarchy precomputes on ``ClusterState._cache``, the standing invalidation
contract), and workload changes go through the traced-state helpers in
``sim.workload`` (no retrace).

``FleetState.refresh`` is the single place the *effective* cluster is
recomputed from the base (as-built) arrays plus the standing knobs
(per-tier capacity scale, down regions).  Events only edit knobs and call
``refresh`` — so stacked events compose and restores are exact.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import planner as P
from repro.core.telemetry import ClusterState
from repro.sim import workload as W

# A down region's latency: far beyond any plausible budget, but finite so
# solver arithmetic stays NaN-free.
OUTAGE_LATENCY_MS = 1e6
# Floor on the per-tier capacity scale: utilization fractions divide by
# capacity, so a drained tier keeps a sliver instead of reaching exactly 0.
MIN_TIER_SCALE = 0.02


@dataclasses.dataclass
class FleetState:
    """The harness's mutable world: effective cluster + workload + knobs."""

    cluster: ClusterState
    wl: W.WorkloadState
    wl_cfg: W.WorkloadConfig
    # As-built arrays the knobs are applied against:
    base_capacity: np.ndarray      # f32[T, R]
    base_task_limit: np.ndarray    # f32[T]
    base_hosts: np.ndarray         # i32[T]
    base_slo_allowed: np.ndarray   # bool[T, S]
    base_latency: np.ndarray       # f32[G, G]
    # Standing knobs (events edit these, then call refresh):
    tier_scale: np.ndarray         # f32[T] capacity scale per tier
    down_regions: set = dataclasses.field(default_factory=set)
    # Advisory channel (``core.planner.Advisory``): the maintenance events
    # this trajectory has *declared* in advance.  The harness hands it to
    # the controller's planner; surprises (flash crowds, churn) never
    # appear here.
    declared_events: tuple = ()
    rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(0))

    def refresh(self) -> None:
        """Recompute the effective cluster from base arrays + knobs."""
        c = self.cluster
        G = self.base_latency.shape[0]
        scale = np.maximum(self.tier_scale, MIN_TIER_SCALE)
        slo_allowed = self.base_slo_allowed.copy()
        lat = self.base_latency.copy()
        if self.down_regions:
            down = np.zeros(G, bool)
            down[list(self.down_regions)] = True
            affected = (c.tier_regions & down).any(axis=1)
            # An affected tier loses the capacity share its down regions
            # carried (hosts are spread over the tier's regions)...
            total = np.maximum(1, c.tier_regions.sum(axis=1))
            live_share = (c.tier_regions & ~down).sum(axis=1) / total
            scale = scale * np.where(affected, live_share, 1.0)
            scale = np.maximum(scale, MIN_TIER_SCALE)
            # ...and its SLO eligibility: placements there can no longer
            # honour the latency the SLO class promises (§3.4 — this is
            # what pushes work through the cooperation path).
            slo_allowed[affected] = False
            # The region itself becomes unreachable: the region scheduler's
            # worst-latency matrix sees OUTAGE_LATENCY_MS through it, so
            # every tier containing the region fails the latency budget.
            lat[down, :] = OUTAGE_LATENCY_MS
            lat[:, down] = OUTAGE_LATENCY_MS
        cap = (self.base_capacity * scale[:, None]).astype(np.float32)
        klim = (self.base_task_limit * scale).astype(np.float32)
        hosts = np.maximum(1, np.round(self.base_hosts * scale)).astype(np.int32)
        problem = dataclasses.replace(
            self.cluster.problem,
            capacity=jnp.asarray(cap),
            task_limit=jnp.asarray(klim),
            slo_allowed=jnp.asarray(slo_allowed))
        self.cluster = dataclasses.replace(
            self.cluster, problem=problem, hosts_per_tier=hosts,
            region_latency=lat.astype(np.float32))


@dataclasses.dataclass(frozen=True)
class TimedEvent:
    """Base: fires once when the harness reaches tick ``at``.

    Maintenance-class events (capacity scales, region outage windows) are
    scheduled in the real world, so they default to ``announced=True`` and
    publish themselves on the advisory channel via ``declare``; surprises
    (flash crowds, churn re-rates) return None and are never declared.
    """

    at: int

    def apply(self, fleet: FleetState) -> None:  # pragma: no cover
        raise NotImplementedError

    def declare(self):
        """The ``core.planner.Advisory`` for this event, or None."""
        return None


@dataclasses.dataclass(frozen=True)
class CapacityScale(TimedEvent):
    """Set a tier's capacity scale relative to as-built (drains/restores).

    Maintenance drains are ramps: a scenario emits a staircase of these
    (tier_drain in ``sim.scenario``), each one a small step, so the
    controller sees a moving target rather than a cliff.
    """

    tier: int = 0
    scale: float = 1.0
    announced: bool = True

    def apply(self, fleet: FleetState) -> None:
        fleet.tier_scale[self.tier] = self.scale
        fleet.refresh()

    def declare(self):
        if not self.announced:
            return None
        return P.Advisory(at=self.at, kind=P.CAPACITY, tier=self.tier,
                          scale=self.scale)


@dataclasses.dataclass(frozen=True)
class RegionOutage(TimedEvent):
    """A region's hosts drop out: overlapping tiers lose the capacity share
    and the SLO eligibility, and the region becomes latency-unreachable."""

    region: int = 0
    announced: bool = True

    def apply(self, fleet: FleetState) -> None:
        fleet.down_regions.add(self.region)
        fleet.refresh()

    def declare(self):
        if not self.announced:
            return None
        return P.Advisory(at=self.at, kind=P.OUTAGE, region=self.region)


@dataclasses.dataclass(frozen=True)
class RegionRestore(TimedEvent):
    region: int = 0
    announced: bool = True

    def apply(self, fleet: FleetState) -> None:
        fleet.down_regions.discard(self.region)
        fleet.refresh()

    def declare(self):
        if not self.announced:
            return None
        return P.Advisory(at=self.at, kind=P.RESTORE, region=self.region)


@dataclasses.dataclass(frozen=True)
class FlashCrowd(TimedEvent):
    """Spike a random ``frac`` of the live apps to ``magnitude``x demand;
    the workload step decays them back geometrically."""

    frac: float = 0.05
    magnitude: float = 6.0

    def apply(self, fleet: FleetState) -> None:
        live = np.where(np.asarray(fleet.wl.valid))[0]
        k = max(1, int(round(self.frac * live.size)))
        ids = fleet.rng.choice(live, size=min(k, live.size), replace=False)
        fleet.wl = W.inject_flash_crowd(fleet.wl, ids, self.magnitude)


@dataclasses.dataclass(frozen=True)
class ShardSkew(TimedEvent):
    """A data-shard hotspot: demand spikes on the apps whose shard mass is
    anchored in one region (their state lives there, so the load cannot be
    shed by moving them far away — the shard locality level constrains the
    controller's repair moves).  Decays back like a flash crowd; data
    hotspots are surprises, so the event never declares an advisory."""

    region: int = 0
    magnitude: float = 5.0

    def apply(self, fleet: FleetState) -> None:
        live = np.asarray(fleet.wl.valid)
        ids = np.where(live & (fleet.cluster.app_region == self.region))[0]
        if ids.size:
            fleet.wl = W.inject_flash_crowd(fleet.wl, ids, self.magnitude)


@dataclasses.dataclass(frozen=True)
class ChurnRate(TimedEvent):
    """Re-rate arrivals/retirements (traced workload state — no retrace)."""

    arrival_rate: float | None = None
    retire_rate: float | None = None

    def apply(self, fleet: FleetState) -> None:
        fleet.wl = W.set_churn_rates(
            fleet.wl, arrival_rate=self.arrival_rate,
            retire_rate=self.retire_rate)


def events_at(events, tick: int):
    """The scenario's events firing at this tick, in declaration order."""
    return [e for e in events if e.at == tick]
