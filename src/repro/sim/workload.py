"""Vectorized workload engine: per-app demand evolution as one jitted step.

The paper motivates *proactive* balancing — "areas of the infrastructure
that previously required minimal load balancing, now must be made more
robust and proactive to application load" — which only matters if load
actually moves.  This module evolves the per-app demand the §3.1 collection
stage would observe, tick over tick, entirely on device:

  * **diurnal sinusoid** — every app follows a shared day/night cycle with a
    per-app phase offset (multi-region fleets see staggered peaks),
  * **lognormal burst noise** — the §3.1 p99-vs-mean gap, resampled per tick,
  * **flash crowds** — rare heavy-tailed demand spikes (per-app ignition or
    scenario-injected) that decay geometrically back to baseline,
  * **app churn** — arrivals and retirements flip the ``valid`` mask over a
    fixed-size app pool, the same inert-row convention ``problem.pad_problem``
    uses for shape bucketing.  The array shapes never change as the live app
    count drifts, so the workload step, the solvers, and the cooperation
    loop all keep their compiled executables (at most one retrace per pow-2
    bucket — asserted in tests/test_sim.py via the existing counters).

``WorkloadState`` is a registered-dataclass pytree; churn rates live in the
*state* (traced scalars), not the static config, so scenario events can
re-rate churn mid-trajectory without triggering a retrace.

The base (mean) demand per app is drawn from the same paper-calibrated
population as ``telemetry.generate_cluster``
(``telemetry.sample_app_population``) — the simulator modulates the
collected p99 baseline rather than inventing a second distribution.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Retrace counter with the same contract as solver_local/pack: increments at
# trace time only, so a delta of 0 across a step means the jit cache was hit.
_TRACE_COUNTS = {"workload_step": 0}


def workload_trace_count() -> int:
    """Number of times the jitted workload step has been (re)traced."""
    return _TRACE_COUNTS["workload_step"]


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Static (hashable) knobs of the demand process.

    Anything a scenario event may change mid-run must NOT live here — it
    would retrace the step.  Churn rates are therefore traced state.
    """

    period: int = 96             # ticks per diurnal cycle
    diurnal_amp: float = 0.30    # peak-to-mean amplitude of the sinusoid
    burst_sigma: float = 0.15    # lognormal tick-noise sigma
    flash_prob: float = 0.0      # per-app per-tick flash-crowd ignition prob
    flash_mag: float = 5.0       # flash-crowd demand multiplier (median)
    flash_decay: float = 0.85    # per-tick geometric decay back to 1.0
    task_elasticity: float = 0.5  # fraction of demand swing mirrored in tasks


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WorkloadState:
    """Device-resident demand-process state over a fixed pool of Nmax apps."""

    key: jax.Array           # PRNG key
    base_demand: jax.Array   # f32[Nmax, R] collected p99 baseline per app
    base_tasks: jax.Array    # f32[Nmax]    baseline task count per app
    phase: jax.Array         # f32[Nmax]    diurnal phase offset in [0, 1)
    flash: jax.Array         # f32[Nmax]    flash-crowd multiplier (>= 1)
    valid: jax.Array         # bool[Nmax]   live apps (churn flips this)
    arrival_rate: jax.Array  # f32[] expected arrivals per tick (traced!)
    retire_rate: jax.Array   # f32[] per-app per-tick retirement prob (traced!)
    tick: jax.Array          # i32[] ticks advanced so far

    @property
    def num_live(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))


def make_workload_state(
    base_demand,
    base_tasks,
    valid,
    *,
    seed: int = 0,
    arrival_rate: float = 0.0,
    retire_rate: float = 0.0,
) -> WorkloadState:
    """Build the initial state around a collected baseline.

    ``base_demand``/``base_tasks`` cover the whole Nmax pool (rows with
    ``valid=False`` are standby apps that may arrive later); phases are
    seeded uniformly so tiers don't peak in lock-step.
    """
    base_demand = jnp.asarray(base_demand, jnp.float32)
    n = base_demand.shape[0]
    rng = np.random.default_rng(seed)
    return WorkloadState(
        key=jax.random.PRNGKey(seed),
        base_demand=base_demand,
        base_tasks=jnp.asarray(base_tasks, jnp.float32),
        phase=jnp.asarray(rng.random(n), jnp.float32),
        flash=jnp.ones((n,), jnp.float32),
        valid=jnp.asarray(valid, bool),
        arrival_rate=jnp.float32(arrival_rate),
        retire_rate=jnp.float32(retire_rate),
        tick=jnp.int32(0),
    )


@partial(jax.jit, static_argnames=("cfg",))
def workload_step(cfg: WorkloadConfig, state: WorkloadState
                  ) -> tuple[WorkloadState, jax.Array, jax.Array, jax.Array]:
    """Advance one tick; returns (state', demand[Nmax, R], tasks[Nmax],
    valid[Nmax]).

    Fixed shapes whatever the live app count: churn only flips the ``valid``
    mask, exactly the inert-row convention the solvers' shape bucketing
    already handles, so a whole scenario shares one compiled step.
    """
    _TRACE_COUNTS["workload_step"] += 1      # trace-time side effect only
    key, k_burst, k_ignite, k_mag, k_retire, k_arrive = jax.random.split(
        state.key, 6)
    n = state.base_demand.shape[0]
    t = state.tick.astype(jnp.float32)

    # Diurnal sinusoid with per-app phase.
    diurnal = 1.0 + cfg.diurnal_amp * jnp.sin(
        2.0 * jnp.pi * (t / cfg.period + state.phase))
    # Lognormal burst noise (median 1).
    burst = jnp.exp(cfg.burst_sigma * jax.random.normal(k_burst, (n,)))
    # Flash crowds: decay standing spikes, ignite new ones.
    flash = 1.0 + (state.flash - 1.0) * cfg.flash_decay
    ignite = jax.random.uniform(k_ignite, (n,)) < cfg.flash_prob
    mag = cfg.flash_mag * jnp.exp(0.25 * jax.random.normal(k_mag, (n,)))
    flash = jnp.where(ignite & state.valid, jnp.maximum(flash, mag), flash)

    # Churn.  Retirements: per-live-app Bernoulli.  Arrivals: Bernoulli over
    # standby rows with the rate split across them, so the *expected* number
    # of arrivals per tick is ``arrival_rate`` while shapes stay static.
    retire = jax.random.uniform(k_retire, (n,)) < state.retire_rate
    valid = state.valid & ~retire
    standby = ~valid
    n_standby = jnp.maximum(1, jnp.sum(standby.astype(jnp.int32)))
    p_arrive = jnp.minimum(1.0, state.arrival_rate / n_standby)
    arrive = standby & (jax.random.uniform(k_arrive, (n,)) < p_arrive)
    valid = valid | arrive

    mult = diurnal * burst * flash                             # f32[Nmax]
    # Standby/retired rows emit exactly zero demand and tasks — the
    # ``pad_problem`` inert-row invariant.  The host packer and the
    # difference-to-balance totals consume these arrays unmasked, so ghost
    # demand on invalid rows would occupy hosts at stale placements and
    # inflate the balanced-state target.
    live = valid.astype(jnp.float32)
    demand = state.base_demand * (mult * live)[:, None]
    # Task fan-out follows demand sub-linearly (scaling adds tasks slower
    # than it adds load); live apps always keep >= 1 task.
    tasks = live * jnp.maximum(
        1.0, state.base_tasks * (1.0 + cfg.task_elasticity * (mult - 1.0)))

    state = dataclasses.replace(
        state, key=key, flash=flash, valid=valid, tick=state.tick + 1)
    return state, demand, tasks, valid


def inject_flash_crowd(state: WorkloadState, app_ids: np.ndarray,
                       magnitude: float) -> WorkloadState:
    """Scenario-driven flash crowd: spike the given apps' multipliers.

    Host-side event plumbing (runs once at the event tick); the decay back
    to baseline happens inside the jitted step.
    """
    ids = jnp.asarray(np.asarray(app_ids, np.int32))
    flash = state.flash.at[ids].max(jnp.float32(magnitude))
    return dataclasses.replace(state, flash=flash)


def set_churn_rates(state: WorkloadState, *, arrival_rate=None,
                    retire_rate=None) -> WorkloadState:
    """Scenario-driven churn re-rating — traced scalars, so no retrace."""
    kw = {}
    if arrival_rate is not None:
        kw["arrival_rate"] = jnp.float32(arrival_rate)
    if retire_rate is not None:
        kw["retire_rate"] = jnp.float32(retire_rate)
    return dataclasses.replace(state, **kw)
