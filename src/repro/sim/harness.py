"""Fleet simulator harness: drive BalanceController through a scenario.

This is the layer that turns the repro from a one-shot solver into the
long-running balancing *system* the paper describes: every tick the
workload engine advances demand on device, timed events rewrite the
cluster (capacity drains, region outages, churn re-rates), arrivals are
placed, and the controller decides whether to rebalance.  The SLO
accountant scores the placement the controller leaves behind.

Two policies share the machinery:
  * ``balanced`` — a ``BalanceController`` ticks over the trajectory
    (hysteresis, cooldown, movement budget — the paper's §3.3 loop),
  * ``static``   — the no-rebalance baseline: the t=0 placement rides out
    the whole trajectory.  The gap between the two, integrated over ticks,
    is the value of proactive balancing (asserted in tests/test_sim.py,
    tracked in BENCH_sim.json).

Shapes are static for the whole run: churn flips the ``valid`` mask over a
fixed app pool (the ``pad_problem`` inert-row convention), so the workload
step compiles once and the solver keeps one executable per pow-2 bucket.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.controller import (BalanceController, ControllerConfig,
                                   FaultToleranceConfig)
from repro.core.hierarchy import RegionScheduler
from repro.core.levels import DEFAULT_LEVELS
from repro.core.solver_local import local_search_trace_count
from repro.core.telemetry import FIG3_INITIAL_UTIL, ClusterState, generate_cluster
from repro.sim.events import (ControlPlaneFault, FleetState, events_at,
                              faulty_hierarchy)
from repro.sim.scenario import Scenario
from repro.sim.slo import (SimReport, SloAccountant, chaos_compare, compare,
                           count_unsafe_moves)
from repro.sim.workload import (make_workload_state, workload_step,
                                workload_trace_count)

# Sim-tuned controller defaults: short deterministic solver budget per tick
# (the controller runs hundreds of times per trajectory), quick cooldown.
SIM_CONTROLLER = ControllerConfig(trigger_d2b=0.15, trigger_over_ideal=0.05,
                                  cooldown_rounds=2, timeout_s=4)

# Chaos scenarios default to the degraded-mode control plane armed: the
# whole point is watching the telemetry monitor / breakers / mode machine
# absorb the faults.  Callers may still pass a fault=None config to watch
# an unprotected controller get hurt.
CHAOS_CONTROLLER = dataclasses.replace(SIM_CONTROLLER,
                                       fault=FaultToleranceConfig())


def build_fleet(sc: Scenario) -> FleetState:
    """Materialize a scenario's t=0 world.

    The cluster is generated over the full app *pool* (live + standby) so
    arrays never change shape; capacity is then rescaled to the live demand
    share so the t=0 utilization keeps the Fig. 3 calibration whatever the
    pool factor.
    """
    pool = sc.max_apps
    cluster = generate_cluster(
        num_apps=pool, seed=sc.seed,
        initial_util=FIG3_INITIAL_UTIL * sc.util_scale)
    problem = cluster.problem
    valid = np.zeros(pool, bool)
    valid[:sc.num_apps] = True

    demand = np.asarray(problem.demand)
    tasks = np.asarray(problem.tasks)
    # Live demand share per resource: capacity was calibrated against the
    # whole pool, the trajectory starts with ``num_apps`` live.
    share = demand[valid].sum(axis=0) / np.maximum(demand.sum(axis=0), 1e-9)
    task_share = tasks[valid].sum() / max(float(tasks.sum()), 1e-9)
    capacity = (np.asarray(problem.capacity) * share[None, :]).astype(np.float32)
    task_limit = (np.asarray(problem.task_limit) * task_share).astype(np.float32)
    hosts = np.maximum(1, np.round(
        cluster.hosts_per_tier * float(share.mean()))).astype(np.int32)

    # Standby rows carry zero demand/tasks in the *problem* (the pad_problem
    # inert-row invariant: packers and balance totals read these unmasked);
    # the workload state keeps the full-pool baseline for later arrivals.
    problem = dataclasses.replace(
        problem, valid=jnp.asarray(valid),
        demand=jnp.asarray(demand * valid[:, None]),
        tasks=jnp.asarray(tasks * valid),
        capacity=jnp.asarray(capacity), task_limit=jnp.asarray(task_limit))
    cluster = dataclasses.replace(cluster, problem=problem,
                                  hosts_per_tier=hosts)

    wl = make_workload_state(
        demand, tasks, valid, seed=sc.seed + 7,
        arrival_rate=sc.arrival_rate, retire_rate=sc.retire_rate)
    return FleetState(
        cluster=cluster, wl=wl, wl_cfg=sc.workload,
        base_capacity=capacity, base_task_limit=task_limit,
        base_hosts=hosts.copy(),
        base_slo_allowed=np.asarray(problem.slo_allowed).copy(),
        base_latency=cluster.region_latency.copy(),
        tier_scale=np.ones(problem.num_tiers, np.float32),
        declared_events=sc.declared_events,
        rng=np.random.default_rng(sc.seed + 13))


def place_arrivals(fleet: FleetState, arrivals: np.ndarray) -> np.ndarray:
    """Initial placement for newly-arrived apps: the SLO-eligible,
    region-reachable tier with the most post-placement headroom (greedy,
    sequential — arrivals per tick are few).  Returns the new assignment0.

    This mimics the paper's pre-balancer reality: arrivals are placed by a
    simple admission rule, and it is the *controller's* job to clean up
    the drift they cause.
    """
    problem = fleet.cluster.problem
    x = np.asarray(problem.assignment0).copy()
    slo = np.asarray(problem.slo)
    slo_allowed = np.asarray(problem.slo_allowed)
    cap = np.asarray(problem.capacity)
    klim = np.asarray(problem.task_limit)
    demand = np.asarray(problem.demand)
    tasks = np.asarray(problem.tasks)
    valid = np.asarray(problem.valid)
    region_ok = RegionScheduler(fleet.cluster).feasibility_matrix()  # [N, T]

    live = valid.copy()
    live[arrivals] = False                    # loads before this batch
    T = problem.num_tiers
    util = np.zeros((T, demand.shape[1]), np.float64)
    tsk = np.zeros(T, np.float64)
    np.add.at(util, x[live], demand[live])
    np.add.at(tsk, x[live], tasks[live])

    for n in arrivals:
        ok = slo_allowed[:, slo[n]] & region_ok[n]
        if not ok.any():
            ok = slo_allowed[:, slo[n]]       # degraded: ignore region
        if not ok.any():
            ok = np.ones(T, bool)             # last resort: anywhere
        frac = np.maximum(
            ((util + demand[n]) / np.maximum(cap, 1e-9)).max(axis=1),
            (tsk + tasks[n]) / np.maximum(klim, 1e-9))
        frac = np.where(ok, frac, np.inf)
        t = int(np.argmin(frac))
        x[n] = t
        util[t] += demand[n]
        tsk[t] += tasks[n]
    return x


# -- chaos machinery: what the controller observes vs what is true ----------

def _corrupt_telemetry(obs: ClusterState, fleet: FleetState) -> ClusterState:
    """Garble a fraction of live apps' demand readings (observed channel
    only).  Draws on ``fleet.chaos_rng`` — never ``fleet.rng``, which must
    stay bit-synchronized with the fault-free oracle run."""
    p = obs.problem
    demand = np.asarray(p.demand, np.float32).copy()
    live = np.where(np.asarray(p.valid))[0]
    k = max(1, int(round(fleet.corrupt_frac * live.size)))
    ids = fleet.chaos_rng.choice(live, size=min(k, live.size), replace=False)
    demand[ids] *= fleet.corrupt_magnitude
    return dataclasses.replace(
        obs, problem=dataclasses.replace(p, demand=jnp.asarray(demand)))


def _observe(fleet: FleetState, observed: ClusterState | None,
             tick: int) -> ClusterState:
    """The controller's telemetry channel for this tick.

    Normal operation: the true cluster, stamped ``collected_at=tick``
    (and corrupted when a ``TelemetryCorruption`` window is active —
    corruption is a plausibility fault, not a staleness one, so the stamp
    stays fresh).  During a ``TelemetryBlackout`` window the previous
    snapshot is re-served with its original stamp, growing staleness; only
    ``assignment0`` is carried forward from the truth, because placement
    is the controller's *own action record*, not telemetry.  A blackout
    declared at tick 0 has no snapshot to freeze and passes tick 0
    through fresh.
    """
    if tick < fleet.blackout_until and observed is not None:
        return dataclasses.replace(
            observed, problem=observed.problem.with_assignment0(
                fleet.cluster.problem.assignment0))
    obs = dataclasses.replace(fleet.cluster, collected_at=tick)
    if tick < fleet.corrupt_until:
        obs = _corrupt_telemetry(obs, fleet)
    return obs


def _apply_fault_windows(ctl: BalanceController, fleet: FleetState,
                         tick: int, base_cfg: ControllerConfig) -> None:
    """Arm/disarm the solver-side fault windows for this tick: a brownout
    zeroes the controller's solver wall-clock budget, a level fault swaps
    a ``FaultyLevel``-wrapped hierarchy into ``hierarchy_override``."""
    if tick < fleet.brownout_until:
        if ctl.config.timeout_s != 0:
            ctl.config = dataclasses.replace(base_cfg, timeout_s=0)
    elif ctl.config is not base_cfg:
        ctl.config = base_cfg
    if tick < fleet.level_fault_until:
        if ctl.hierarchy_override is None:
            ctl.hierarchy_override = faulty_hierarchy(
                base_cfg.coop.levels, fleet.level_fault_level,
                fleet.level_fault_mode)
    else:
        ctl.hierarchy_override = None


def run_scenario(sc: Scenario, *, policy: str = "balanced",
                 config: ControllerConfig | None = None,
                 anticipation: bool = True,
                 verbose: bool = False) -> SimReport:
    """Run one scenario under one policy; returns the scored trajectory.

    ``anticipation`` hands the scenario's declared maintenance advisories
    (``Scenario.declared_events``) to the controller's planner, and the
    scenario's ``move_budget`` (when set) becomes the controller's
    trajectory movement budget unless the caller's config already pins one
    — so the proactive evacuation is judged against what it spends.
    """
    assert policy in ("balanced", "static"), policy
    has_chaos = sc.chaos or any(isinstance(e, ControlPlaneFault)
                                for e in sc.events)
    fleet = build_fleet(sc)
    ctl = None
    if policy == "balanced":
        cfg = config or (CHAOS_CONTROLLER if has_chaos else SIM_CONTROLLER)
        if sc.move_budget is not None and cfg.movement_cost_budget is None:
            cfg = dataclasses.replace(cfg, movement_cost_budget=sc.move_budget)
        if sc.levels is not None and cfg.coop.levels is None:
            # The scenario names its scheduler stack (e.g. shard_skew runs
            # region+host+shard); a caller-pinned stack wins.
            cfg = dataclasses.replace(
                cfg, coop=dataclasses.replace(cfg.coop,
                                              levels=tuple(sc.levels)))
        ctl = BalanceController(fleet.cluster, cfg)
        if anticipation:
            ctl.set_advisories(fleet.declared_events)
    acct = SloAccountant()
    solver_traces0 = local_search_trace_count()
    wl_traces0 = workload_trace_count()
    observed: ClusterState | None = None   # chaos telemetry channel
    base_cfg = ctl.config if ctl is not None else None

    for tick in range(sc.ticks):
        # 1. Advance demand on device (one compiled step for the whole run).
        fleet.wl, demand, tasks, valid = workload_step(fleet.wl_cfg, fleet.wl)
        prev_valid = np.asarray(fleet.cluster.problem.valid)
        fleet.cluster = dataclasses.replace(
            fleet.cluster,
            problem=dataclasses.replace(
                fleet.cluster.problem, demand=demand, tasks=tasks,
                valid=valid))

        # 2. Timed events rewrite the effective cluster / workload knobs.
        for ev in events_at(sc.events, tick):
            ev.apply(fleet)

        # 3. Place arrivals (after events: admission sees drained capacity).
        arrivals = np.where(np.asarray(valid) & ~prev_valid)[0]
        if arrivals.size:
            x0 = place_arrivals(fleet, arrivals)
            fleet.cluster = dataclasses.replace(
                fleet.cluster,
                problem=fleet.cluster.problem.with_assignment0(
                    jnp.asarray(x0)))

        # 4. Controller decides; the applied mapping becomes assignment0.
        if ctl is not None and has_chaos:
            # Chaos: the controller plans on the *observed* channel (frozen
            # or corrupted telemetry) while the accountant scores the true
            # cluster.  Committed moves transplant back onto the truth —
            # placement is an action, not a reading — and every applied
            # move is checked for true-world safety.
            observed = _observe(fleet, observed, tick)
            _apply_fault_windows(ctl, fleet, tick, base_cfg)
            x_before = np.asarray(fleet.cluster.problem.assignment0)
            evr = ctl.tick(observed, now=tick,
                           collected_at=observed.collected_at)
            unsafe = 0
            if evr.applied:
                committed = np.asarray(ctl.cluster.problem.assignment0)
                unsafe = count_unsafe_moves(fleet.cluster.problem,
                                            x_before, committed)
                fleet.cluster = dataclasses.replace(
                    fleet.cluster,
                    problem=fleet.cluster.problem.with_assignment0(
                        jnp.asarray(committed)))
            stat = acct.observe(
                fleet.cluster, moved=evr.moved if evr.applied else 0,
                applied=evr.applied, triggered=evr.triggered,
                solve_s=evr.time_s,
                movement_cost=evr.movement_cost if evr.applied else 0.0,
                budget_limited=evr.budget_limited, unsafe_moves=unsafe,
                mode=evr.mode, health_score=evr.health_score)
        elif ctl is not None:
            evr = ctl.tick(fleet.cluster, now=tick)
            fleet.cluster = ctl.cluster
            stat = acct.observe(
                fleet.cluster, moved=evr.moved if evr.applied else 0,
                applied=evr.applied, triggered=evr.triggered,
                solve_s=evr.time_s,
                movement_cost=evr.movement_cost if evr.applied else 0.0,
                budget_limited=evr.budget_limited,
                mode=evr.mode, health_score=evr.health_score)
        else:
            stat = acct.observe(fleet.cluster)
        if verbose:
            mode = f" [{stat.mode}]" if stat.mode != "normal" else ""
            print(f"  t={tick:4d} live={stat.live_apps:5d} "
                  f"d2b={stat.d2b:.3f} slo_viol={stat.slo_violating_apps:4d} "
                  f"over_ideal={stat.over_ideal_tiers}{mode} "
                  f"{'MOVED ' + str(stat.moved) if stat.applied else ''}")

    report = acct.report(sc.name, policy)
    report.extra.update(
        solver_retraces=local_search_trace_count() - solver_traces0,
        workload_retraces=workload_trace_count() - wl_traces0,
        num_apps=sc.num_apps, pool=sc.max_apps)
    if ctl is not None:
        report.extra.update(
            audit=ctl.audit(),
            levels=list(ctl.config.coop.levels or DEFAULT_LEVELS),
            # The budget the controller actually enforced — a caller-pinned
            # config budget overrides the scenario default, and recording
            # the scenario's number instead would misgrade within_budget.
            move_budget=ctl.config.movement_cost_budget,
            anticipation=bool(anticipation and fleet.declared_events))
    return report


def run_pair(sc: Scenario, *, config: ControllerConfig | None = None,
             anticipation: bool = True, verbose: bool = False) -> dict:
    """Baseline + controller over the same trajectory, plus the comparison
    record (the per-scenario entry in BENCH_sim.json)."""
    baseline = run_scenario(sc, policy="static", verbose=verbose)
    balanced = run_scenario(sc, policy="balanced", config=config,
                            anticipation=anticipation, verbose=verbose)
    return {
        "baseline": baseline,
        "balanced": balanced,
        "compare": compare(baseline, balanced),
    }


def strip_chaos(sc: Scenario) -> Scenario:
    """The fault-free oracle twin of a chaos scenario: same seed, same
    workload process, same cluster events — only the control-plane faults
    removed.  Both runs draw flash-crowd targets from the same ``rng``
    stream (chaos consumes ``chaos_rng``, never ``rng``), so the
    trajectories are bit-identical up to the controller's decisions."""
    events = tuple(e for e in sc.events
                   if not isinstance(e, ControlPlaneFault))
    return dataclasses.replace(sc, events=events, chaos=False)


def run_chaos_pair(sc: Scenario, *, config: ControllerConfig | None = None,
                   verbose: bool = False) -> dict:
    """A chaos scenario three ways: degraded (faults live), oracle (faults
    stripped, same trajectory), and the static baseline.  The ``chaos``
    record is the degraded-vs-oracle scorecard the regression gate pins
    (zero unsafe moves, bounded violation ratio, recovery to NORMAL)."""
    cfg = config or CHAOS_CONTROLLER
    oracle_sc = strip_chaos(sc)
    degraded = run_scenario(sc, policy="balanced", config=cfg,
                            verbose=verbose)
    oracle = run_scenario(oracle_sc, policy="balanced", config=cfg,
                          verbose=verbose)
    baseline = run_scenario(oracle_sc, policy="static", verbose=verbose)
    return {
        "degraded": degraded,
        "oracle": oracle,
        "baseline": baseline,
        "chaos": chaos_compare(degraded, oracle),
        "compare": compare(baseline, degraded),
    }
