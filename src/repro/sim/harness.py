"""Fleet simulator harness: drive BalanceController through a scenario.

This is the layer that turns the repro from a one-shot solver into the
long-running balancing *system* the paper describes: every tick the
workload engine advances demand on device, timed events rewrite the
cluster (capacity drains, region outages, churn re-rates), arrivals are
placed, and the controller decides whether to rebalance.  The SLO
accountant scores the placement the controller leaves behind.

Two policies share the machinery:
  * ``balanced`` — a ``BalanceController`` ticks over the trajectory
    (hysteresis, cooldown, movement budget — the paper's §3.3 loop),
  * ``static``   — the no-rebalance baseline: the t=0 placement rides out
    the whole trajectory.  The gap between the two, integrated over ticks,
    is the value of proactive balancing (asserted in tests/test_sim.py,
    tracked in BENCH_sim.json).

Shapes are static for the whole run: churn flips the ``valid`` mask over a
fixed app pool (the ``pad_problem`` inert-row convention), so the workload
step compiles once and the solver keeps one executable per pow-2 bucket.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.constraints import FEAS_TOL
from repro.core.controller import (BalanceController, ControllerConfig,
                                   FaultToleranceConfig, TickInput)
from repro.core.hierarchy import RegionScheduler
from repro.core.levels import DEFAULT_LEVELS
from repro.core.shedding import ShedConfig
from repro.core.solver_local import local_search_trace_count
from repro.core.telemetry import FIG3_INITIAL_UTIL, ClusterState, generate_cluster
from repro.core.utility import attach_curves, default_curves
from repro.sim.events import (ControlPlaneFault, FleetState, events_at,
                              faulty_hierarchy)
from repro.sim.scenario import Scenario
from repro.sim.slo import (SimReport, SloAccountant, chaos_compare, compare,
                           count_unsafe_moves, overload_compare,
                           utility_stats)
from repro.sim.workload import (make_workload_state, workload_step,
                                workload_trace_count)
from repro.streams.admission import AdmissionController, AdmissionState

# Sim-tuned controller defaults: short deterministic solver budget per tick
# (the controller runs hundreds of times per trajectory), quick cooldown.
SIM_CONTROLLER = ControllerConfig(trigger_d2b=0.15, trigger_over_ideal=0.05,
                                  cooldown_rounds=2, timeout_s=4)

# Chaos scenarios default to the degraded-mode control plane armed: the
# whole point is watching the telemetry monitor / breakers / mode machine
# absorb the faults.  Callers may still pass a fault=None config to watch
# an unprotected controller get hurt.
CHAOS_CONTROLLER = dataclasses.replace(SIM_CONTROLLER,
                                       fault=FaultToleranceConfig())


def build_fleet(sc: Scenario) -> FleetState:
    """Materialize a scenario's t=0 world.

    The cluster is generated over the full app *pool* (live + standby) so
    arrays never change shape; capacity is then rescaled to the live demand
    share so the t=0 utilization keeps the Fig. 3 calibration whatever the
    pool factor.
    """
    pool = sc.max_apps
    cluster = generate_cluster(
        num_apps=pool, seed=sc.seed,
        initial_util=FIG3_INITIAL_UTIL * sc.util_scale)
    problem = cluster.problem
    valid = np.zeros(pool, bool)
    valid[:sc.num_apps] = True

    demand = np.asarray(problem.demand)
    tasks = np.asarray(problem.tasks)
    # Live demand share per resource: capacity was calibrated against the
    # whole pool, the trajectory starts with ``num_apps`` live.
    share = demand[valid].sum(axis=0) / np.maximum(demand.sum(axis=0), 1e-9)
    task_share = tasks[valid].sum() / max(float(tasks.sum()), 1e-9)
    capacity = (np.asarray(problem.capacity) * share[None, :]).astype(np.float32)
    task_limit = (np.asarray(problem.task_limit) * task_share).astype(np.float32)
    hosts = np.maximum(1, np.round(
        cluster.hosts_per_tier * float(share.mean()))).astype(np.int32)

    # Standby rows carry zero demand/tasks in the *problem* (the pad_problem
    # inert-row invariant: packers and balance totals read these unmasked);
    # the workload state keeps the full-pool baseline for later arrivals.
    problem = dataclasses.replace(
        problem, valid=jnp.asarray(valid),
        demand=jnp.asarray(demand * valid[:, None]),
        tasks=jnp.asarray(tasks * valid),
        capacity=jnp.asarray(capacity), task_limit=jnp.asarray(task_limit))
    cluster = dataclasses.replace(cluster, problem=problem,
                                  hosts_per_tier=hosts)

    wl = make_workload_state(
        demand, tasks, valid, seed=sc.seed + 7,
        arrival_rate=sc.arrival_rate, retire_rate=sc.retire_rate)
    return FleetState(
        cluster=cluster, wl=wl, wl_cfg=sc.workload,
        base_capacity=capacity, base_task_limit=task_limit,
        base_hosts=hosts.copy(),
        base_slo_allowed=np.asarray(problem.slo_allowed).copy(),
        base_latency=cluster.region_latency.copy(),
        tier_scale=np.ones(problem.num_tiers, np.float32),
        declared_events=sc.declared_events,
        rng=np.random.default_rng(sc.seed + 13))


def place_arrivals(fleet: FleetState, arrivals: np.ndarray) -> np.ndarray:
    """Initial placement for newly-arrived apps: the SLO-eligible,
    region-reachable tier with the most post-placement headroom (greedy,
    sequential — arrivals per tick are few).  Returns the new assignment0.

    This mimics the paper's pre-balancer reality: arrivals are placed by a
    simple admission rule, and it is the *controller's* job to clean up
    the drift they cause.
    """
    problem = fleet.cluster.problem
    x = np.asarray(problem.assignment0).copy()
    slo = np.asarray(problem.slo)
    slo_allowed = np.asarray(problem.slo_allowed)
    cap = np.asarray(problem.capacity)
    klim = np.asarray(problem.task_limit)
    demand = np.asarray(problem.demand)
    tasks = np.asarray(problem.tasks)
    valid = np.asarray(problem.valid)
    region_ok = RegionScheduler(fleet.cluster).feasibility_matrix()  # [N, T]

    live = valid.copy()
    live[arrivals] = False                    # loads before this batch
    T = problem.num_tiers
    util = np.zeros((T, demand.shape[1]), np.float64)
    tsk = np.zeros(T, np.float64)
    np.add.at(util, x[live], demand[live])
    np.add.at(tsk, x[live], tasks[live])

    for n in arrivals:
        ok = slo_allowed[:, slo[n]] & region_ok[n]
        if not ok.any():
            ok = slo_allowed[:, slo[n]]       # degraded: ignore region
        if not ok.any():
            ok = np.ones(T, bool)             # last resort: anywhere
        frac = np.maximum(
            ((util + demand[n]) / np.maximum(cap, 1e-9)).max(axis=1),
            (tsk + tasks[n]) / np.maximum(klim, 1e-9))
        frac = np.where(ok, frac, np.inf)
        t = int(np.argmin(frac))
        x[n] = t
        util[t] += demand[n]
        tsk[t] += tasks[n]
    return x


# -- chaos machinery: what the controller observes vs what is true ----------

def _corrupt_telemetry(obs: ClusterState, fleet: FleetState) -> ClusterState:
    """Garble a fraction of live apps' demand readings (observed channel
    only).  Draws on ``fleet.chaos_rng`` — never ``fleet.rng``, which must
    stay bit-synchronized with the fault-free oracle run."""
    p = obs.problem
    demand = np.asarray(p.demand, np.float32).copy()
    live = np.where(np.asarray(p.valid))[0]
    k = max(1, int(round(fleet.corrupt_frac * live.size)))
    ids = fleet.chaos_rng.choice(live, size=min(k, live.size), replace=False)
    demand[ids] *= fleet.corrupt_magnitude
    return dataclasses.replace(
        obs, problem=dataclasses.replace(p, demand=jnp.asarray(demand)))


def _observe(fleet: FleetState, observed: ClusterState | None,
             tick: int, view: ClusterState | None = None) -> ClusterState:
    """The controller's telemetry channel for this tick.

    Normal operation: the true cluster, stamped ``collected_at=tick``
    (and corrupted when a ``TelemetryCorruption`` window is active —
    corruption is a plausibility fault, not a staleness one, so the stamp
    stays fresh).  During a ``TelemetryBlackout`` window the previous
    snapshot is re-served with its original stamp, growing staleness; only
    ``assignment0`` is carried forward from the truth, because placement
    is the controller's *own action record*, not telemetry.  A blackout
    declared at tick 0 has no snapshot to freeze and passes tick 0
    through fresh.

    ``view`` overrides what "the truth" looks like to the controller —
    overload runs feed the *resident* cluster (admission-deferred apps
    held out) rather than the raw offered one.
    """
    truth = fleet.cluster if view is None else view
    if tick < fleet.blackout_until and observed is not None:
        return dataclasses.replace(
            observed, problem=observed.problem.with_assignment0(
                truth.problem.assignment0))
    obs = dataclasses.replace(truth, collected_at=tick)
    if tick < fleet.corrupt_until:
        obs = _corrupt_telemetry(obs, fleet)
    return obs


def _apply_fault_windows(ctl: BalanceController, fleet: FleetState,
                         tick: int, base_cfg: ControllerConfig) -> None:
    """Arm/disarm the solver-side fault windows for this tick: a brownout
    zeroes the controller's solver wall-clock budget, a level fault swaps
    a ``FaultyLevel``-wrapped hierarchy into ``hierarchy_override``."""
    if tick < fleet.brownout_until:
        if ctl.config.timeout_s != 0:
            ctl.config = dataclasses.replace(base_cfg, timeout_s=0)
    elif ctl.config is not base_cfg:
        ctl.config = base_cfg
    if tick < fleet.level_fault_until:
        if ctl.hierarchy_override is None:
            ctl.hierarchy_override = faulty_hierarchy(
                base_cfg.coop.levels, fleet.level_fault_level,
                fleet.level_fault_mode)
    else:
        ctl.hierarchy_override = None


# -- overload machinery: the admission gate in front of the trajectory ------

def _resident_view(cluster: ClusterState,
                   resident: np.ndarray) -> ClusterState:
    """The cluster as the controller sees it: admission-held apps are not
    resident — their rows go inert (the pad_problem convention) so tier
    loads, balance totals and the shedder never count them."""
    p = cluster.problem
    return dataclasses.replace(cluster, problem=dataclasses.replace(
        p, valid=jnp.asarray(resident),
        demand=jnp.asarray(np.asarray(p.demand) * resident[:, None]),
        tasks=jnp.asarray(np.asarray(p.tasks) * resident)))


def _admit_arrivals(fleet: FleetState, ctl: BalanceController,
                    pending: dict[int, int], arrivals: np.ndarray,
                    tick: int, counters: dict) -> np.ndarray:
    """Gate this tick's arrivals plus retry-eligible deferred apps through
    the controller's admission gate.  Mutates ``pending`` (app id -> next
    retry tick) and returns the new assignment0 with admitted apps placed
    at their priced tier.

    Each candidate is priced against the resident world *as of its own
    decision* (earlier admissions in the same tick count), so a batch of
    arrivals cannot collectively over-commit a tier the gate priced as
    having room for one.  After every admission the destination tier is
    re-checked against hard capacity at the admitted cap — the
    ``infeasible_admissions`` counter the regression gate pins to zero.
    """
    problem = fleet.cluster.problem
    dem = np.asarray(problem.demand, np.float64)
    tasks = np.asarray(problem.tasks, np.float64)
    slo = np.asarray(problem.slo)
    crit = np.asarray(problem.criticality)
    valid = np.asarray(problem.valid, bool)
    x0 = np.asarray(problem.assignment0).copy()
    cap_arr = np.asarray(problem.capacity, np.float64)
    klim = np.asarray(problem.task_limit, np.float64)
    pool = valid.size

    # Retired-while-waiting apps leave the queue; fresh arrivals join it
    # (retry "now", i.e. this tick).
    for n in [n for n in pending if not valid[n]]:
        del pending[n]
    for n in arrivals:
        pending.setdefault(int(n), tick)
    candidates = sorted(n for n, t in pending.items() if t <= tick)
    if not candidates:
        return x0

    caps = np.ones(pool, np.float64)
    if ctl.shedder is not None and ctl.shedder.caps is not None:
        c = np.asarray(ctl.shedder.caps, np.float64)
        caps[:c.size] = c

    pending_mask = np.zeros(pool, bool)
    pending_mask[list(pending)] = True
    r_valid = valid & ~pending_mask
    # Resident tier loads at the *served* caps — the independent recount
    # the post-admit feasibility check runs against.
    util = np.zeros_like(cap_arr)
    tsk = np.zeros(cap_arr.shape[0])
    np.add.at(util, x0[r_valid], dem[r_valid] * caps[r_valid, None])
    np.add.at(tsk, x0[r_valid], tasks[r_valid])

    for n in candidates:
        r_problem = dataclasses.replace(
            problem, valid=jnp.asarray(r_valid),
            demand=jnp.asarray(dem * r_valid[:, None]),
            tasks=jnp.asarray(tasks * r_valid)).with_assignment0(
                jnp.asarray(x0))
        d = ctl.admission.decide(
            r_problem, demand=dem[n], tasks=float(tasks[n]),
            slo=int(slo[n]), criticality=float(crit[n]), key=f"app{n}",
            mode=ctl.mode.value, now=tick)
        if d.admitted:
            del pending[n]
            x0[n] = d.tier
            r_valid[n] = True
            if (d.state is AdmissionState.ADMIT_DEGRADED
                    and ctl.shedder is not None):
                ctl.shedder._ensure(pool)
                ctl.shedder.set_cap(n, d.cap)
                caps[n] = d.cap
            util[d.tier] += dem[n] * caps[n]
            tsk[d.tier] += tasks[n]
            # The admission contract is *marginal* per resource: the app
            # must fit the headroom on every resource it consumes.  A tier
            # already over capacity on a resource the app demands none of
            # (workload drift after earlier admissions) is the shedder's
            # problem, not an infeasible admission.
            used = dem[n] > 0.0
            # Slack scales with the candidate: pricing admits at
            # max_cap >= 1 - FEAS_TOL, so an overshoot up to
            # demand * FEAS_TOL is the tolerance working, not a bug.
            over = (util[d.tier]
                    > cap_arr[d.tier] + FEAS_TOL * (1.0 + dem[n]))
            if (np.any(over & used)
                    or tsk[d.tier] > klim[d.tier] + FEAS_TOL):
                counters["infeasible_admissions"] += 1
        else:
            # DEFER backs off per the decision; REJECT (SAFE mode) has no
            # retry hint — the sim re-submits once the backoff-equivalent
            # window passes, modelling a client retrying after the fleet
            # leaves SAFE.
            pending[n] = tick + (d.retry_after if d.retry_after > 0 else 4)
    return x0


def _advance_world(fleet: FleetState, sc: Scenario, tick: int) -> None:
    """Step 2 of every tick: timed events rewrite the effective cluster /
    workload knobs.  The fleet clock advances first (jitter reads it), and
    an active jitter storm re-randomizes the effective latency every tick
    of its window (plus one refresh after it closes, restoring calm) even
    when no event fires."""
    fleet.tick = tick
    for ev in events_at(sc.events, tick):
        ev.apply(fleet)
    if fleet.jitter_sigma > 0.0 and tick <= fleet.jitter_until:
        fleet.refresh()


class _NetlatPlane:
    """The measurement plane a netlat run arms: a per-tick prober feeding
    the process-wide sketch bank, calibration after ``calibrate_ticks``
    clean ticks, and link-health publication into the controller's
    telemetry monitor.  ``budget_exceeding(...)`` is the per-tick audit the
    scorecard integrates — moves whose destination tier has a pair over
    its live measured budget."""

    def __init__(self, sc: Scenario, num_regions: int,
                 calibrate_ticks: int = 4):
        from repro import netlat as NL
        self._nl = NL
        self.bank = NL.LinkSketchBank(num_regions)
        self.source = NL.LinkMeasurementSource(seed=sc.seed + 31)
        self.config = NL.NetlatConfig()
        self.calibrate_ticks = calibrate_ticks
        NL.install_bank(self.bank, config=self.config, now=0)

    def observe(self, fleet: FleetState, ctl: BalanceController | None,
                tick: int) -> None:
        truth = np.asarray(fleet.cluster.region_latency, np.float64)
        self.bank.ingest(self.source.measure(truth, tick), tick)
        if not self.bank.calibrated and tick + 1 >= self.calibrate_ticks:
            self.bank.calibrate(tick)
        self._nl.set_now(tick)
        if ctl is not None and getattr(ctl, "monitor", None) is not None:
            ctl.monitor.note_signal(self.bank.signal_health(tick))

    def budget_exceeding(self, fleet: FleetState, x_before: np.ndarray,
                         x_after: np.ndarray, tick: int) -> int:
        if not self.bank.calibrated:
            return 0
        c = fleet.cluster
        valid = np.asarray(c.problem.valid, bool)
        moved = np.where((np.asarray(x_before) != np.asarray(x_after))
                         & valid)[0]
        if moved.size == 0:
            return 0
        budget = np.clip(self.config.headroom * self.bank.calibrated_p99,
                         self.config.min_ms, self.config.cap_ms)
        bad_pair = self.bank.p99(tick) > budget                 # [G, G]
        tier_bad = (bad_pair.astype(np.float64)
                    @ c.tier_regions.T.astype(np.float64)) > 0  # [G, T]
        tier_bad[:, ~c.tier_regions.any(axis=1)] = True
        dst = np.asarray(x_after)[moved]
        return int(np.sum(tier_bad[c.app_region[moved], dst]))

    def extra(self) -> dict:
        return {
            "calibrated": self.bank.calibrated,
            "calibrated_at": self.bank.calibrated_at,
            "relax_factor": round(self.bank.relax_factor(
                cap=self.config.max_relax), 4),
            "quarantined": int(self.bank.quarantined_total),
        }

    def close(self) -> None:
        self._nl.install_bank(None)


def run_scenario(sc: Scenario, *, policy: str = "balanced",
                 config: ControllerConfig | None = None,
                 anticipation: bool = True, utility: bool = False,
                 netlat: bool = False, verbose: bool = False) -> SimReport:
    """Run one scenario under one policy; returns the scored trajectory.

    ``anticipation`` hands the scenario's declared maintenance advisories
    (``Scenario.declared_events``) to the controller's planner, and the
    scenario's ``move_budget`` (when set) becomes the controller's
    trajectory movement budget unless the caller's config already pins one
    — so the proactive evacuation is judged against what it spends.

    ``netlat`` (or ``Scenario.netlat``) arms the measurement plane: a
    deterministic per-tick link prober feeds the process-wide sketch bank,
    budgets calibrate from the observed baseline, and link health is
    published into the controller's telemetry monitor.  Whether the
    controller *uses* the measurements is the stack's choice — a config
    with ``levels=("netlat", "host")`` binds the latency-SLO level; the
    default stack stays on the static constant, which is exactly the
    contrast ``run_netlat_pair`` scores.

    ``utility`` arms the overload-resilient control plane on an overload
    scenario: utility curves attach to the controller's problem, arrivals
    route through the admission gate (admit / admit-degraded / defer with
    backoff), and the load shedder runs in the cooperation bus.  The
    binary-baseline twin (``utility=False``) rides the same trajectory
    with none of it — both are scored on the same curves by
    ``utility_stats``, which is what makes ``overload_compare`` fair.
    """
    assert policy in ("balanced", "static"), policy
    has_chaos = sc.chaos or any(isinstance(e, ControlPlaneFault)
                                for e in sc.events)
    fleet = build_fleet(sc)
    curves = (default_curves(np.asarray(fleet.cluster.problem.criticality))
              if sc.overload else None)
    ctl = None
    if policy == "balanced":
        cfg = config or (CHAOS_CONTROLLER if has_chaos else SIM_CONTROLLER)
        if sc.move_budget is not None and cfg.movement_cost_budget is None:
            cfg = dataclasses.replace(cfg, movement_cost_budget=sc.move_budget)
        if sc.levels is not None and cfg.coop.levels is None:
            # The scenario names its scheduler stack (e.g. shard_skew runs
            # region+host+shard); a caller-pinned stack wins.
            cfg = dataclasses.replace(
                cfg, coop=dataclasses.replace(cfg.coop,
                                              levels=tuple(sc.levels)))
        if sc.shards is not None and cfg.shards is None:
            # The scenario routes solves through the sharded fleet path
            # (repro.shard); a caller-pinned shard count wins.
            cfg = dataclasses.replace(cfg, shards=sc.shards)
        if utility and cfg.shed is None:
            cfg = dataclasses.replace(cfg, shed=ShedConfig())
        if utility and curves is not None:
            # The utility run's controller sees the curves on every problem
            # it observes: attached once here, they ride through the
            # per-tick demand/valid replaces.  The binary twin's problem
            # never carries them (``has_utility`` stays False end to end).
            fleet.cluster = dataclasses.replace(
                fleet.cluster,
                problem=attach_curves(fleet.cluster.problem, *curves))
        ctl = BalanceController(fleet.cluster, cfg)
        if anticipation:
            from repro.service.events import AdvisoryBatch
            ctl.ingest(AdvisoryBatch(advisories=tuple(fleet.declared_events)))
        if utility:
            ctl.admission = AdmissionController()
    plane = (_NetlatPlane(sc, fleet.base_latency.shape[0])
             if (netlat or sc.netlat) else None)
    acct = SloAccountant()
    pending: dict[int, int] = {}     # admission-deferred: app id -> retry tick
    overload_counters = {"infeasible_admissions": 0}
    solver_traces0 = local_search_trace_count()
    wl_traces0 = workload_trace_count()
    observed: ClusterState | None = None   # chaos telemetry channel
    base_cfg = ctl.config if ctl is not None else None

    for tick in range(sc.ticks):
        # 1. Advance demand on device (one compiled step for the whole run).
        fleet.wl, demand, tasks, valid = workload_step(fleet.wl_cfg, fleet.wl)
        prev_valid = np.asarray(fleet.cluster.problem.valid)
        fleet.cluster = dataclasses.replace(
            fleet.cluster,
            problem=dataclasses.replace(
                fleet.cluster.problem, demand=demand, tasks=tasks,
                valid=valid))

        # 2. Timed events rewrite the effective cluster / workload knobs;
        # an armed measurement plane then probes the post-event truth.
        _advance_world(fleet, sc, tick)
        if plane is not None:
            plane.observe(fleet, ctl, tick)

        # 3. Place arrivals (after events: admission sees drained capacity).
        # Overload + utility: arrivals (and retry-eligible deferred apps)
        # route through the admission gate instead — admitted apps land at
        # their priced tier, deferred ones stay out of the resident world.
        arrivals = np.where(np.asarray(valid) & ~prev_valid)[0]
        gated = ctl is not None and sc.overload and utility
        if gated:
            x0 = _admit_arrivals(fleet, ctl, pending, arrivals, tick,
                                 overload_counters)
            fleet.cluster = dataclasses.replace(
                fleet.cluster,
                problem=fleet.cluster.problem.with_assignment0(
                    jnp.asarray(x0)))
        elif arrivals.size:
            x0 = place_arrivals(fleet, arrivals)
            fleet.cluster = dataclasses.replace(
                fleet.cluster,
                problem=fleet.cluster.problem.with_assignment0(
                    jnp.asarray(x0)))

        # 4. Controller decides; the applied mapping becomes assignment0.
        if ctl is not None and sc.overload:
            # Overload runs (utility AND binary twin) share one transplant
            # path so both are scored identically: the controller plans on
            # the resident view (deferred apps held out; empty for the
            # binary twin), committed moves transplant onto the offered
            # world, and the accountant scores the *served* world —
            # resident apps at their shed caps.
            pending_mask = np.zeros(np.asarray(valid).size, bool)
            if pending:
                pending_mask[list(pending)] = True
            r_valid = np.asarray(fleet.cluster.problem.valid) & ~pending_mask
            view = _resident_view(fleet.cluster, r_valid)
            x_before = np.asarray(view.problem.assignment0)
            if has_chaos:
                observed = _observe(fleet, observed, tick, view=view)
                _apply_fault_windows(ctl, fleet, tick, base_cfg)
                evr = ctl.step(TickInput(
                    cluster=observed, now=tick,
                    collected_at=observed.collected_at))
            else:
                evr = ctl.step(TickInput(cluster=view, now=tick))
            if evr.applied:
                committed = np.asarray(ctl.cluster.problem.assignment0)
                fleet.cluster = dataclasses.replace(
                    fleet.cluster,
                    problem=fleet.cluster.problem.with_assignment0(
                        jnp.asarray(committed)))
            caps_vec = None
            if (utility and ctl.shedder is not None
                    and ctl.shedder.caps is not None):
                caps_vec = np.asarray(ctl.shedder.caps, np.float32)
            served = _resident_view(fleet.cluster, r_valid)
            if caps_vec is not None and np.any(caps_vec < 1.0):
                served = dataclasses.replace(
                    served, problem=dataclasses.replace(
                        served.problem,
                        demand=served.problem.demand
                        * jnp.asarray(caps_vec)[:, None]))
            unsafe = 0
            if evr.applied and has_chaos:
                # Safety judged against the served true world: with caps
                # actuated, that is what the moves actually land on.
                unsafe = count_unsafe_moves(served.problem, x_before,
                                            committed)
            ustats = utility_stats(fleet.cluster.problem, curves,
                                   caps=caps_vec, pending=pending_mask)
            stat = acct.observe(
                served, moved=evr.moved if evr.applied else 0,
                applied=evr.applied, triggered=evr.triggered,
                solve_s=evr.time_s,
                movement_cost=evr.movement_cost if evr.applied else 0.0,
                budget_limited=evr.budget_limited, unsafe_moves=unsafe,
                mode=evr.mode, health_score=evr.health_score,
                utility=ustats, shed_capped_apps=evr.shed_active,
                shed_churn=evr.shed_churn)
        elif ctl is not None and has_chaos:
            # Chaos: the controller plans on the *observed* channel (frozen
            # or corrupted telemetry) while the accountant scores the true
            # cluster.  Committed moves transplant back onto the truth —
            # placement is an action, not a reading — and every applied
            # move is checked for true-world safety.
            observed = _observe(fleet, observed, tick)
            _apply_fault_windows(ctl, fleet, tick, base_cfg)
            x_before = np.asarray(fleet.cluster.problem.assignment0)
            evr = ctl.step(TickInput(cluster=observed, now=tick,
                                     collected_at=observed.collected_at))
            unsafe = 0
            if evr.applied:
                committed = np.asarray(ctl.cluster.problem.assignment0)
                unsafe = count_unsafe_moves(fleet.cluster.problem,
                                            x_before, committed)
                fleet.cluster = dataclasses.replace(
                    fleet.cluster,
                    problem=fleet.cluster.problem.with_assignment0(
                        jnp.asarray(committed)))
            stat = acct.observe(
                fleet.cluster, moved=evr.moved if evr.applied else 0,
                applied=evr.applied, triggered=evr.triggered,
                solve_s=evr.time_s,
                movement_cost=evr.movement_cost if evr.applied else 0.0,
                budget_limited=evr.budget_limited, unsafe_moves=unsafe,
                mode=evr.mode, health_score=evr.health_score)
        elif ctl is not None:
            x_before = np.asarray(fleet.cluster.problem.assignment0)
            evr = ctl.step(TickInput(cluster=fleet.cluster, now=tick))
            fleet.cluster = ctl.cluster
            exceeding = 0
            if plane is not None and evr.applied:
                exceeding = plane.budget_exceeding(
                    fleet, x_before,
                    np.asarray(fleet.cluster.problem.assignment0), tick)
            stat = acct.observe(
                fleet.cluster, moved=evr.moved if evr.applied else 0,
                applied=evr.applied, triggered=evr.triggered,
                solve_s=evr.time_s,
                movement_cost=evr.movement_cost if evr.applied else 0.0,
                budget_limited=evr.budget_limited,
                mode=evr.mode, health_score=evr.health_score,
                budget_exceeding_moves=exceeding)
        else:
            stat = acct.observe(fleet.cluster)
        if verbose:
            mode = f" [{stat.mode}]" if stat.mode != "normal" else ""
            print(f"  t={tick:4d} live={stat.live_apps:5d} "
                  f"d2b={stat.d2b:.3f} slo_viol={stat.slo_violating_apps:4d} "
                  f"over_ideal={stat.over_ideal_tiers}{mode} "
                  f"{'MOVED ' + str(stat.moved) if stat.applied else ''}")

    report = acct.report(sc.name, policy)
    report.extra.update(
        solver_retraces=local_search_trace_count() - solver_traces0,
        workload_retraces=workload_trace_count() - wl_traces0,
        num_apps=sc.num_apps, pool=sc.max_apps)
    if ctl is not None:
        report.extra.update(
            audit=ctl.audit(),
            levels=list(ctl.config.coop.levels or DEFAULT_LEVELS),
            # The budget the controller actually enforced — a caller-pinned
            # config budget overrides the scenario default, and recording
            # the scenario's number instead would misgrade within_budget.
            move_budget=ctl.config.movement_cost_budget,
            anticipation=bool(anticipation and fleet.declared_events))
    if ctl is not None and sc.overload:
        report.extra.update(
            infeasible_admissions=overload_counters["infeasible_admissions"],
            deferred_backlog=len(pending))
    if plane is not None:
        report.extra.update(netlat=plane.extra())
        plane.close()
    return report


def run_pair(sc: Scenario, *, config: ControllerConfig | None = None,
             anticipation: bool = True, verbose: bool = False) -> dict:
    """Baseline + controller over the same trajectory, plus the comparison
    record (the per-scenario entry in BENCH_sim.json)."""
    baseline = run_scenario(sc, policy="static", verbose=verbose)
    balanced = run_scenario(sc, policy="balanced", config=config,
                            anticipation=anticipation, verbose=verbose)
    return {
        "baseline": baseline,
        "balanced": balanced,
        "compare": compare(baseline, balanced),
    }


def run_overload_pair(sc: Scenario, *,
                      config: ControllerConfig | None = None,
                      verbose: bool = False) -> dict:
    """An overload scenario two ways over the same trajectory: the
    binary-SLO baseline controller (no curves, no admission, no shedding)
    and the utility-armed control plane.  The ``overload`` record is the
    scorecard the regression gate pins (delivered-utility improvement > 1,
    zero infeasible admissions, budgets held)."""
    binary_cfg = (dataclasses.replace(config, shed=None)
                  if config is not None else None)
    binary = run_scenario(sc, policy="balanced", config=binary_cfg,
                          utility=False, verbose=verbose)
    armed = run_scenario(sc, policy="balanced", config=config,
                         utility=True, verbose=verbose)
    return {
        "binary": binary,
        "utility": armed,
        "overload": overload_compare(binary, armed),
    }


def strip_chaos(sc: Scenario) -> Scenario:
    """The fault-free oracle twin of a chaos scenario: same seed, same
    workload process, same cluster events — only the control-plane faults
    removed.  Both runs draw flash-crowd targets from the same ``rng``
    stream (chaos consumes ``chaos_rng``, never ``rng``), so the
    trajectories are bit-identical up to the controller's decisions."""
    events = tuple(e for e in sc.events
                   if not isinstance(e, ControlPlaneFault))
    return dataclasses.replace(sc, events=events, chaos=False)


def run_chaos_pair(sc: Scenario, *, config: ControllerConfig | None = None,
                   verbose: bool = False) -> dict:
    """A chaos scenario three ways: degraded (faults live), oracle (faults
    stripped, same trajectory), and the static baseline.  The ``chaos``
    record is the degraded-vs-oracle scorecard the regression gate pins
    (zero unsafe moves, bounded violation ratio, recovery to NORMAL)."""
    cfg = config or CHAOS_CONTROLLER
    oracle_sc = strip_chaos(sc)
    degraded = run_scenario(sc, policy="balanced", config=cfg,
                            verbose=verbose)
    oracle = run_scenario(oracle_sc, policy="balanced", config=cfg,
                          verbose=verbose)
    baseline = run_scenario(oracle_sc, policy="static", verbose=verbose)
    return {
        "degraded": degraded,
        "oracle": oracle,
        "baseline": baseline,
        "chaos": chaos_compare(degraded, oracle),
        "compare": compare(baseline, degraded),
    }


def run_netlat_pair(sc: Scenario, *, config: ControllerConfig | None = None,
                    verbose: bool = False) -> dict:
    """A network_degraded scenario two ways over the same trajectory: the
    static-budget stack (region+host, the hard-coded 36 ms constant) and
    the measured stack (netlat+host, per-pair budgets calibrated from the
    sketch bank).  Both runs arm the measurement plane — the static twin
    collects the same measurements so its budget-exceeding moves are
    counted against the same live budgets — but only the measured twin's
    controller binds the latency-SLO level.  The ``netlat`` record is the
    scorecard the regression gate pins (p99 integral ratio < 1, zero
    measured-stack budget-exceeding moves)."""
    from repro.sim.slo import netlat_compare
    base = config or SIM_CONTROLLER
    measured_cfg = dataclasses.replace(
        base, coop=dataclasses.replace(base.coop, levels=("netlat", "host")))
    static = run_scenario(sc, policy="balanced", config=base, netlat=True,
                          verbose=verbose)
    measured = run_scenario(sc, policy="balanced", config=measured_cfg,
                            netlat=True, verbose=verbose)
    return {
        "static": static,
        "measured": measured,
        "netlat": netlat_compare(static, measured),
    }


# -- streaming service adapter ---------------------------------------------

def run_scenario_service(sc: Scenario, *,
                         config: ControllerConfig | None = None,
                         anticipation: bool = True,
                         num_shards: int = 4,
                         verbose: bool = False) -> SimReport:
    """Replay a scenario as an *event stream* through the ServiceLoop.

    The world evolves exactly as in ``run_scenario`` (same workload state,
    same timed events, same greedy arrival placement — the trajectories are
    bit-identical up to the controller's decisions), but the controller
    never sees the cluster directly: every change reaches it as a typed
    ``ServiceEvent`` (telemetry deltas for drifted demand, capacity updates
    for timed events, arrivals/departures for churn, one advisory batch at
    t=0), and the drift detector decides per tick whether to pay for a
    solve at all.  The accountant scores the same served world as the
    lockstep run; the loop's operational counters ride
    ``report.extra["service"]``.

    Chaos and overload scenarios are out of scope here — they need the
    observed-channel / resident-view machinery (``run_scenario``), not an
    event replay.
    """
    if sc.overload or sc.chaos:
        raise ValueError("service replay supports plain scenarios only")
    from repro.service import ServiceConfig, ServiceLoop
    from repro.service.events import (AdvisoryBatch, AppArrival, AppDeparture,
                                      CapacityUpdate, LatencyDelta,
                                      TelemetryDelta)

    fleet = build_fleet(sc)
    cfg = config or SIM_CONTROLLER
    if sc.move_budget is not None and cfg.movement_cost_budget is None:
        cfg = dataclasses.replace(cfg, movement_cost_budget=sc.move_budget)
    if sc.shards is not None and cfg.shards is None:
        cfg = dataclasses.replace(cfg, shards=sc.shards)
    # Delta solves partition at this count; full passes keep the engine the
    # lockstep run would use (global unless the scenario/config shards it).
    shards = cfg.shards or num_shards
    ctl = BalanceController(fleet.cluster, cfg)
    loop = ServiceLoop(controller=ctl,
                       config=ServiceConfig(num_shards=shards))
    if anticipation and fleet.declared_events:
        loop.submit(AdvisoryBatch(advisories=tuple(fleet.declared_events)))

    acct = SloAccountant()
    solver_traces0 = local_search_trace_count()
    wl_traces0 = workload_trace_count()
    p0 = fleet.cluster.problem
    prev_demand = np.asarray(p0.demand, np.float64).copy()
    prev_tasks = np.asarray(p0.tasks, np.float64).copy()
    prev_cap = np.asarray(p0.capacity, np.float64).copy()
    prev_klim = np.asarray(p0.task_limit, np.float64).copy()
    prev_slo_ok = np.asarray(p0.slo_allowed, bool).copy()
    prev_lat = np.asarray(fleet.cluster.region_latency).copy()
    prev_hosts = np.asarray(fleet.cluster.hosts_per_tier).copy()

    for tick in range(sc.ticks):
        fleet.wl, demand, tasks, valid = workload_step(fleet.wl_cfg, fleet.wl)
        prev_valid = np.asarray(fleet.cluster.problem.valid)
        fleet.cluster = dataclasses.replace(
            fleet.cluster,
            problem=dataclasses.replace(
                fleet.cluster.problem, demand=demand, tasks=tasks,
                valid=valid))
        _advance_world(fleet, sc, tick)
        valid_np = np.asarray(fleet.cluster.problem.valid)
        arrivals = np.where(valid_np & ~prev_valid)[0]
        if arrivals.size:
            x0 = place_arrivals(fleet, arrivals)
            fleet.cluster = dataclasses.replace(
                fleet.cluster,
                problem=fleet.cluster.problem.with_assignment0(
                    jnp.asarray(x0)))

        # The world's changes, re-expressed as events.
        p = fleet.cluster.problem
        cap = np.asarray(p.capacity, np.float64)
        klim = np.asarray(p.task_limit, np.float64)
        slo_ok = np.asarray(p.slo_allowed, bool)
        lat = np.asarray(fleet.cluster.region_latency)
        hosts = np.asarray(fleet.cluster.hosts_per_tier)
        changed = {}
        if not np.array_equal(cap, prev_cap):
            changed["capacity"] = cap.copy()
        if not np.array_equal(klim, prev_klim):
            changed["task_limit"] = klim.copy()
        if not np.array_equal(slo_ok, prev_slo_ok):
            changed["slo_allowed"] = slo_ok.copy()
        if not np.array_equal(lat, prev_lat):
            changed["region_latency"] = lat.copy()
        if not np.array_equal(hosts, prev_hosts):
            changed["hosts_per_tier"] = hosts.copy()
        if set(changed) == {"region_latency"}:
            # Network weather only: a LatencyDelta keeps the delta path
            # open (a breach dirties just the affected apps' shards),
            # where a CapacityUpdate would force a fleet-wide full pass.
            loop.submit(LatencyDelta(region_latency=lat.copy(),
                                     collected_at=tick))
        elif changed:
            loop.submit(CapacityUpdate(**changed))
        prev_cap, prev_klim, prev_slo_ok = cap, klim, slo_ok
        prev_lat, prev_hosts = lat, hosts

        x0_np = np.asarray(p.assignment0)
        dem = np.asarray(p.demand, np.float64)
        tsk = np.asarray(p.tasks, np.float64)
        slo_np = np.asarray(p.slo)
        crit_np = np.asarray(p.criticality)
        for n in arrivals:
            loop.submit(AppArrival(
                app_id=int(n), demand=dem[n].copy(), tasks=float(tsk[n]),
                slo=int(slo_np[n]), criticality=float(crit_np[n]),
                tier=int(x0_np[n])))
        for n in np.where(prev_valid & ~valid_np)[0]:
            loop.submit(AppDeparture(app_id=int(n)))
        moved_mask = valid_np & prev_valid & (
            np.any(dem != prev_demand, axis=1) | (tsk != prev_tasks))
        ids = np.where(moved_mask)[0]
        if ids.size:
            loop.submit(TelemetryDelta(
                app_ids=tuple(int(n) for n in ids),
                demand=dem[ids].copy(), tasks=tsk[ids].copy(),
                collected_at=tick))
        prev_demand, prev_tasks = dem.copy(), tsk.copy()

        out = loop.step(tick)
        res = out.result
        if res is not None and res.applied:
            fleet.cluster = dataclasses.replace(
                fleet.cluster,
                problem=fleet.cluster.problem.with_assignment0(
                    jnp.asarray(np.asarray(
                        ctl.cluster.problem.assignment0))))
        stat = acct.observe(
            fleet.cluster,
            moved=res.moved if res is not None and res.applied else 0,
            applied=res is not None and res.applied,
            triggered=res is not None and res.triggered,
            solve_s=out.latency_s if res is not None else 0.0,
            movement_cost=(res.movement_cost
                           if res is not None and res.applied else 0.0),
            budget_limited=res is not None and res.budget_limited)
        if verbose:
            print(f"  t={tick:4d} {out.action:5s} live={stat.live_apps:5d} "
                  f"d2b={stat.d2b:.3f} slo_viol={stat.slo_violating_apps:4d} "
                  f"{out.reason}")

    report = acct.report(sc.name, "service")
    report.extra.update(
        solver_retraces=local_search_trace_count() - solver_traces0,
        workload_retraces=workload_trace_count() - wl_traces0,
        num_apps=sc.num_apps, pool=sc.max_apps,
        audit=ctl.audit(), service=loop.stats())
    return report


def run_service_pair(sc: Scenario, *,
                     config: ControllerConfig | None = None,
                     verbose: bool = False) -> dict:
    """The same trajectory twice — lockstep controller vs event-driven
    service — plus the ``service`` scorecard the regression gate pins
    (quality within tolerance of lockstep, >= 30% fewer full cooperate
    passes, zero dropped events)."""
    from repro.sim.slo import service_compare
    lockstep = run_scenario(sc, policy="balanced", config=config,
                            verbose=verbose)
    service = run_scenario_service(sc, config=config, verbose=verbose)
    return {
        "lockstep": lockstep,
        "service": service,
        "service_compare": service_compare(lockstep, service),
    }
