"""SLO accounting over a simulated trajectory (§3.3 decision evaluation).

The paper's decision-evaluation stage emits per-decision metrics; Henge
(arXiv 1802.00082) argues stream schedulers should be scored on *intent/SLO
satisfaction over time* under dynamic load.  This module does both: every
tick it scores the cluster the controller left behind, and the accumulated
``SimReport`` is the trajectory-level scorecard the benchmarks persist.

Per-tick signals:
  * ``slo_violating_apps`` — live apps currently placed on a tier that is
    not eligible for their SLO class (constraint 4 read as a *state*, not a
    move filter: outages/drains can strand incumbents on newly-ineligible
    tiers),
  * ``over_ideal_tiers`` / ``over_capacity_tiers`` — tiers above their
    ideal utilization (goal 5) / hard capacity (constraint 1) on any
    resource or on task count,
  * ``d2b`` — difference-to-balance (Fig. 5 y-axis) as a time series,
  * ``moved`` / ``applied`` / ``solve_s`` — movement (the downtime proxy,
    goal 8) and solver wall-clock attributable to the controller.

Totals integrate over ticks: an app stranded for 10 ticks costs 10
app-ticks — reacting late is worse than reacting small.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core.hierarchy import REGION_LATENCY_BUDGET_MS, RegionScheduler
from repro.core.levels import SHARD_MIN_AFFINITY
from repro.core.problem import Problem, utilization_fraction
from repro.core.telemetry import ClusterState, shard_affinity_of
from repro.core.utility import (delivered_fractions, oracle_utility,
                                utility_of)

# Slack on the over-ideal / over-capacity tests so float noise at exactly
# the ideal line does not count as a violation tick.
EPS = 1e-3


@dataclasses.dataclass
class TickStats:
    tick: int
    live_apps: int
    d2b: float
    slo_violating_apps: int
    over_ideal_tiers: int
    over_capacity_tiers: int
    # Severity-weighted over-ideal: sum over tiers of the worst-resource
    # excess above ideal.  The tier *count* saturates (a 10x-hot tier and a
    # 1.01x one both count 1); the excess integral is what goal 5 actually
    # minimizes.
    over_ideal_excess: float = 0.0
    moved: int = 0
    applied: bool = False
    triggered: bool = False
    solve_s: float = 0.0
    # Priced reconfiguration cost the controller actually spent this tick
    # (core.planner.move_costs units: mean live app == 1.0), and whether
    # the movement budget bound the round (trimmed or blocked movement).
    movement_cost: float = 0.0
    budget_limited: bool = False
    # Live apps placed beyond the strict region latency budget — the
    # maintenance placement mode's bounded degradation, surfaced so the
    # relaxed-evacuation tradeoff is priced, never silent.
    region_breach_apps: int = 0
    # p99 network latency of the standing placement (the Fig. 4 spill
    # model read as a state, ``core.metrics.placement_p99_ms``) — what the
    # measured-latency control plane is scored on.
    network_p99_ms: float = 0.0
    # Moves committed this tick whose destination exceeded its *measured*
    # live p99 budget (netlat runs only; the gate pins the measured stack
    # to zero).
    budget_exceeding_moves: int = 0
    # Live apps placed on a tier holding less than the shard locality
    # level's minimum of their data-shard mass (every window/join reads
    # remote state) — what the shard_skew scenario's third level protects.
    shard_misplaced_apps: int = 0
    # Degraded-mode accounting (chaos scenarios): moves the controller
    # committed this tick whose *true* destination was SLO-ineligible or
    # over hard capacity (the controller planned them on faulted
    # telemetry), plus the operating mode / composite health score the
    # controller reported.
    unsafe_moves: int = 0
    mode: str = "normal"
    health_score: float = 1.0
    # Overload accounting (overload scenarios only; all zero elsewhere):
    # delivered fleet utility this tick vs the fractional-knapsack oracle
    # and the all-served maximum, apps deferred at the admission gate, apps
    # under a shed cap, and cap transitions executed this tick.
    delivered_utility: float = 0.0
    oracle_utility: float = 0.0
    max_utility: float = 0.0
    deferred_apps: int = 0
    shed_capped_apps: int = 0
    shed_churn: int = 0


def score_cluster(problem: Problem) -> dict:
    """The assignment-state signals for one tick (on ``assignment0`` — the
    placement actually in effect after this tick's control action)."""
    x = problem.assignment0
    slo_ok = np.asarray(problem.slo_allowed)[
        np.asarray(x), np.asarray(problem.slo)]
    valid = np.asarray(problem.valid)
    uf, tf = utilization_fraction(problem, x)
    uf, tf = np.asarray(uf), np.asarray(tf)
    ideal = np.asarray(problem.ideal_frac)
    ideal_t = np.asarray(problem.ideal_task_frac)
    over_ideal = np.any(uf > ideal + EPS, axis=1) | (tf > ideal_t + EPS)
    over_cap = np.any(uf > 1.0 + EPS, axis=1) | (tf > 1.0 + EPS)
    excess = np.maximum(np.max(uf - ideal, axis=1),
                        tf - ideal_t).clip(min=0.0)
    return {
        "live_apps": int(valid.sum()),
        "slo_violating_apps": int(np.sum(~slo_ok & valid)),
        "over_ideal_tiers": int(over_ideal.sum()),
        "over_capacity_tiers": int(over_cap.sum()),
        "over_ideal_excess": float(excess.sum()),
        "d2b": float(M.difference_to_balance(problem, x)),
    }


def utility_stats(problem: Problem, curves, *, caps=None,
                  pending=None) -> dict:
    """One tick's delivered-utility accounting (overload scenarios).

    ``problem`` is the *offered* world: ``valid`` includes apps the
    admission gate is holding out (``pending``), demand is uncapped.
    ``curves`` is the (knee, slope, weight) triple scoring is done under —
    explicit, so the binary-baseline run is scored on the same utility
    definition its controller never saw.  ``caps`` are the shedder's
    delivery caps.  Deferred apps deliver 0 and earn ``u(0)``; the oracle
    is priced on the full offered demand (deferred apps included — turning
    one away is a *choice* the oracle gets to disagree with).
    """
    knee, slope, weight = (np.asarray(c, np.float32) for c in curves)
    valid = np.asarray(problem.valid, bool)
    pending = (np.zeros_like(valid) if pending is None
               else np.asarray(pending, bool)) & valid
    resident = valid & ~pending
    p_curved = dataclasses.replace(
        problem, util_knee=jnp.asarray(knee), util_slope=jnp.asarray(slope),
        util_weight=jnp.asarray(weight))
    p_resident = dataclasses.replace(
        p_curved, valid=jnp.asarray(resident),
        demand=p_curved.demand
        * jnp.asarray(resident, p_curved.demand.dtype)[:, None])
    delivered = np.asarray(delivered_fractions(
        p_resident, p_resident.assignment0, caps))
    u = np.asarray(utility_of(jnp.asarray(delivered), jnp.asarray(knee),
                              jnp.asarray(slope), jnp.asarray(weight)))
    return {
        "delivered_utility": float(np.sum(u * valid)),
        "oracle_utility": oracle_utility(p_curved),
        "max_utility": float(np.sum(weight * valid)),
        "deferred_apps": int(pending.sum()),
    }


def count_unsafe_moves(problem: Problem, x_before, x_after) -> int:
    """Moves from ``x_before`` to ``x_after`` whose destination is unsafe
    *in this problem's (true) world*: an SLO-ineligible tier, or a tier
    over hard capacity under the true demand after the moves land.

    A controller planning on healthy telemetry cannot commit these (the
    solver enforces both as hard constraints on the view it sees); under a
    telemetry fault the view and the world diverge, and this is the metric
    that prices the divergence.  The degraded-mode machinery exists to
    keep it at zero — the chaos gates pin it there.
    """
    x0 = np.asarray(x_before, np.int64)
    x1 = np.asarray(x_after, np.int64)
    valid = np.asarray(problem.valid, bool)
    moved = np.where((x0 != x1) & valid)[0]
    if moved.size == 0:
        return 0
    slo_ok = np.asarray(problem.slo_allowed)[
        x1[moved], np.asarray(problem.slo)[moved]]
    uf, tf = utilization_fraction(problem, x1)
    over_cap = (np.max(np.asarray(uf), axis=-1) > 1.0 + EPS) | (
        np.asarray(tf) > 1.0 + EPS)
    return int(np.sum(~slo_ok | over_cap[x1[moved]]))


class SloAccountant:
    """Accumulates per-tick stats; ``report`` freezes them into a SimReport."""

    def __init__(self):
        self.ticks: list[TickStats] = []

    def observe(self, cluster: ClusterState, *, moved: int = 0,
                applied: bool = False, triggered: bool = False,
                solve_s: float = 0.0, movement_cost: float = 0.0,
                budget_limited: bool = False, unsafe_moves: int = 0,
                mode: str = "normal", health_score: float = 1.0,
                utility: dict | None = None, shed_capped_apps: int = 0,
                shed_churn: int = 0,
                budget_exceeding_moves: int = 0) -> TickStats:
        s = score_cluster(cluster.problem)
        p = cluster.problem
        worst = RegionScheduler(cluster)._worst_ms   # memoized on the cluster
        x = np.asarray(p.assignment0)
        valid = np.asarray(p.valid)
        breach = (worst[cluster.app_region, x] > REGION_LATENCY_BUDGET_MS)
        # Shard co-location is scored for every policy (the static baseline
        # included): the affinity matrix is memoized on the cluster, and a
        # placement below the bar is remote-state I/O whether or not the
        # controller ran a shard level.
        aff = shard_affinity_of(cluster)
        misplaced = aff[np.arange(x.size), x] < SHARD_MIN_AFFINITY
        stat = TickStats(tick=len(self.ticks), moved=moved, applied=applied,
                         triggered=triggered, solve_s=solve_s,
                         movement_cost=movement_cost,
                         budget_limited=budget_limited,
                         region_breach_apps=int(np.sum(breach & valid)),
                         shard_misplaced_apps=int(np.sum(misplaced & valid)),
                         unsafe_moves=unsafe_moves, mode=mode,
                         health_score=health_score,
                         shed_capped_apps=shed_capped_apps,
                         shed_churn=shed_churn,
                         network_p99_ms=M.placement_p99_ms(cluster),
                         budget_exceeding_moves=budget_exceeding_moves,
                         **(utility or {}), **s)
        self.ticks.append(stat)
        return stat

    def report(self, scenario: str, policy: str) -> "SimReport":
        return SimReport(scenario=scenario, policy=policy, ticks=self.ticks)


@dataclasses.dataclass
class SimReport:
    """Trajectory scorecard: what BENCH_sim.json persists per (scenario,
    policy) and what tests assert margins on."""

    scenario: str
    policy: str
    ticks: list[TickStats]
    extra: dict = dataclasses.field(default_factory=dict)

    def summary(self) -> dict:
        ts = self.ticks
        d2b = np.array([t.d2b for t in ts]) if ts else np.zeros(1)
        slo_ticks = sum(t.slo_violating_apps for t in ts)
        over_ideal = sum(t.over_ideal_tiers for t in ts)
        return {
            "scenario": self.scenario,
            "policy": self.policy,
            "ticks": len(ts),
            # app-ticks on an ineligible tier + tier-ticks over ideal: the
            # combined SLO-violation integral the acceptance margin uses.
            "slo_violation_ticks": slo_ticks + over_ideal,
            "slo_violating_app_ticks": slo_ticks,
            "over_ideal_tier_ticks": over_ideal,
            "over_capacity_tier_ticks": sum(
                t.over_capacity_tiers for t in ts),
            "over_ideal_excess_integral": float(sum(
                t.over_ideal_excess for t in ts)),
            "total_moves": sum(t.moved for t in ts if t.applied),
            # Movement priced, not just counted (Madsen-style downtime
            # accounting), plus the ticks the budget bound the controller.
            "movement_cost": round(sum(
                t.movement_cost for t in ts if t.applied), 4),
            "budget_overruns": sum(1 for t in ts if t.budget_limited),
            "region_breach_app_ticks": sum(
                t.region_breach_apps for t in ts),
            "shard_misplaced_app_ticks": sum(
                t.shard_misplaced_apps for t in ts),
            # The latency-SLO scorecard: the placement-p99 integral (ms x
            # ticks — holding a degraded placement for 10 ticks costs 10x
            # its excess) and the worst tick.
            "network_p99_integral": float(sum(
                t.network_p99_ms for t in ts)),
            "peak_network_p99_ms": float(max(
                (t.network_p99_ms for t in ts), default=0.0)),
            "budget_exceeding_moves": sum(
                t.budget_exceeding_moves for t in ts),
            "rebalances": sum(1 for t in ts if t.applied),
            "triggers": sum(1 for t in ts if t.triggered),
            # Degraded-mode accounting: unsafe moves committed on faulted
            # telemetry, and ticks spent per operating mode (a fault-free
            # run reads {"normal": ticks}).
            "unsafe_moves": sum(t.unsafe_moves for t in ts),
            "mode_ticks": {m: sum(1 for t in ts if t.mode == m)
                           for m in dict.fromkeys(t.mode for t in ts)},
            "mean_d2b": float(d2b.mean()),
            "peak_d2b": float(d2b.max()),
            "final_d2b": float(d2b[-1]),
            "solver_time_s": float(sum(t.solve_s for t in ts)),
            **self._utility_summary(),
            **self.extra,
        }

    def _utility_summary(self) -> dict:
        """Overload-run keys: present only when utility was accounted."""
        ts = self.ticks
        if not any(t.oracle_utility > 0 for t in ts):
            return {}
        du = float(sum(t.delivered_utility for t in ts))
        ou = float(sum(t.oracle_utility for t in ts))
        mu = float(sum(t.max_utility for t in ts))
        return {
            "delivered_utility_integral": round(du, 4),
            "oracle_utility_integral": round(ou, 4),
            # The headline: what fraction of the oracle's achievable fleet
            # utility the policy actually delivered over the trajectory.
            "delivered_utility_ratio": round(du / max(ou, 1e-9), 6),
            "utility_vs_max": round(du / max(mu, 1e-9), 6),
            "deferred_app_ticks": sum(t.deferred_apps for t in ts),
            "shed_capped_app_ticks": sum(t.shed_capped_apps for t in ts),
            "shed_churn_events": sum(t.shed_churn for t in ts),
        }

    def series(self) -> dict:
        """Per-tick time series (for BENCH_sim.json / plotting)."""
        return {
            "d2b": [round(t.d2b, 4) for t in self.ticks],
            "network_p99_ms": [round(t.network_p99_ms, 1)
                               for t in self.ticks],
            "slo_violating_apps": [t.slo_violating_apps for t in self.ticks],
            "over_ideal_tiers": [t.over_ideal_tiers for t in self.ticks],
            "live_apps": [t.live_apps for t in self.ticks],
            "moved": [t.moved if t.applied else 0 for t in self.ticks],
            "movement_cost": [round(t.movement_cost, 3) if t.applied else 0.0
                              for t in self.ticks],
            "mode": [t.mode for t in self.ticks],
            "health_score": [round(t.health_score, 3) for t in self.ticks],
            **({"delivered_utility": [round(t.delivered_utility, 3)
                                      for t in self.ticks],
                "oracle_utility": [round(t.oracle_utility, 3)
                                   for t in self.ticks],
                "deferred_apps": [t.deferred_apps for t in self.ticks],
                "shed_capped_apps": [t.shed_capped_apps
                                     for t in self.ticks]}
               if any(t.oracle_utility > 0 for t in self.ticks) else {}),
        }


def compare(baseline: SimReport, balanced: SimReport) -> dict:
    """Controller-vs-static deltas: the numbers the acceptance asserts."""
    b, c = baseline.summary(), balanced.summary()

    def ratio(key):
        # None (JSON null) when the baseline integral is 0 but the balanced
        # run is not — json.dump would otherwise emit a bare ``Infinity``,
        # which is not valid JSON.
        if b[key] > 0:
            return c[key] / b[key]
        return 1.0 if c[key] == 0 else None

    return {
        "slo_violation_ticks": {"baseline": b["slo_violation_ticks"],
                                "balanced": c["slo_violation_ticks"],
                                "ratio": ratio("slo_violation_ticks")},
        "over_ideal_tier_ticks": {"baseline": b["over_ideal_tier_ticks"],
                                  "balanced": c["over_ideal_tier_ticks"],
                                  "ratio": ratio("over_ideal_tier_ticks")},
        "over_ideal_excess_integral": {
            "baseline": b["over_ideal_excess_integral"],
            "balanced": c["over_ideal_excess_integral"],
            "ratio": ratio("over_ideal_excess_integral")},
        "mean_d2b": {"baseline": b["mean_d2b"], "balanced": c["mean_d2b"],
                     "ratio": (c["mean_d2b"] / b["mean_d2b"]
                               if b["mean_d2b"] > 0 else 1.0)},
        "total_moves": c["total_moves"],
        "rebalances": c["rebalances"],
        "solver_time_s": c["solver_time_s"],
        # What the win cost: priced movement vs the scenario's downtime
        # budget (None = unbudgeted).  ``within_budget`` is the acceptance
        # bit the regression gate pins.
        "movement": {
            "cost": c["movement_cost"],
            "budget": c.get("move_budget"),
            "overrun_ticks": c["budget_overruns"],
            "within_budget": (c.get("move_budget") is None
                              or c["movement_cost"]
                              <= c["move_budget"] + 1e-6),
        },
        # Maintenance placement mode's bounded latency degradation, vs the
        # baseline's own breaches (normally 0) — priced, never silent.
        "region_breach_app_ticks": {"baseline": b["region_breach_app_ticks"],
                                    "balanced": c["region_breach_app_ticks"]},
        # Data-shard co-location held by the shard locality level: a
        # controller without it may fix balance by scattering apps away
        # from their state — this is the metric that would catch it.
        "shard_misplaced_app_ticks": {
            "baseline": b["shard_misplaced_app_ticks"],
            "balanced": c["shard_misplaced_app_ticks"],
            "ratio": ratio("shard_misplaced_app_ticks")},
    }


def overload_compare(binary: SimReport, utility: SimReport) -> dict:
    """Utility-policy vs binary-SLO baseline scorecard (overload family).

    Both runs rode the *same* trajectory and are scored on the same curves
    and the same fractional-knapsack oracle; the binary run's controller
    simply never saw them (no utility goal, no admission gate, no
    shedding).  ``improvement`` > 1 is the acceptance claim: graceful
    degradation delivers strictly more fleet utility than stranding
    whoever sits on the saturated tier.
    """
    b, u = binary.summary(), utility.summary()
    b_ratio = float(b.get("delivered_utility_ratio", 0.0))
    u_ratio = float(u.get("delivered_utility_ratio", 0.0))
    u_audit = u.get("audit", {})
    return {
        "delivered_utility_ratio": {
            "binary": round(b_ratio, 6),
            "utility": round(u_ratio, 6),
            "improvement": round(u_ratio / max(b_ratio, 1e-9), 6)},
        "utility_vs_max": {"binary": b.get("utility_vs_max", 0.0),
                           "utility": u.get("utility_vs_max", 0.0)},
        "deferred_app_ticks": u.get("deferred_app_ticks", 0),
        "shed_capped_app_ticks": u.get("shed_capped_app_ticks", 0),
        # Flap metric the hysteresis is judged on: every cap transition is
        # churn somebody pays for.
        "shed_churn_events": u.get("shed_churn_events", 0),
        "shed_events": u_audit.get("shed_events", 0),
        "readmit_events": u_audit.get("readmit_events", 0),
        # Hard invariants (the regression gate pins both to 0): admissions
        # that did not actually fit, and movement-budget overruns.
        "infeasible_admissions": u.get("infeasible_admissions", 0),
        "budget_overruns": {"binary": b["budget_overruns"],
                            "utility": u["budget_overruns"]},
        "within_budget": {
            "binary": (b.get("move_budget") is None
                       or b["movement_cost"] <= b["move_budget"] + 1e-6),
            # The controller's lifetime cost_spent (audit movement_cost)
            # already includes shed-churn pricing on top of applied moves.
            "utility": (u.get("move_budget") is None
                        or u_audit.get("movement_cost", u["movement_cost"])
                        <= u["move_budget"] + 1e-6)},
        "admission": u_audit.get("admission", {}),
        "moves": {"binary": b["total_moves"], "utility": u["total_moves"]},
    }


def chaos_compare(degraded: SimReport, oracle: SimReport) -> dict:
    """Degraded-vs-oracle scorecard for a chaos scenario.

    ``degraded`` ran the scenario with its control-plane faults;
    ``oracle`` ran the *same trajectory* with the faults stripped (same
    seed, same workload, same cluster events — perfect telemetry and a
    healthy solver).  The gap is the price of flying blind; the gate
    asserts the degraded controller pays it in *held balance*, never in
    unsafe moves.
    """
    d, o = degraded.summary(), oracle.summary()
    audit = d.get("audit", {})
    transitions = audit.get("mode_transitions", [])
    degraded_ticks = sum(n for m, n in d["mode_ticks"].items()
                         if m != "normal")
    return {
        "unsafe_moves": d["unsafe_moves"],
        # Violation integral, degraded / oracle: how much SLO ground the
        # faults cost.  The max(1, ...) floor keeps a perfect oracle from
        # reading as an infinite ratio.
        "degraded_vs_oracle": {
            "degraded": d["slo_violation_ticks"],
            "oracle": o["slo_violation_ticks"],
            "ratio": d["slo_violation_ticks"]
            / max(1, o["slo_violation_ticks"])},
        "mode_ticks": d["mode_ticks"],
        "degraded_ticks": degraded_ticks,
        "mode_transitions": transitions,
        "modes_entered": sorted({t["to"] for t in transitions}),
        # Did the controller come back?  Final mode NORMAL after having
        # actually degraded (a run that never left NORMAL never proved
        # anything — the chaos tests assert degraded_ticks > 0 separately).
        "recovered": audit.get("mode", "normal") == "normal",
        "breaker_trips": audit.get("breaker_trips", 0),
        "telemetry_quarantined": audit.get("telemetry_quarantined", 0),
        "budget_overruns": d["budget_overruns"],
        "moves": {"degraded": d["total_moves"], "oracle": o["total_moves"]},
    }


def netlat_compare(static_budget: SimReport, measured: SimReport) -> dict:
    """Measured-budget stack vs the static-36 ms stack, same trajectory
    (network_degraded family).

    ``static_budget`` ran the default region+host stack (the hard-coded
    ``REGION_LATENCY_BUDGET_MS`` constant); ``measured`` ran netlat+host —
    per-pair budgets calibrated from the sketch bank's observed baseline,
    vetted against live p99 estimates.  The acceptance claim: the measured
    stack holds a strictly better placement-p99 integral (ratio < 1) while
    committing zero moves that exceed their live measured budget.
    """
    s, m = static_budget.summary(), measured.summary()
    nl = measured.extra.get("netlat", {})

    def ratio(key):
        if s[key] > 0:
            return m[key] / s[key]
        return 1.0 if m[key] == 0 else None

    return {
        "network_p99_integral": {"static": s["network_p99_integral"],
                                 "measured": m["network_p99_integral"],
                                 "ratio": ratio("network_p99_integral")},
        "peak_network_p99_ms": {"static": s["peak_network_p99_ms"],
                                "measured": m["peak_network_p99_ms"]},
        # The hard invariant the gate pins to zero: the measured stack must
        # never commit a move whose destination exceeds its live budget.
        # The static stack's count is the contrast — how often the blind
        # constant let one through.
        "budget_exceeding_moves": {
            "static": s["budget_exceeding_moves"],
            "measured": m["budget_exceeding_moves"]},
        "slo_violation_ticks": {"static": s["slo_violation_ticks"],
                                "measured": m["slo_violation_ticks"],
                                "ratio": ratio("slo_violation_ticks")},
        "moves": {"static": s["total_moves"], "measured": m["total_moves"]},
        "movement_cost": {"static": s["movement_cost"],
                          "measured": m["movement_cost"]},
        "calibrated": bool(nl.get("calibrated", False)),
        "relax_factor": nl.get("relax_factor"),
        "quarantined_samples": nl.get("quarantined", 0),
    }


def service_compare(lockstep: SimReport, service: SimReport) -> dict:
    """Event-driven service vs the lockstep controller, same trajectory.

    Both runs evolved bit-identical worlds (same seeds, same events); the
    lockstep run evaluated the full trigger policy — and paid a full
    cooperate pass whenever it fired — every tick, while the service run
    replayed the trajectory as an event stream and let the drift detector
    decide.  The scorecard the regression gate pins: placement quality
    within tolerance of lockstep, >= 30% fewer full passes, zero dropped
    events.
    """
    ls, sv = lockstep.summary(), service.summary()
    stats = service.extra.get("service", {})

    def ratio(key):
        if ls[key] > 0:
            return sv[key] / ls[key]
        return 1.0 if sv[key] == 0 else None

    # Every lockstep trigger ran the full solver; the service's full passes
    # are counted directly by the loop.
    lockstep_full = int(ls["triggers"])
    service_full = int(stats.get("full_solves", 0))
    if lockstep_full > 0:
        reduction = 1.0 - service_full / lockstep_full
    else:
        reduction = 1.0 if service_full == 0 else 0.0
    return {
        "slo_violation_ticks": {"lockstep": ls["slo_violation_ticks"],
                                "service": sv["slo_violation_ticks"],
                                "ratio": ratio("slo_violation_ticks")},
        "over_ideal_excess_integral": {
            "lockstep": ls["over_ideal_excess_integral"],
            "service": sv["over_ideal_excess_integral"],
            "ratio": ratio("over_ideal_excess_integral")},
        "mean_d2b": {"lockstep": ls["mean_d2b"], "service": sv["mean_d2b"],
                     "ratio": (sv["mean_d2b"] / ls["mean_d2b"]
                               if ls["mean_d2b"] > 0 else 1.0)},
        "total_moves": {"lockstep": ls["total_moves"],
                        "service": sv["total_moves"]},
        "movement_cost": {"lockstep": ls["movement_cost"],
                          "service": sv["movement_cost"]},
        "full_passes": {"lockstep": lockstep_full, "service": service_full,
                        "reduction": round(reduction, 4)},
        "delta_solves": int(stats.get("delta_solves", 0)),
        "noop_ticks": int(stats.get("noop_ticks", 0)),
        "delta_fraction": round(float(stats.get("delta_fraction", 0.0)), 4),
        "dropped_events": int(stats.get("dropped_events", 0)),
        "delta_reverts": int(stats.get("delta_reverts", 0)),
        "events_applied": int(stats.get("events_applied", 0)),
    }
