"""Fleet simulator: dynamic workloads, scenario library, SLO accounting.

Evolves a cluster over hundreds of ticks and drives ``BalanceController``
through it — the trajectory-level evaluation (Henge-style SLO scoring,
reconfiguration cost under live load shifts) that a one-shot solve cannot
provide.  See ``sim.scenario`` for the registry and
``examples/simulate_fleet.py`` for the how-to.
"""
from repro.sim.events import (CapacityScale, ChurnRate, ControlPlaneFault,
                              FaultyLevel, FlashCrowd, FleetState,
                              JitterStorm, LevelFault, LinkDegrade,
                              LinkRestore, RegionOutage, RegionRestore,
                              ShardSkew, SolverBrownout, TelemetryBlackout,
                              TelemetryCorruption, TimedEvent,
                              faulty_hierarchy)
from repro.sim.harness import (CHAOS_CONTROLLER, SIM_CONTROLLER, build_fleet,
                               place_arrivals, run_chaos_pair,
                               run_netlat_pair, run_overload_pair, run_pair,
                               run_scenario, run_scenario_service,
                               run_service_pair, strip_chaos)
from repro.sim.scenario import (Scenario, get_scenario, list_scenarios,
                                scenario)
from repro.sim.slo import (SimReport, SloAccountant, TickStats, chaos_compare,
                           compare, count_unsafe_moves, netlat_compare,
                           overload_compare, service_compare, utility_stats)
from repro.sim.workload import (WorkloadConfig, WorkloadState,
                                inject_flash_crowd, make_workload_state,
                                set_churn_rates, workload_step,
                                workload_trace_count)

__all__ = [
    "CapacityScale", "ChurnRate", "ControlPlaneFault", "FaultyLevel",
    "FlashCrowd", "FleetState", "JitterStorm", "LevelFault", "LinkDegrade",
    "LinkRestore", "RegionOutage", "RegionRestore", "ShardSkew",
    "SolverBrownout", "TelemetryBlackout", "TelemetryCorruption",
    "TimedEvent", "faulty_hierarchy",
    "CHAOS_CONTROLLER", "SIM_CONTROLLER", "build_fleet", "place_arrivals",
    "run_chaos_pair", "run_netlat_pair", "run_overload_pair", "run_pair",
    "run_scenario", "run_scenario_service", "run_service_pair",
    "strip_chaos",
    "Scenario", "get_scenario", "list_scenarios", "scenario",
    "SimReport", "SloAccountant", "TickStats", "chaos_compare", "compare",
    "count_unsafe_moves", "netlat_compare", "overload_compare",
    "service_compare", "utility_stats",
    "WorkloadConfig", "WorkloadState", "inject_flash_crowd",
    "make_workload_state", "set_churn_rates", "workload_step",
    "workload_trace_count",
]
