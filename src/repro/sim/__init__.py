"""Fleet simulator: dynamic workloads, scenario library, SLO accounting.

Evolves a cluster over hundreds of ticks and drives ``BalanceController``
through it — the trajectory-level evaluation (Henge-style SLO scoring,
reconfiguration cost under live load shifts) that a one-shot solve cannot
provide.  See ``sim.scenario`` for the registry and
``examples/simulate_fleet.py`` for the how-to.
"""
from repro.sim.events import (CapacityScale, ChurnRate, FlashCrowd,
                              FleetState, RegionOutage, RegionRestore,
                              ShardSkew, TimedEvent)
from repro.sim.harness import (SIM_CONTROLLER, build_fleet, place_arrivals,
                               run_pair, run_scenario)
from repro.sim.scenario import (Scenario, get_scenario, list_scenarios,
                                scenario)
from repro.sim.slo import SimReport, SloAccountant, TickStats, compare
from repro.sim.workload import (WorkloadConfig, WorkloadState,
                                inject_flash_crowd, make_workload_state,
                                set_churn_rates, workload_step,
                                workload_trace_count)

__all__ = [
    "CapacityScale", "ChurnRate", "FlashCrowd", "FleetState", "RegionOutage",
    "RegionRestore", "ShardSkew", "TimedEvent",
    "SIM_CONTROLLER", "build_fleet", "place_arrivals", "run_pair",
    "run_scenario",
    "Scenario", "get_scenario", "list_scenarios", "scenario",
    "SimReport", "SloAccountant", "TickStats", "compare",
    "WorkloadConfig", "WorkloadState", "inject_flash_crowd",
    "make_workload_state", "set_churn_rates", "workload_step",
    "workload_trace_count",
]
