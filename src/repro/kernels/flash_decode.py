"""Flash-decode (Pallas TPU): single-token attention against a long KV cache.

The serving hot-spot (§Perf A): one query token per sequence attends over a
32k-524k cache.  Roofline: ~2 flops per cache byte — pure HBM-bandwidth
work, so the kernel's only job is to stream K/V through VMEM exactly once
with no S x S materialization and no f32 cache copies (the two CPU-path
overheads measured in EXPERIMENTS.md §Perf A2).

Layout: grid (B * KV_heads, kv_blocks); each program owns one kv head's G
query heads (GQA group) and accumulates online softmax over its kv stream.
The written-length of the cache arrives as an SMEM scalar so wholly-invalid
blocks are skipped (`pl.when`) — decode at pos p only touches
ceil(p / bk) blocks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, softcap: Optional[float],
                   bk: int, num_kv_blocks: int, G: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid_len = len_ref[0]
    k_start = ki * bk

    @pl.when(k_start < valid_len)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale            # [G, D]
        k = k_ref[0].astype(jnp.float32)                    # [bk, D]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # [G, bk]
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
        logits = jnp.where(k_pos < valid_len, logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                    # [bk, D]
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "bk",
                                             "interpret"))
def flash_decode(q, k, v, kv_len, *, scale: Optional[float] = None,
                 softcap: Optional[float] = None, bk: int = DEFAULT_BK,
                 interpret: bool = True):
    """q: [B, 1, H, D]; k/v: [B, Smax, KV, D]; kv_len: i32[] (written slots).

    -> [B, 1, H, D].  All cache positions < kv_len participate (causality of
    a decode step over an append-only cache).
    """
    B, _, H, D = q.shape
    _, Smax, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else D ** -0.5

    bk = min(bk, Smax)
    Skp = -(-Smax // bk) * bk
    Dp = -(-D // 128) * 128
    Gp = -(-G // 8) * 8                                     # sublane pad

    qp = jnp.pad(q[:, 0].reshape(B, KV, G, D),
                 ((0, 0), (0, 0), (0, Gp - G), (0, Dp - D)))
    qp = qp.reshape(B * KV, Gp, Dp)
    kp = jnp.pad(k, ((0, 0), (0, Skp - Smax), (0, 0), (0, Dp - D)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Smax), (0, 0), (0, Dp - D)))
    kp = kp.transpose(0, 2, 1, 3).reshape(B * KV, Skp, Dp)
    vp = vp.transpose(0, 2, 1, 3).reshape(B * KV, Skp, Dp)

    nk = Skp // bk
    grid = (B * KV, nk)
    len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, scale=scale, softcap=softcap, bk=bk,
        num_kv_blocks=nk, G=Gp)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Gp, Dp), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, Dp), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, Dp), lambda bh, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, Gp, Dp), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, Gp, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Gp, Dp), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(len_arr, qp, kp, vp)

    out = out.reshape(B, KV, Gp, Dp)[:, :, :G, :D]
    return out.reshape(B, 1, H, D)
