"""Pallas TPU kernel for the Mamba2 SSD per-chunk compute (zamba2 hot-spot).

The chunked SSD algorithm splits into:
  (1) per-chunk, per-head dense compute — intra-chunk "attention" (two
      [Q x Q] x [Q x P] matmuls) + the chunk's contribution to the carried
      state ([N x Q] x [Q x P]).  O(S * Q * (P + N)) FLOPs — the hot spot.
  (2) a tiny inter-chunk linear recurrence over C = S/Q chunk states.

The kernel implements (1) with one program per (batch, chunk, head):
VMEM working set = Q*(P + 2N) inputs + Q*Q decay kernel + P*N state
≈ 128*(64+128)*4B + 128*128*4B + 64*64*4B ≈ 180 KiB — comfortably VMEM-
resident, with all matmul dims 64/128 (MXU-aligned).  (2) stays in jnp —
it is O(C*H*P*N) and memory-trivial.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, cum_ref, *, chunk: int):
    Q = chunk
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, 0, :, :].astype(jnp.float32)         # [Q, 1]
    a = a_ref[0, 0]                                     # scalar A (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)                # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)                # [Q, N]

    dA = dt * a                                         # [Q, 1] log-decay
    cum = jnp.cumsum(dA, axis=0)                        # [Q, 1]
    total = cum[Q - 1:Q, :]                             # [1, 1]

    # intra-chunk: y[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
    Lmat = cum - cum.reshape(1, Q)                      # [Q, Q] (cum_i - cum_j)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    # mask before exp (matches ref: overflow-safe in fwd and bwd)
    decay = jnp.exp(jnp.where(iota_j <= iota_i, Lmat, -1e30))
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q, Q]
    w = scores * decay * dt.reshape(1, Q)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q, P]

    # chunk state contribution: state[p, n] = sum_j exp(total-cum_j) dt_j B_j[n] x_j[p]
    decay_out = jnp.exp(total - cum)                    # [Q, 1]
    xw = x * (decay_out * dt)                           # [Q, P]
    state = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # [P, N]

    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)
    state_ref[0, 0, 0] = state.astype(state_ref.dtype)
    cum_ref[0, 0, :, :] = cum.astype(cum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(x, dt, A, Bm, Cm, *, interpret: bool = True):
    """Per-chunk compute.  x: [B, C, Q, H, P]; dt: [B, C, Q, H]; A: [H];
    Bm/Cm: [B, C, Q, N].  Returns (y_intra [B,C,Q,H,P],
    state_c [B,C,H,P,N], cum [B,C,Q,H])."""
    Bb, C, Q, H, P = x.shape
    N = Bm.shape[-1]
    grid = (Bb, C, H)

    a2d = A.reshape(H, 1).astype(jnp.float32)

    y, state, cum = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, chunk=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1), lambda b, c, h: (h, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, C, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, C, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((Bb, C, Q, H), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a2d, Bm, Cm)
    return y, state, cum


def ssd_chunked_kernel(x, dt, A, Bm, Cm, D, h0=None, *, interpret: bool = True):
    """Full SSD scan using the Pallas per-chunk kernel + jnp inter-chunk
    recurrence.  Same contract as models.mamba2.ssd_chunked."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    from repro.models.mamba2 import CHUNK
    Q = min(CHUNK, S)
    assert S % Q == 0
    C = S // Q

    xc = x.reshape(Bsz, C, Q, H, P)
    dtc = dt.reshape(Bsz, C, Q, H)
    Bc = Bm.reshape(Bsz, C, Q, N)
    Cc = Cm.reshape(Bsz, C, Q, N)

    y_intra, state_c, cum = ssd_chunk_pallas(xc, dtc, A, Bc, Cc,
                                             interpret=interpret)

    total = cum[:, :, -1, :]                               # [B, C, H]
    chunk_decay = jnp.exp(total)

    def scan_fn(h, inp):
        dec, s = inp
        return h * dec[:, :, None, None] + s, h

    init = (jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    hT, h_prev = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(state_c, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                    # [B, C, H, P, N]

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, jnp.exp(cum), h_prev)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P) + D[None, None, :, None] * x
    return y.astype(x.dtype), hT
