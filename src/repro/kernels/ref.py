"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each oracle shares its math with the production XLA path so kernel tests
pin the Pallas implementations to the exact semantics the framework uses.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# move_eval oracles == the solver's XLA path (single source of truth).
from repro.core.delta import move_best_per_app as move_eval_best_ref  # noqa: F401
from repro.core.delta import move_delta_cost as move_eval_ref  # noqa: F401

# mamba chunked-scan oracle == the model's XLA path.
from repro.models.mamba2 import ssd_chunked as mamba_scan_ref  # noqa: F401


def flash_attention_ref(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
):
    """Dense GQA attention oracle.  q: [B,Sq,H,D]; k/v: [B,Skv,KV,D]."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def flash_decode_ref(q, k, v, kv_len, *, scale=None, softcap=None):
    """Decode-attention oracle: q [B,1,H,D] over cache positions < kv_len."""
    B, _, H, D = q.shape
    Smax = k.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32), (B, Smax))
    kv_valid = kv_pos < kv_len
    q_positions = jnp.full((B, 1), Smax, jnp.int32)   # all cache is past
    from repro.models.layers import attention
    return attention(q, k, v, causal=False, q_positions=q_positions,
                     kv_positions=kv_pos, kv_valid=kv_valid,
                     softcap=softcap, scale=scale)
