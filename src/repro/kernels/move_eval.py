"""Pallas TPU kernels for the SPTLB candidate-move delta-cost (paper hot-spot).

At Meta scale a LocalSearch iteration scores N x T candidate moves
(1e5 apps x 1e2 tiers).  The math is closed-form (core/delta.py); the
kernels tile the app axis into VMEM-resident blocks and evaluate all tiers
for a block entirely in registers — pure-VPU (elementwise) kernels, so the
roofline target is HBM bandwidth: ~13 input floats per app vs ~T outputs.

Two kernels share the delta computation (``_block_delta``):

  * ``move_eval_pallas``      — emits the full delta[N, T] sweep (oracle
                                parity path, used when the caller needs every
                                candidate),
  * ``move_eval_best_pallas`` — fuses the feasibility mask (capacity/task
                                headroom, movement budget, SLO/avoid,
                                no-self-moves) and the per-app argmin
                                reduction in-kernel, emitting only
                                (best_score, best_tier) per app.  This is
                                what the batched top-k LocalSearch consumes:
                                output bandwidth drops from N*T to N*2
                                floats.  Oracle: core.delta.move_best_per_app.

Per-app *source-side* quantities are O(N) and precomputed outside (gathers
are not TPU-vectorizer-friendly); the kernels handle the O(N*T) part.

Layout: app block BN=256 (sublane-aligned), tiers padded to 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.constraints import FEAS_TOL

BN = 256          # apps per block (sublane-dim tiling)
LANE = 128        # tier padding (lane alignment)


def _block_delta(
    a_src_ref, a0_ref,
    f_src_ref, f_src_new_ref, dC_src_ref, ideal_src_ref,    # [BN, R]
    g_src_ref, g_src_new_ref, dK_src_ref, gideal_src_ref,   # [BN, 1]
    d_ref,                                                   # [BN, R]
    k_ref, mc_ref, cc_ref,                                   # [BN, 1]
    f_ref, inv_cap_ref, ideal_ref,                           # [R, Tp]
    g_ref, inv_klim_ref, gideal_t_ref,                       # [1, Tp]
    mean_ref,                                                # [1, R+1]
    w_ref,                                                   # [1, 8]
    *, num_tiers: int, num_resources: int, out_shape,
):
    """Shared delta computation: returns (delta[BN, Tp], fits[BN, Tp]).

    ``fits`` is the destination capacity/task-limit headroom check with the
    same FEAS_TOL absolute tolerance as constraints.move_mask, expressed in
    load-fraction space: util + d <= cap + tol  <=>  f' <= 1 + tol/cap.
    """
    T = num_tiers
    Tp = out_shape[-1]
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (BN, Tp), 1)
    a_src = a_src_ref[...]                                  # [BN, 1]
    a0 = a0_ref[...]

    def h2(x, ideal):
        h = jnp.maximum(x - ideal, 0.0)
        return h * h

    d_under = jnp.zeros((BN, Tp), jnp.float32)
    d_res_bal = jnp.zeros((BN, Tp), jnp.float32)
    fits = jnp.ones((BN, Tp), jnp.bool_)
    for r in range(num_resources):
        inv_cap = inv_cap_ref[r:r + 1, :]                   # [1, Tp]
        dC = d_ref[:, r:r + 1] * inv_cap                    # [BN, Tp]
        f_dst = f_ref[r:r + 1, :]                           # [1, Tp]
        f_dst_new = f_dst + dC
        fits &= f_dst_new <= 1.0 + FEAS_TOL * inv_cap
        d_sumsq = (f_src_new_ref[:, r:r + 1] ** 2 - f_src_ref[:, r:r + 1] ** 2
                   + f_dst_new ** 2 - f_dst ** 2)
        d_mean = (dC - dC_src_ref[:, r:r + 1]) / T
        mean_f = mean_ref[0, r]
        new_mean = mean_f + d_mean
        d_res_bal += d_sumsq - T * (new_mean ** 2 - mean_f ** 2)
        d_under += (h2(f_src_new_ref[:, r:r + 1], ideal_src_ref[:, r:r + 1])
                    - h2(f_src_ref[:, r:r + 1], ideal_src_ref[:, r:r + 1])
                    + h2(f_dst_new, ideal_ref[r:r + 1, :])
                    - h2(f_dst, ideal_ref[r:r + 1, :]))

    # task-count analogue
    inv_klim = inv_klim_ref[0:1, :]
    dK = k_ref[...] * inv_klim                              # [BN, Tp]
    g_dst = g_ref[0:1, :]
    g_dst_new = g_dst + dK
    fits &= g_dst_new <= 1.0 + FEAS_TOL * inv_klim
    d_sumsq_t = (g_src_new_ref[...] ** 2 - g_src_ref[...] ** 2
                 + g_dst_new ** 2 - g_dst ** 2)
    d_mean_t = (dK - dK_src_ref[...]) / T
    mean_g = mean_ref[0, num_resources]
    new_mean_t = mean_g + d_mean_t
    d_task_bal = d_sumsq_t - T * (new_mean_t ** 2 - mean_g ** 2)
    d_under += (h2(g_src_new_ref[...], gideal_src_ref[...])
                - h2(g_src_ref[...], gideal_src_ref[...])
                + h2(g_dst_new, gideal_t_ref[0:1, :])
                - h2(g_dst, gideal_t_ref[0:1, :]))

    # movement indicator delta
    was_moved = (a_src != a0).astype(jnp.float32)           # [BN, 1]
    will_move = (iota_t != a0).astype(jnp.float32)          # [BN, Tp]
    d_moved = will_move - was_moved
    d_move_cost = d_moved * mc_ref[...]
    d_crit = d_moved * cc_ref[...]

    delta = (w_ref[0, 0] * d_under
             + w_ref[0, 1] * d_res_bal
             + w_ref[0, 2] * d_task_bal
             + w_ref[0, 3] * d_move_cost
             + w_ref[0, 4] * d_crit)
    return delta, fits


def _move_eval_kernel(*refs, num_tiers: int, num_resources: int):
    *in_refs, out_ref = refs
    delta, _ = _block_delta(*in_refs, num_tiers=num_tiers,
                            num_resources=num_resources,
                            out_shape=out_ref.shape)
    T = num_tiers
    Tp = out_ref.shape[-1]
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (BN, Tp), 1)
    a_src = in_refs[0][...]
    delta = jnp.where(iota_t == a_src, 0.0, delta)          # self-moves
    delta = jnp.where(iota_t >= T, jnp.inf, delta)          # tier padding
    out_ref[...] = delta


def _move_eval_best_kernel(*refs, num_tiers: int, num_resources: int):
    """Fused mask + per-app argmin: out[:, 0] = best score, out[:, 1] = tier."""
    *in_refs, feas_ref, flags_ref, out_ref = refs
    delta, fits = _block_delta(*in_refs, num_tiers=num_tiers,
                               num_resources=num_resources,
                               out_shape=(BN, feas_ref.shape[-1]))
    T = num_tiers
    Tp = feas_ref.shape[-1]
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (BN, Tp), 1)
    a_src = in_refs[0][...]
    a0 = in_refs[1][...]
    already_moved = a_src != a0                             # [BN, 1]
    have_budget = flags_ref[0, 0] > 0.0
    mask = ((feas_ref[...] > 0.0) & fits
            & (already_moved | have_budget)
            & (iota_t != a_src) & (iota_t < T))
    scores = jnp.where(mask, delta, jnp.inf)
    s_min = jnp.min(scores, axis=-1, keepdims=True)         # [BN, 1]
    t_min = jnp.argmin(scores, axis=-1).astype(jnp.float32)[:, None]
    lane = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)
    out_ref[...] = jnp.where(lane == 0, s_min,
                             jnp.where(lane == 1, t_min, 0.0))


def _prepare(demand, tasks, criticality, assignment, assignment0,
             capacity, task_limit, ideal_frac, ideal_task_frac,
             util, tier_tasks, weights):
    """Shared host-side precompute + padding for both kernels."""
    N, R = demand.shape
    T = capacity.shape[0]
    Np = -(-N // BN) * BN
    Tp = -(-T // LANE) * LANE

    f = (util / capacity).astype(jnp.float32)               # [T, R]
    g = (tier_tasks / task_limit).astype(jnp.float32)       # [T]
    mean_f = jnp.mean(f, axis=0)
    mean_g = jnp.mean(g)

    # per-app source-side precompute (O(N), outside the kernel)
    src = assignment
    dC_src = demand / capacity[src]                         # [N, R]
    f_src = f[src]
    f_src_new = f_src - dC_src
    ideal_src = ideal_frac[src]
    dK_src = (tasks / task_limit[src])[:, None]             # [N, 1]
    g_src = g[src][:, None]
    g_src_new = g_src - dK_src
    gideal_src = ideal_task_frac[src][:, None]
    total_tasks = jnp.maximum(jnp.sum(tasks), 1.0)
    total_crit = jnp.maximum(jnp.sum(criticality), 1.0)
    mc = (tasks / total_tasks)[:, None]
    cc = (criticality / total_crit)[:, None]

    def pad_n(x, fill=0):
        pad = [(0, Np - N)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x.astype(jnp.float32 if x.dtype != jnp.int32 else x.dtype),
                       pad, constant_values=fill)

    def pad_t(x):                                            # [T,...] -> [.., Tp] row-major
        return jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, Tp - T)])

    app_inputs = [
        pad_n(assignment[:, None]), pad_n(assignment0[:, None]),
        pad_n(f_src), pad_n(f_src_new), pad_n(dC_src), pad_n(ideal_src),
        pad_n(g_src), pad_n(g_src_new), pad_n(dK_src), pad_n(gideal_src),
        pad_n(demand), pad_n(tasks[:, None]), pad_n(mc), pad_n(cc),
    ]
    tier_inputs = [
        pad_t(f.T), pad_t((1.0 / capacity).T), pad_t(ideal_frac.T),
        pad_t(g[None, :]), pad_t((1.0 / task_limit)[None, :]),
        pad_t(ideal_task_frac[None, :]),
    ]
    mean_in = jnp.concatenate([mean_f, mean_g[None]])[None, :]      # [1, R+1]
    w_in = jnp.pad(weights.astype(jnp.float32), (0, 8 - weights.shape[0]))[None, :]

    def app_spec(width):
        return pl.BlockSpec((BN, width), lambda i: (i, 0))

    def full_spec(rows, cols):
        return pl.BlockSpec((rows, cols), lambda i: (0, 0))
    in_specs = [
        app_spec(1), app_spec(1),
        app_spec(R), app_spec(R), app_spec(R), app_spec(R),
        app_spec(1), app_spec(1), app_spec(1), app_spec(1),
        app_spec(R), app_spec(1), app_spec(1), app_spec(1),
        full_spec(R, Tp), full_spec(R, Tp), full_spec(R, Tp),
        full_spec(1, Tp), full_spec(1, Tp), full_spec(1, Tp),
        full_spec(1, R + 1), full_spec(1, 8),
    ]
    inputs = [*app_inputs, *tier_inputs, mean_in, w_in]
    return N, R, T, Np, Tp, inputs, in_specs, pad_n, pad_t, app_spec, full_spec


@functools.partial(jax.jit, static_argnames=("interpret",))
def move_eval_pallas(
    demand, tasks, criticality, assignment, assignment0,
    capacity, task_limit, ideal_frac, ideal_task_frac,
    util, tier_tasks, weights, *, interpret: bool = True,
):
    """Same flat signature as core.delta.move_delta_cost -> delta[N, T]."""
    N, R, T, Np, Tp, inputs, in_specs, *_ = _prepare(
        demand, tasks, criticality, assignment, assignment0,
        capacity, task_limit, ideal_frac, ideal_task_frac,
        util, tier_tasks, weights)

    out = pl.pallas_call(
        functools.partial(_move_eval_kernel, num_tiers=T, num_resources=R),
        grid=(Np // BN,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BN, Tp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Tp), jnp.float32),
        interpret=interpret,
    )(*inputs)
    return out[:N, :T]


@functools.partial(jax.jit, static_argnames=("interpret",))
def move_eval_best_pallas(
    demand, tasks, criticality, assignment, assignment0,
    capacity, task_limit, ideal_frac, ideal_task_frac,
    util, tier_tasks, weights, feasible, moves_left,
    *, interpret: bool = True,
):
    """Fused sweep+mask+argmin: core.delta.move_best_per_app semantics.

    Returns (best_score f32[N], best_tier i32[N]); +inf score marks apps with
    no feasible move.  ``feasible`` is the static bool[N, T] SLO/avoid/
    validity mask; ``moves_left`` the remaining movement budget (scalar).
    """
    N, R, T, Np, Tp, inputs, in_specs, pad_n, pad_t, app_spec, full_spec = \
        _prepare(demand, tasks, criticality, assignment, assignment0,
                 capacity, task_limit, ideal_frac, ideal_task_frac,
                 util, tier_tasks, weights)

    feas_padded = jnp.pad(feasible.astype(jnp.float32),
                          [(0, Np - N), (0, Tp - T)])        # pad rows/lanes 0
    flags = jnp.zeros((1, 8), jnp.float32).at[0, 0].set(
        (moves_left > 0).astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_move_eval_best_kernel, num_tiers=T,
                          num_resources=R),
        grid=(Np // BN,),
        in_specs=[*in_specs, app_spec(Tp), full_spec(1, 8)],
        out_specs=pl.BlockSpec((BN, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, LANE), jnp.float32),
        interpret=interpret,
    )(*inputs, feas_padded, flags)
    return out[:N, 0], out[:N, 1].astype(jnp.int32)
