"""Pallas TPU kernels (+ XLA reference paths) for the framework hot-spots."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
