"""jit'd dispatch wrappers: one entry point per kernel, impl-selectable.

``impl="xla"``    — pure-jnp path (CPU container, dry-run lowering, oracle)
``impl="pallas"`` — Pallas TPU kernel (``interpret=True`` on CPU for tests;
                    compiled on real TPU)

The dry-run/roofline always lowers the XLA path (Pallas does not lower for
the CPU backend); on-TPU deployments flip ``ModelConfig.attn_impl`` /
``LocalSearchConfig`` wiring to "pallas".
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.mamba_scan import ssd_chunked_kernel as _mamba_pallas
from repro.kernels.move_eval import move_eval_best_pallas as _move_best_pallas
from repro.kernels.move_eval import move_eval_pallas as _move_pallas

_ON_TPU = jax.default_backend() == "tpu"


def _interp() -> bool:
    return not _ON_TPU


def move_eval(*args, impl: str = "xla"):
    """delta[N, T] — see core.delta.move_delta_cost for the signature."""
    if impl == "xla":
        return _ref.move_eval_ref(*args)
    return _move_pallas(*args, interpret=_interp())


def move_eval_best(*args, impl: str = "xla"):
    """Fused sweep + move-mask + per-app argmin -> (best_score[N], best_tier[N]).

    The reduction the batched top-k LocalSearch consumes (it only ever looks
    at the top-k of the N per-app best scores); see
    core.delta.move_best_per_app for the signature and mask semantics.
    """
    if impl == "xla":
        return _ref.move_eval_best_ref(*args)
    return _move_best_pallas(*args, interpret=_interp())


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    impl: str = "xla"):
    if impl == "xla":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        softcap=softcap, scale=scale)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         softcap=softcap, scale=scale, interpret=_interp())


def mamba_scan(x, dt, A, Bm, Cm, D, h0=None, *, impl: str = "xla"):
    if impl == "xla":
        return _ref.mamba_scan_ref(x, dt, A, Bm, Cm, D, h0)
    return _mamba_pallas(x, dt, A, Bm, Cm, D, h0, interpret=_interp())


def flash_decode(q, k, v, kv_len, *, scale=None, softcap=None,
                 impl: str = "xla"):
    """Single-token decode attention over an append-only KV cache."""
    if impl == "xla":
        return _ref.flash_decode_ref(q, k, v, kv_len, scale=scale,
                                     softcap=softcap)
    from repro.kernels.flash_decode import flash_decode as _fd
    return _fd(q, k, v, kv_len, scale=scale, softcap=softcap,
               interpret=_interp())
