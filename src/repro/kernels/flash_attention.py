"""Flash attention (Pallas TPU): causal GQA with sliding-window + softcap.

Covers the attention variants of the assigned archs: GQA grouping (qwen kv=2
... gemma2 kv=8), gemma2's 4096-token sliding window and logit softcap.

TPU adaptation (vs. the CUDA flash-attention algorithm):
  * q/k/v blocks are VMEM tiles driven by BlockSpecs; the kv axis is the
    *minor-most grid dimension*, so the online-softmax accumulators live in
    VMEM scratch across sequential kv steps (TPU grids execute in order —
    no atomics / shared-memory reductions as on GPU),
  * block shapes are MXU-aligned (128 q rows x 128 kv cols; head_dim padded
    to a lane multiple by the wrapper),
  * fully-masked kv blocks are skipped with ``pl.when`` (causal/window),
    which is where the 2x causal win comes from.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], bq: int, bk: int,
                  num_kv_blocks: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    # Skip blocks that are entirely masked out.
    relevant = True
    if causal:
        relevant = k_start <= q_start + bq - 1        # some k <= max q pos
    if window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + bk - 1 > q_start - window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale            # [bq, D]
        k = k_ref[0].astype(jnp.float32)                    # [bk, D]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # [bq, bk]
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < kv_len                       # padded kv columns
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                                  # [bq, 1]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                          # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                      # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                     # [bk, D]
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk",
                     "interpret"))
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
):
    """q: [B, Sq, H, D]; k, v: [B, Skv, KV, D] -> [B, Sq, H, D].

    Self-attention positions (q position i == sequence position i).  The
    wrapper pads D to a lane multiple and Sq/Skv to block multiples.
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else D ** -0.5

    bq = min(bq, max(8, Sq))
    bk = min(bk, max(8, Skv))
    Sqp = -(-Sq // bq) * bq
    Skp = -(-Skv // bk) * bk
    Dp = -(-D // 128) * 128

    qp = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, Dp - D)))
    kp = jnp.pad(k, ((0, 0), (0, Skp - Skv), (0, 0), (0, Dp - D)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Skv), (0, 0), (0, Dp - D)))
    # [B*H, S, D] query-head-major; kv stays [B*KV, S, D]
    qp = qp.transpose(0, 2, 1, 3).reshape(B * H, Sqp, Dp)
    kp = kp.transpose(0, 2, 1, 3).reshape(B * KV, Skp, Dp)
    vp = vp.transpose(0, 2, 1, 3).reshape(B * KV, Skp, Dp)

    nq = Sqp // bq
    nk = Skp // bk
    grid = (B * H, nq, nk)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KV + h // G, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, num_kv_blocks=nk, kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dp), q_index),
            pl.BlockSpec((1, bk, Dp), kv_index),
            pl.BlockSpec((1, bk, Dp), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, Dp), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dp), jnp.float32),     # acc
            pltpu.VMEM((bq, 1), jnp.float32),      # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),      # l (running denom)
        ],
        interpret=interpret,
    )(qp, kp, vp)

    out = out.reshape(B, H, Sqp, Dp).transpose(0, 2, 1, 3)
    return out[:, :Sq, :, :D]
