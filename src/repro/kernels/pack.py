"""First-fit-decreasing bin packing as compiled scans (host-scheduler core).

The hierarchy's host scheduler answers "does every app mapped to this tier
still fit after packing?" by first-fit packing the tier's demand (sorted
decreasing) into identical host bins.  Two entry points:

  * ``pack_ffd``       — one tier.  The host axis is padded to a static
                         power-of-two ``num_hosts_pad`` with -inf-capacity
                         bins (they can never accept an item), and the live
                         host count arrives as a *traced* scalar — so tiers
                         with different host counts share one compiled
                         executable instead of retracing per distinct
                         ``hosts_per_tier`` value.
  * ``pack_ffd_tiers`` — every tier of a cluster at once: a vmap of the same
                         scan over a ``[T, M, R]`` demand tensor.  One device
                         dispatch replaces the per-tier Python loop inside a
                         cooperation feedback round.

Both run the seed scan's exact arithmetic: the same f32 subtractions in the
same order over the pre-sorted demand, first fit == lowest live host index;
padded bins sit *after* the live bins so they never perturb ``argmax``.
Zero-demand padding rows fit host 0 and consume nothing, so app-axis bucket
padding never changes the packing either.  Accept/reject is therefore
bit-identical across both entry points for any given item order — and
bit-identical to the seed per-tier loop whenever max demands are tie-free
(the callers canonicalize tie order by ascending app id, where the seed
packed in caller order with an unstable sort).

These are XLA ``lax.scan`` kernels, not Pallas: FFD is a strict sequential
dependence over items (each placement changes the bins the next item sees),
so there is no intra-tier parallelism for a Pallas grid to exploit — the win
is batching tiers and caching executables, which XLA already gives us.

Retrace counters (``pack_trace_count``) increment at *trace* time only, like
``solver_local.local_search_trace_count``: a delta of 0 across a call means
the jit cache was hit.  ``DispatchStats`` wraps a compiled call with the
wall-clock / dispatch / retrace bookkeeping every caller of these kernels
wants (the host scheduler level reports it through the cooperation bus's
per-level ``counters()`` hook).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

_TRACE_COUNTS = {"pack_ffd": 0, "pack_ffd_tiers": 0}


def pack_trace_count() -> int:
    """Total (re)traces of the packing executables across both entry points."""
    return _TRACE_COUNTS["pack_ffd"] + _TRACE_COUNTS["pack_ffd_tiers"]


@dataclasses.dataclass
class DispatchStats:
    """Device-dispatch bookkeeping for the packing kernels.

    ``run`` executes one compiled call synchronously (``np.asarray`` blocks
    on the device) and accumulates wall-clock seconds, dispatch count, and
    the retrace delta observed across the call — the counters the
    cooperation bus folds into ``CoopTimings.levels["host"]`` and
    ``host_side_frac`` classification (dispatch time counts device-side).
    """

    seconds: float = 0.0
    dispatches: int = 0
    retraces: int = 0

    def run(self, fn, *args, **kw) -> np.ndarray:
        t = time.perf_counter()
        before = pack_trace_count()
        out = np.asarray(fn(*args, **kw))      # asarray syncs the device
        self.retraces += pack_trace_count() - before
        self.dispatches += 1
        self.seconds += time.perf_counter() - t
        return out


def _ffd_scan(demand_sorted: jax.Array, capacity: jax.Array,
              num_hosts: jax.Array, num_hosts_pad: int) -> jax.Array:
    """First-fit scan of pre-sorted items into ``num_hosts`` live bins.

    ``num_hosts`` is traced; ``num_hosts_pad`` is the static padded bin
    count.  Dead bins get -inf capacity: ``-inf >= d`` is False for every
    d >= 0 (including the zero padding rows), so they never accept an item
    and never shift the first-fit index.  Returns rejected bool[M].
    """
    live = jnp.arange(num_hosts_pad) < num_hosts
    hosts0 = jnp.where(live[:, None], capacity[None, :], -jnp.inf)

    def step(hosts, d):
        fit = jnp.all(hosts >= d[None, :], axis=1)
        any_fit = jnp.any(fit)
        h = jnp.argmax(fit)                                 # first fit
        hosts = hosts.at[h].add(jnp.where(any_fit, -d, 0.0))
        return hosts, ~any_fit

    _, rejected = jax.lax.scan(step, hosts0, demand_sorted)
    return rejected


@partial(jax.jit, static_argnames=("num_hosts_pad",))
def pack_ffd(demand_sorted: jax.Array, capacity: jax.Array,
             num_hosts: jax.Array, *, num_hosts_pad: int) -> jax.Array:
    """Single-tier FFD: rejected bool[M] for ``demand_sorted`` [M, R]."""
    _TRACE_COUNTS["pack_ffd"] += 1          # trace-time side effect only
    return _ffd_scan(demand_sorted, capacity, num_hosts, num_hosts_pad)


@partial(jax.jit, static_argnames=("num_hosts_pad",))
def pack_ffd_tiers(demand_sorted: jax.Array, capacity: jax.Array,
                   hosts_per_tier: jax.Array, *,
                   num_hosts_pad: int) -> jax.Array:
    """All-tier FFD: rejected bool[T, M] for ``demand_sorted`` [T, M, R].

    Row t is tier t's demand, sorted decreasing and zero-padded to M; the
    vmapped scan packs every tier in one dispatch with per-tier live host
    counts from ``hosts_per_tier`` (i32[T]).
    """
    _TRACE_COUNTS["pack_ffd_tiers"] += 1    # trace-time side effect only
    return jax.vmap(
        lambda d, nh: _ffd_scan(d, capacity, nh, num_hosts_pad)
    )(demand_sorted, hosts_per_tier)
