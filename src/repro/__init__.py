"""repro: SPTLB hierarchical multi-objective scheduling + JAX training framework.

The curated public surface.  Everything an integrator needs for the three
supported workflows imports from here:

* **One-shot balancing** — build a cluster (``generate_cluster`` or
  ``streams.build_cluster``), hand it to ``Sptlb`` and call ``balance``.
* **Closed-loop control** — wrap the cluster in a ``BalanceController``
  and drive it with ``step(TickInput(...)) -> TickResult``.
* **Streaming service** — wrap the controller in a ``ServiceLoop`` and
  ``submit`` typed ``ServiceEvent`` records; the loop decides per tick
  whether drift justifies a delta solve or a full cooperate pass.

Scenario-driven evaluation (``get_scenario`` / ``run_pair`` /
``run_service_pair``) lives in ``repro.sim`` and is re-exported here.
Deeper modules (``repro.core.*``, ``repro.shard``, ``repro.streams``)
remain importable but are not part of the stability contract.
"""
from repro.core import (Advisory, BalanceController, BalanceDecision,
                        ClusterState, ControllerConfig, CoopConfig,
                        FaultToleranceConfig, Mode, Problem, Sptlb,
                        TickInput, TickResult, generate_cluster,
                        make_problem, utilization_fraction)
from repro.service import (AdvisoryBatch, AppArrival, AppDeparture,
                           CapacityUpdate, DriftConfig, DriftDetector,
                           FaultSignal, FleetShadow, LatencyDelta,
                           ServiceConfig, ServiceEvent, ServiceLoop,
                           ServiceStepResult, TelemetryDelta)
from repro.sim import (Scenario, get_scenario, list_scenarios,
                       netlat_compare, run_netlat_pair, run_pair,
                       run_scenario, run_scenario_service, run_service_pair,
                       service_compare)
from repro.streams import PodSlice, StreamApp, StreamRouter, build_cluster

__version__ = "0.1.0"

__all__ = [
    # one-shot balancing
    "Sptlb", "BalanceDecision", "CoopConfig", "Problem", "make_problem",
    "ClusterState", "generate_cluster", "utilization_fraction",
    # closed-loop control
    "BalanceController", "ControllerConfig", "FaultToleranceConfig",
    "Mode", "Advisory", "TickInput", "TickResult",
    # streaming service
    "ServiceLoop", "ServiceConfig", "ServiceStepResult", "ServiceEvent",
    "TelemetryDelta", "CapacityUpdate", "LatencyDelta", "AppArrival",
    "AppDeparture", "AdvisoryBatch", "FaultSignal", "DriftConfig",
    "DriftDetector", "FleetShadow",
    # scenario registry + trajectory evaluation
    "Scenario", "get_scenario", "list_scenarios", "run_pair",
    "run_scenario", "run_scenario_service", "run_service_pair",
    "service_compare", "run_netlat_pair", "netlat_compare",
    # stream-runtime frontend
    "StreamApp", "StreamRouter", "PodSlice", "build_cluster",
    "__version__",
]
