"""repro: SPTLB hierarchical multi-objective scheduling + JAX training framework."""
__version__ = "0.1.0"
