"""Training step factory: loss -> grad -> AdamW update, as a single jittable
function suitable for pjit (dry-run AOT compile) and the live driver.

Microbatching (gradient accumulation) runs as a ``lax.scan`` over microbatch
slices — the standard memory/throughput knob for the perf pass.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array
    compress_err: Any = None        # gradient-compression error feedback


def init_train_state(model, key, *, compressor=None) -> TrainState:
    params = model.init(key)
    err = compressor.init_state(params) if compressor is not None else None
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32), compress_err=err)


def abstract_train_state(model, key) -> TrainState:
    """Shape-only TrainState (no allocation) for the dry-run."""
    return jax.eval_shape(lambda k: init_train_state(model, k), key)


def make_train_step(model, opt_cfg: AdamWConfig = AdamWConfig(),
                    *, microbatches: int = 1, unroll: bool = False,
                    compressor=None) -> Callable:
    """-> train_step(state, batch) -> (state, metrics).

    ``unroll`` runs the microbatch loop as a python loop instead of
    ``lax.scan`` — used by the dry-run cost calibration (HloCostAnalysis
    counts while-loop bodies once).

    ``compressor`` (distributed.compress.GradCompressor): gradients cross
    the optimizer boundary in compressed form with error feedback carried
    in TrainState — the transform the inter-pod (DCN) reduction applies in
    deployment (see EXPERIMENTS.md §Multi-pod).
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            # Static reshape [B, ...] -> [mb, B/mb, ...]: microbatches flow
            # through scan xs, so the (sharded) batch dim is never sliced at
            # a traced offset (a dynamic slice on a sharded dim forces an
            # all-gather and replicates the step — measured in §Perf C).
            def to_mb(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            batch_mb = jax.tree.map(to_mb, batch)

            def mb_body(acc, mb_batch):
                (l, m), g = grad_fn(state.params, mb_batch)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), m

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            acc0 = (zero_g, jnp.zeros((), jnp.float32))
            if unroll:
                for i in range(microbatches):
                    acc0, metrics = mb_body(
                        acc0, jax.tree.map(lambda x: x[i], batch_mb))
                grads, loss = acc0
            else:
                (grads, loss), metrics = jax.lax.scan(
                    mb_body, acc0, batch_mb)
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        compress_err = state.compress_err
        if compressor is not None:
            comp, compress_err = compressor.compress(grads, compress_err)
            grads = compressor.decompress(comp)

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return TrainState(new_params, new_opt, state.step + 1,
                          compress_err), out_metrics

    return train_step
