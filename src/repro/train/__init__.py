from repro.train.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.train.train_step import TrainState, init_train_state, make_train_step
from repro.train.serve_step import make_decode_step, make_prefill

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "TrainState", "init_train_state", "make_train_step",
           "make_decode_step", "make_prefill"]
