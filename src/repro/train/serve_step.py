"""Serving steps: prefill and single-token decode (the dry-run "serve_step").

``make_decode_step`` returns the function lowered for the decode_32k /
long_500k cells: one new token per sequence against a full KV cache, plus
greedy sampling of the next token.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_prefill(model) -> Callable:
    def prefill(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], cache
    return prefill


def make_decode_step(model) -> Callable:
    def serve_step(params, token, cache):
        logits, cache = model.decode_step(params, token, cache)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], cache
    return serve_step


def abstract_cache(model, batch: int, max_seq: int):
    """Shape-only cache (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: model.init_cache(batch, max_seq))
