"""AdamW + gradient clipping + LR schedule (pure JAX, shardable states).

State is a pytree mirroring the params (m, v) plus a scalar count, so the
sharding rules that apply to params apply verbatim to the optimizer state
(ZeRO-style sharding over the data axis is a recorded perf iteration).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    progress = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cosine = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cosine)


def adamw_init(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """-> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    b1c = 1.0 - cfg.beta1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * (g * g)
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(count, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
