"""Quickstart: the paper's pipeline end-to-end in ~30 seconds on CPU.

1. collect a 5-tier stream-processing cluster (paper §4 setup),
2. balance it with SPTLB under manual_cnst hierarchy co-operation,
3. compare against the greedy baseline (paper Fig. 3),
4. train a reduced assigned-architecture model on SPTLB-routed streams.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro import CoopConfig, Sptlb, generate_cluster, utilization_fraction
from repro.models import build_model, reduce_for_smoke
from repro.configs import get_config
from repro.streams import StreamConfig, TokenStream
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    # --- 1+2: SPTLB balancing (paper Figs 1-3) -----------------------------
    cluster = generate_cluster(num_apps=800, seed=0)
    sptlb = Sptlb(cluster)
    balanced = sptlb.balance("local", timeout_s=30,
                             config=CoopConfig(variant="no_cnst"))
    uf0, _ = utilization_fraction(cluster.problem, cluster.problem.assignment0)
    print("== SPTLB multi-objective balancing ==")
    print(f"initial  cpu util per tier: {np.asarray(uf0)[:, 0].round(2)}")
    print(f"balanced cpu util per tier: {balanced.projected.util_frac[:, 0].round(2)}")
    print(f"balanced mem util per tier: {balanced.projected.util_frac[:, 1].round(2)}")
    print(f"moved {balanced.projected.num_moved} apps "
          f"(budget {balanced.violations.move_budget}), "
          f"constraints ok: {balanced.violations.ok}")

    # --- 3: greedy baseline comparison (paper Fig. 3) ----------------------
    greedy = sptlb.balance("greedy-cpu")
    print("\n== greedy-cpu baseline (single-objective) ==")
    print(f"cpu util per tier : {greedy.projected.util_frac[:, 0].round(2)}  (balanced)")
    print(f"mem util per tier : {greedy.projected.util_frac[:, 1].round(2)}  (left unbalanced!)")

    # --- hierarchy co-operation (paper Figs 2, 4, 5) ------------------------
    coop = sptlb.balance("local", timeout_s=30,
                         config=CoopConfig(variant="manual_cnst",
                                           max_rounds=20))
    print("\n== manual_cnst co-operation with region/host schedulers ==")
    print(f"feedback rounds {coop.cooperation.feedback_rounds}, "
          f"avoid constraints learned {coop.cooperation.num_rejections}, "
          f"accepted: {coop.cooperation.accepted}")
    print(f"worst-case net latency: {coop.network_p99_ms:.0f} ms "
          f"(vs {balanced.network_p99_ms:.0f} ms hierarchy-blind)")

    # --- 4: train a reduced assigned arch on the routed streams ------------
    print("\n== train smollm-360m (reduced) for 10 steps ==")
    cfg = reduce_for_smoke(get_config("smollm-360m"))
    model = build_model(cfg)
    stream = TokenStream(StreamConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      global_batch=8))
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    import jax.numpy as jnp
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        state, metrics = step(state, batch)
        if i % 3 == 0 or i == 9:
            print(f"step {i}: loss {float(metrics['loss']):.4f}")
    print("done.")


if __name__ == "__main__":
    main()
