"""Drive the balancing controller through a dynamic fleet scenario.

The one-shot experiment (examples/rebalance_cluster.py) solves a single
Fig. 3 snapshot; this example runs the *system*: demand evolves every tick
(diurnal cycle, burst noise, flash crowds, churn), timed events rewrite the
cluster (capacity drains, region outages), and ``BalanceController``
decides tick by tick whether rebalancing is worth the movement cost.  The
SLO accountant scores the trajectory for both the controller and the
no-rebalance baseline.

Run:
  PYTHONPATH=src python examples/simulate_fleet.py                  # all
  PYTHONPATH=src python examples/simulate_fleet.py \\
      --scenario tier_drain --apps 400 --ticks 160 --verbose
  PYTHONPATH=src python examples/simulate_fleet.py \\
      --scenario fleet_scale --shards 4      # sharded solver path

Scenario how-to
---------------
A scenario is a ``WorkloadConfig`` (the demand process) plus a tuple of
timed events over a tick horizon.  The registry in ``repro/sim/scenario.py``
ships five: steady_diurnal, flash_crowd, tier_drain, region_outage,
churn_heavy.  To add your own, register a builder:

    from repro.sim import (Scenario, WorkloadConfig, CapacityScale,
                           FlashCrowd, scenario)

    @scenario("friday_deploy", "weekly deploy drains tier 1 during a spike")
    def _friday_deploy(num_apps, ticks, seed):
        return Scenario(
            name="friday_deploy", description="", ticks=ticks,
            num_apps=num_apps, seed=seed,
            workload=WorkloadConfig(diurnal_amp=0.25, burst_sigma=0.12),
            events=(FlashCrowd(at=ticks // 3, frac=0.10, magnitude=5.0),
                    CapacityScale(at=ticks // 3 + 2, tier=0, scale=0.3),
                    CapacityScale(at=(2 * ticks) // 3, tier=0, scale=1.0)))

then ``--scenario friday_deploy`` (or ``sim.get_scenario``) runs it.
Available events: ``CapacityScale`` (maintenance drains/restores, scale is
relative to as-built), ``RegionOutage``/``RegionRestore`` (capacity + SLO
eligibility loss on overlapping tiers, region goes latency-dark),
``FlashCrowd`` (demand spike on a random app subset, decays geometrically),
``ShardSkew`` (demand spike anchored to one region's data shards),
``ChurnRate`` (re-rate arrivals/retirements mid-run — traced state, so no
recompile).  Churned app counts ride the ``valid``-mask padding: shapes
never change, so the whole trajectory reuses one compiled solver per pow-2
bucket.

Scheduler levels
----------------
The controller's cooperation bus runs an ordered stack of
``core.levels.SchedulerLevel`` plugins.  The default is the paper's
region+host pair; ``--levels region,host,shard`` adds the shard locality
level (data-shard co-location vetting — what the ``shard_skew`` scenario
exercises), and a scenario may pin its own stack via ``Scenario.levels``.
Register a custom level (``register_level("mine", MyLevel)``) and it is
immediately addressable here — see docs/custom_scheduler_levels.md.

Anticipation
------------
Maintenance events are *declared*: ``CapacityScale``/``RegionOutage``/
``RegionRestore`` default to ``announced=True`` and publish advisories
(``Scenario.declared_events``) that the controller's planner
(``repro/core/planner.py``) turns into time-phased capacity targets — the
evacuation starts before the first ramp step instead of after it strands
incumbents, and every move is priced against the scenario's
``move_budget``.  ``--compare-anticipation`` runs the balanced policy
twice (planner on vs off) over the same trajectory:

  PYTHONPATH=src python examples/simulate_fleet.py \\
      --scenario tier_drain --compare-anticipation

Chaos
-----
``--chaos`` runs the control-plane fault family (telemetry_blackout,
solver_brownout, cascading_outage — or a single chaos scenario via
``--scenario``) through ``sim.run_chaos_pair``: the *degraded* run faces
the faults with the degraded-mode control plane armed
(``CHAOS_CONTROLLER``), the *oracle* twin replays the identical workload
with the faults stripped, and the scorecard reports what degradation cost:
operating-mode residency and audited transitions, unsafe moves committed
on faulted telemetry (must be 0), breaker trips, quarantined readings, and
the degraded-vs-oracle SLO-violation ratio.  See docs/degraded_modes.md
for the runbook interpretation of each figure:

  PYTHONPATH=src python examples/simulate_fleet.py --chaos --verbose

Overload
--------
``--overload`` runs the demand-side failure family (overload_surge,
overload_flash, overload_capacity_loss — or one of them via
``--scenario``) through ``sim.run_overload_pair``: the *utility* run arms
the full overload control plane (Henge-style utility curves, the admission
gate, the load shedder), the *binary* twin rides the identical trajectory
with none of it, and the scorecard reports what graceful degradation
bought: delivered-utility ratio vs the fractional-knapsack oracle for both
policies, deferred/shed-capped app-ticks, cap-churn against the movement
budget, and the two hard invariants (infeasible admissions and budget
overruns, both must be 0).  See docs/overload_and_admission.md for the
runbook interpretation:

  PYTHONPATH=src python examples/simulate_fleet.py --overload --verbose

Network
-------
``--netlat`` runs the network_degraded family (slow links, asymmetric
detours, jitter storms — or one of them via ``--scenario``) through
``sim.run_netlat_pair``: the *static* run vets placements against the
hard-coded 36 ms constant, the *measured* twin binds the latency-SLO
level (per-pair budgets calibrated from streaming P² sketches, vetted
against live p99 estimates), and the scorecard reports the placement-p99
integral ratio (must be < 1), budget-exceeding moves (measured must be
0), and the calibration/quarantine counters.  See docs/latency_slo.md:

  PYTHONPATH=src python examples/simulate_fleet.py --netlat --verbose

Metrics (see ``repro/sim/slo.py``): ``slo_violation_ticks`` integrates
app-ticks on SLO-ineligible tiers plus tier-ticks over the ideal line;
``over_ideal_excess_integral`` weights the latter by severity;
``total_moves`` counts moves and ``movement_cost`` prices them
(Madsen-style, ``core.planner.move_costs`` — the paper's goal 8 made a
budget); ``region_breach_app_ticks`` surfaces the maintenance placement
mode's bounded latency degradation.  ``BENCH_sim.json`` is regenerated by
``PYTHONPATH=src python -m benchmarks.sim_scenarios``.
"""
import argparse

from repro import (ControllerConfig, CoopConfig, get_scenario, list_scenarios,
                   run_netlat_pair, run_pair, run_scenario, run_service_pair)
from repro.sim import run_chaos_pair, run_overload_pair


def run_netlat(names, args):
    """--netlat: measured-vs-static budget scorecard per network scenario."""
    if args.scenario == "all":
        names = [n for n in sorted(list_scenarios())
                 if get_scenario(n, num_apps=8, ticks=8, seed=0).netlat]
    for name in names:
        sc = get_scenario(name, num_apps=args.apps, ticks=args.ticks,
                          seed=args.seed)
        if not sc.netlat:
            print(f"{name}: not a network scenario (no link weather for the "
                  f"measurement plane to see) — skipping")
            continue
        print(f"-- {name}: {sc.description}")
        out = run_netlat_pair(sc, verbose=args.verbose)
        c = out["netlat"]
        p99 = c["network_p99_integral"]
        print(f"   p99 integral       static {p99['static']:.1f} vs "
              f"measured {p99['measured']:.1f} (ratio {p99['ratio']:.4f})")
        peak = c["peak_network_p99_ms"]
        print(f"   peak p99           static {peak['static']:.1f} ms vs "
              f"measured {peak['measured']:.1f} ms")
        bex = c["budget_exceeding_moves"]
        print(f"   budget-exceeding   static {bex['static']} vs "
              f"measured {bex['measured']} (measured must be 0)")
        print(f"   moves              static {c['moves']['static']} vs "
              f"measured {c['moves']['measured']}")
        print(f"   calibrated         {c['calibrated']} "
              f"(relax {c['relax_factor']}, "
              f"{c['quarantined_samples']} quarantined samples)")


def run_service(names, args):
    """--service: event-stream service vs lockstep scorecard per scenario."""
    if args.scenario == "all":
        names = [n for n in sorted(list_scenarios())
                 if not (sc := get_scenario(n, num_apps=8, ticks=8,
                                            seed=0)).chaos and not sc.overload]
    for name in names:
        sc = get_scenario(name, num_apps=args.apps, ticks=args.ticks,
                          seed=args.seed)
        if sc.chaos or sc.overload:
            print(f"{name}: chaos/overload scenarios replay through their "
                  f"own harnesses — skipping")
            continue
        print(f"-- {name}: {sc.description}")
        out = run_service_pair(sc, verbose=args.verbose)
        c = out["service_compare"]
        fp = c["full_passes"]
        print(f"   full passes        lockstep {fp['lockstep']} vs "
              f"service {fp['service']} (reduction {fp['reduction']:.2f})")
        print(f"   delta solves       {c['delta_solves']} "
              f"({c['delta_fraction']:.2f} of solves), "
              f"{c['noop_ticks']} no-op ticks, "
              f"{c['delta_reverts']} parity reverts")
        v = c["slo_violation_ticks"]
        ratio = "n/a" if v["ratio"] is None else f"{v['ratio']:.2f}"
        print(f"   violation ticks    lockstep {v['lockstep']} vs "
              f"service {v['service']} (ratio {ratio})")
        print(f"   moves              lockstep {c['total_moves']['lockstep']} "
              f"vs service {c['total_moves']['service']}")
        print(f"   events             {c['events_applied']} applied, "
              f"{c['dropped_events']} dropped (must be 0)")


def run_chaos(names, args):
    """--chaos: degraded-vs-oracle scorecard per chaos scenario."""
    if args.scenario == "all":
        names = [n for n in sorted(list_scenarios())
                 if get_scenario(n, num_apps=8, ticks=8, seed=0).chaos]
    for name in names:
        sc = get_scenario(name, num_apps=args.apps, ticks=args.ticks,
                          seed=args.seed)
        if not sc.chaos:
            print(f"{name}: not a chaos scenario (has no control-plane "
                  f"fault windows) — skipping")
            continue
        print(f"-- {name}: {sc.description}")
        out = run_chaos_pair(sc, verbose=args.verbose)
        c = out["chaos"]
        modes = ", ".join(f"{m}:{t}" for m, t in c["mode_ticks"].items())
        print(f"   mode ticks         {modes}")
        for t in c["mode_transitions"]:
            print(f"   transition         tick {t['tick']:3d}  "
                  f"{t['from']:>12s} -> {t['to']:<12s} score {t['score']}")
        d, o = c["degraded_vs_oracle"]["degraded"], \
            c["degraded_vs_oracle"]["oracle"]
        print(f"   unsafe moves       {c['unsafe_moves']} (must be 0)")
        print(f"   violation ticks    degraded {d} vs oracle {o} "
              f"(ratio {c['degraded_vs_oracle']['ratio']:.2f})")
        print(f"   moves              degraded {c['moves']['degraded']} "
              f"vs oracle {c['moves']['oracle']}")
        print(f"   breaker trips      {c['breaker_trips']}")
        print(f"   quarantined        {c['telemetry_quarantined']} readings")
        print(f"   budget overruns    {c['budget_overruns']}")
        print(f"   recovered          {c['recovered']}")


def run_overload(names, args):
    """--overload: utility-vs-binary scorecard per overload scenario."""
    if args.scenario == "all":
        names = [n for n in sorted(list_scenarios())
                 if get_scenario(n, num_apps=8, ticks=8, seed=0).overload]
    for name in names:
        sc = get_scenario(name, num_apps=args.apps, ticks=args.ticks,
                          seed=args.seed)
        if not sc.overload:
            print(f"{name}: not an overload scenario (demand never outgrows "
                  f"the fleet) — skipping")
            continue
        print(f"-- {name}: {sc.description}")
        out = run_overload_pair(sc, verbose=args.verbose)
        o = out["overload"]
        r = o["delivered_utility_ratio"]
        print(f"   delivered utility  binary {r['binary']:.3f} vs "
              f"utility {r['utility']:.3f} of oracle "
              f"(improvement {r['improvement']:.2f}x)")
        adm = o["admission"]
        if adm:
            print(f"   admission          {adm.get('admit', 0)} admit, "
                  f"{adm.get('admit_degraded', 0)} degraded, "
                  f"{adm.get('defer', 0)} defer, {adm.get('reject', 0)} "
                  f"reject ({adm.get('backlog', 0)} backlogged)")
        print(f"   deferred           {o['deferred_app_ticks']} app-ticks")
        print(f"   shed-capped        {o['shed_capped_app_ticks']} app-ticks "
              f"({o['shed_events']} shed, {o['readmit_events']} readmitted, "
              f"{o['shed_churn_events']} churn events)")
        print(f"   moves              binary {o['moves']['binary']} vs "
              f"utility {o['moves']['utility']}")
        wb = o["within_budget"]
        print(f"   within budget      binary {wb['binary']} / "
              f"utility {wb['utility']} "
              f"({o['budget_overruns']['utility']} overruns)")
        print(f"   infeasible adm.    {o['infeasible_admissions']} "
              f"(must be 0)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="all",
                    help=f"one of {sorted(list_scenarios())} or 'all'")
    ap.add_argument("--apps", type=int, default=240)
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout-s", type=int, default=4,
                    help="per-tick solver budget knob")
    ap.add_argument("--restart-rounds", type=int, default=0,
                    help="vetted perturbation restarts per accepted solve")
    ap.add_argument("--levels", default=None,
                    help="comma-separated scheduler-level stack for the "
                         "cooperation bus (e.g. region,host,shard); default "
                         "lets each scenario pick its own (shard_skew runs "
                         "the three-level stack), others use region,host")
    ap.add_argument("--shards", type=int, default=None,
                    help="route the balanced controller's solves through the "
                         "S-shard partitioned fleet path (repro.shard); "
                         "default lets each scenario pick (fleet_scale runs "
                         "2 shards), others use the global solver")
    ap.add_argument("--no-anticipation", action="store_true",
                    help="ignore declared maintenance advisories (reactive "
                         "controller, the pre-PR-4 behaviour)")
    ap.add_argument("--compare-anticipation", action="store_true",
                    help="also run the balanced policy with the planner off "
                         "and print the proactive-vs-reactive delta")
    ap.add_argument("--chaos", action="store_true",
                    help="run the control-plane chaos family through "
                         "run_chaos_pair and print the degraded-vs-oracle "
                         "scorecard (see docs/degraded_modes.md)")
    ap.add_argument("--overload", action="store_true",
                    help="run the overload family through run_overload_pair "
                         "and print the utility-vs-binary scorecard (see "
                         "docs/overload_and_admission.md)")
    ap.add_argument("--netlat", action="store_true",
                    help="run the network_degraded family through "
                         "run_netlat_pair and print the measured-vs-static "
                         "budget scorecard (see docs/latency_slo.md)")
    ap.add_argument("--service", action="store_true",
                    help="replay scenarios as event streams through the "
                         "ServiceLoop (drift-triggered delta solves) and "
                         "print the service-vs-lockstep scorecard (see "
                         "docs/streaming_service.md)")
    ap.add_argument("--verbose", action="store_true",
                    help="per-tick trace")
    args = ap.parse_args()

    names = (sorted(list_scenarios()) if args.scenario == "all"
             else [args.scenario])
    if args.chaos:
        run_chaos(names, args)
        return
    if args.overload:
        run_overload(names, args)
        return
    if args.netlat:
        run_netlat(names, args)
        return
    if args.service:
        run_service(names, args)
        return
    levels = (tuple(n for n in args.levels.split(",") if n.strip())
              if args.levels else None)
    config = ControllerConfig(
        timeout_s=args.timeout_s, cooldown_rounds=2, shards=args.shards,
        coop=CoopConfig(restart_rounds=args.restart_rounds, levels=levels))

    print(f"{'scenario':16s} {'policy':9s} {'viol':>6s} {'excess':>8s} "
          f"{'peak d2b':>8s} {'moves':>6s} {'cost':>7s} {'rebal':>5s} "
          f"{'solver s':>8s}")
    for name in names:
        sc = get_scenario(name, num_apps=args.apps, ticks=args.ticks,
                          seed=args.seed)
        if args.verbose:
            print(f"-- {name}: {sc.description}")
        out = run_pair(sc, config=config,
                       anticipation=not args.no_anticipation,
                       verbose=args.verbose)
        for policy in ("baseline", "balanced"):
            s = out[policy].summary()
            print(f"{name:16s} {s['policy']:9s} "
                  f"{s['slo_violation_ticks']:6d} "
                  f"{s['over_ideal_excess_integral']:8.2f} "
                  f"{s['peak_d2b']:8.3f} {s['total_moves']:6d} "
                  f"{s['movement_cost']:7.1f} "
                  f"{s['rebalances']:5d} {s['solver_time_s']:8.2f}")
        ratio = out["compare"]["slo_violation_ticks"]["ratio"]
        if ratio is None:
            print(f"{'':16s} -> baseline had 0 violation ticks")
        else:
            print(f"{'':16s} -> violation-tick ratio {ratio:.2f} "
                  f"({'controller wins' if ratio < 1 else 'baseline holds'})")
        move = out["compare"]["movement"]
        if move["budget"] is not None:
            print(f"{'':16s} -> movement cost {move['cost']:.1f} of budget "
                  f"{move['budget']:.0f} "
                  f"({'within' if move['within_budget'] else 'OVERRUN'}, "
                  f"{move['overrun_ticks']} budget-bound ticks)")

        if args.compare_anticipation and sc.declared_events:
            # Same trajectory with the planner toggled: whichever mode the
            # main run used, the comparison re-runs the other one.
            if args.no_anticipation:
                ant = run_scenario(sc, policy="balanced", config=config,
                                   anticipation=True).summary()
                blind = out["balanced"].summary()
            else:
                blind = run_scenario(sc, policy="balanced", config=config,
                                     anticipation=False).summary()
                ant = out["balanced"].summary()
            print(f"{'':16s} anticipation on : viol="
                  f"{ant['slo_violation_ticks']:4d} "
                  f"cost={ant['movement_cost']:7.1f} "
                  f"breach_ticks={ant['region_breach_app_ticks']}")
            print(f"{'':16s} anticipation off: viol="
                  f"{blind['slo_violation_ticks']:4d} "
                  f"cost={blind['movement_cost']:7.1f} "
                  f"breach_ticks={blind['region_breach_app_ticks']}")
        elif args.compare_anticipation:
            print(f"{'':16s} (no declared events — nothing to anticipate)")


if __name__ == "__main__":
    main()
