"""Batched serving: prefill + KV-cache decode on an assigned architecture.

Demonstrates the serving path the decode_32k / long_500k dry-run cells lower:
greedy decoding with a batch of requests against a shared-shape KV cache
(ring caches for the sliding-window layers when --ring is set).

Run:  PYTHONPATH=src python examples/serve.py --arch gemma2-9b --ring
      (reduced config on CPU; full configs are dry-run/TPU territory)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, reduce_for_smoke
from repro.train.serve_step import make_decode_step, make_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ring", action="store_true",
                    help="window-sized ring caches for local layers")
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    if args.ring:
        cfg = dataclasses.replace(cfg, ring_cache=True)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)
    max_seq = args.prompt_len + args.new_tokens
    cache = model.init_cache(B, max_seq)

    prefill = jax.jit(make_prefill(model))
    decode = jax.jit(make_decode_step(model))

    t0 = time.perf_counter()
    token, cache = prefill(params, {"tokens": prompts}, cache)
    token.block_until_ready()
    t_prefill = time.perf_counter() - t0

    generated = [token]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        token, cache = decode(params, token, cache)
        generated.append(token)
    jax.block_until_ready(generated[-1])
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.arch_id} (reduced) ring_cache={cfg.ring_cache}")
    print(f"prefill {args.prompt_len} toks x{B}: {t_prefill*1e3:.1f} ms")
    print(f"decode {args.new_tokens-1} steps: "
          f"{t_decode/(args.new_tokens-1)*1e3:.1f} ms/token (CPU, compiled)")
    print(f"generated token ids (seq 0): {list(map(int, out[0][:12]))} ...")


if __name__ == "__main__":
    main()
