"""The paper's full experiment (§4) as a runnable scenario:

  * 5 tiers, paper SLO table, tier 3 hot,
  * all three integration variants (no_cnst / w_cnst / manual_cnst),
  * both engines (LocalSearch / OptimalSearch),
  * a failure event mid-scenario -> capacity shrink -> movement-bounded
    re-balance (the framework's fault-tolerance loop).

Run:  PYTHONPATH=src python examples/rebalance_cluster.py [--apps 600]

The cooperation knobs ride a ``CoopConfig`` and the lower-level scheduler
stack is a ``Hierarchy`` built from registry names — ``--levels
region,host,shard`` runs the three-level stack (the shard locality plugin
vetting data-shard co-location) through the exact same bus.  Registering
your own level is one call:

    from repro.core import SchedulerLevel, register_level

    class QuotaLevel(SchedulerLevel):
        name = "quota"
        def __init__(self, cluster): ...
        def vet(self, proposal): ...     # -> rejected app ids

    register_level("quota", QuotaLevel)  # then --levels region,host,quota
"""
import argparse


from repro import CoopConfig, Sptlb, generate_cluster
from repro.core import Hierarchy
from repro.distributed.fault import CapacityEvent, rebalance


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--levels", default="region,host",
                    help="comma-separated scheduler-level stack for the "
                         "cooperation bus (registry names; e.g. "
                         "region,host,shard adds data-shard co-location "
                         "vetting)")
    ap.add_argument("--no-premask", action="store_true",
                    help="disable level pre-masking (the manual_cnst "
                         "feedback loop then re-learns each level's "
                         "feasibility one rejection round at a time, as in "
                         "the paper's plain variant)")
    args = ap.parse_args()

    cluster = generate_cluster(num_apps=args.apps, seed=args.seed)
    sptlb = Sptlb(cluster)
    hierarchy = Hierarchy.from_names(args.levels)

    print(f"levels: {args.levels}")
    print(f"{'variant':14s} {'engine':8s} {'d2b':>6s} {'p99 ms':>7s} "
          f"{'moved':>6s} {'rounds':>6s} {'time s':>7s} ok")
    for engine in ("local", "optimal"):
        for variant in ("no_cnst", "w_cnst", "manual_cnst"):
            cfg = CoopConfig(variant=variant, max_rounds=20,
                             premask=not args.no_premask)
            d = sptlb.balance(engine, timeout_s=30, config=cfg,
                              hierarchy=hierarchy)
            rounds = d.cooperation.feedback_rounds if d.cooperation else 1
            t = d.cooperation.total_time_s if d.cooperation else d.solve.solve_time_s
            print(f"{variant:14s} {engine:8s} {d.difference_to_balance:6.3f} "
                  f"{d.network_p99_ms:7.0f} {d.projected.num_moved:6d} "
                  f"{rounds:6d} {t:7.2f} {d.violations.ok}")

    print("\n-- host failure: tier 3 loses 25% capacity --")
    event = CapacityEvent("host_failure", tier=2, fraction=0.25)
    rebalanced, decision = rebalance(cluster, event)
    print(f"re-balance moved {decision.projected.num_moved} apps "
          f"(bounded by {decision.violations.move_budget}), "
          f"d2b {decision.difference_to_balance:.3f}, "
          f"constraints ok: {decision.violations.ok}")
    print("tier 3 util after failure+rebalance:",
          decision.projected.util_frac[2].round(2))


if __name__ == "__main__":
    main()
