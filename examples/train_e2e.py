"""End-to-end resilient training: the full production loop in miniature.

SPTLB routes 48 streaming jobs onto a 5-slice cluster; a reduced assigned
architecture trains on the deterministic token stream with periodic atomic
checkpoints; a mid-run host failure triggers (1) SPTLB re-balancing with the
paper's movement bound, (2) restart from the latest checkpoint.  Exactly the
`launch/train.py` driver — this wrapper picks demonstration-friendly flags.

Run:  PYTHONPATH=src python examples/train_e2e.py [--arch qwen2.5-3b]
"""
import argparse
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as tmp:
        train_main([
            "--arch", args.arch, "--smoke",
            "--steps", str(args.steps),
            "--global-batch", "8",
            "--seq-len", "128",
            "--ckpt-dir", f"{tmp}/ckpt",
            "--ckpt-every", "8",
            "--inject-failure-at", str(args.steps // 2),
        ])


if __name__ == "__main__":
    main()
