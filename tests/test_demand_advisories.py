"""Demand-side advisories (PR 8 satellite): declared flash crowds phase
capacity headroom through the PR-4 advisory channel the way maintenance
phases capacity out, reusing the PR-7 SHED advisory kind."""

import numpy as np
import pytest

from repro.core import generate_cluster
from repro.core.planner import SHED, Advisory, MaintenancePlanner, PlannerConfig
from repro.sim.events import FlashCrowd
from repro.sim.harness import run_scenario
from repro.sim.scenario import get_scenario


@pytest.fixture(scope="module")
def cluster():
    return generate_cluster(num_apps=120, seed=3)


# -- event -> advisory wiring ------------------------------------------------


def test_flash_crowd_declares_only_when_announced():
    surprise = FlashCrowd(at=10, frac=0.1, magnitude=5.0)
    assert surprise.declare() is None

    declared = FlashCrowd(at=10, frac=0.1, magnitude=5.0, announced=True)
    adv = declared.declare()
    assert adv is not None
    assert adv.kind == SHED and adv.at == 10
    # expected offered-demand factor: frac of apps spike by magnitude
    assert adv.scale == pytest.approx(1.0 + 0.1 * (5.0 - 1.0))
    assert adv.scale > 1.0  # the demand side of the SHED kind


def test_fleet_scale_surge_scenario_declares_its_crowds():
    sc = get_scenario("fleet_scale_surge", num_apps=96, ticks=32, seed=0)
    declared = sc.declared_events
    assert len(declared) == 2
    assert all(a.kind == SHED and a.scale > 1.0 for a in declared)
    assert sc.shards == 2


# -- planner phasing ---------------------------------------------------------


def test_outlook_phases_headroom_toward_a_declared_crowd(cluster):
    planner = MaintenancePlanner(
        [Advisory(at=10, kind=SHED, scale=1.8)], PlannerConfig(horizon=8)
    )
    # Beyond the horizon: nothing tightens yet.
    assert not planner.outlook(0, cluster).active

    far = planner.outlook(3, cluster)  # 7 ticks out, weight 2/8
    near = planner.outlook(9, cluster)  # 1 tick out, weight 1.0
    assert far.active and near.active
    # headroom phases in monotonically: targets tighten toward the event
    assert (near.tier_factor <= far.tier_factor + 1e-6).all()
    assert (far.tier_factor < 1.0).all()
    # at weight 1.0 the target is the full declared surge: 1 / 1.8
    np.testing.assert_allclose(near.tier_factor, 1.0 / 1.8, atol=1e-6)
    # demand headroom never marks tiers for evacuation
    assert not far.avoid_tiers.any() and not near.avoid_tiers.any()
    assert not near.slo_off_tiers.any()


def test_tier_scoped_crowd_only_tightens_that_tier(cluster):
    planner = MaintenancePlanner(
        [Advisory(at=5, kind=SHED, tier=2, scale=2.0)], PlannerConfig(horizon=8)
    )
    out = planner.outlook(4, cluster)  # weight 1.0
    assert out.tier_factor[2] == pytest.approx(0.5, abs=1e-6)
    others = np.delete(out.tier_factor, 2)
    np.testing.assert_allclose(others, 1.0)


def test_shedder_shed_advisories_stay_audit_only(cluster):
    """The load shedder publishes SHED caps with scale <= 1 (PR 7); those
    must keep riding the channel without touching capacity targets."""
    planner = MaintenancePlanner(
        [Advisory(at=5, kind=SHED, scale=0.7)], PlannerConfig(horizon=8)
    )
    out = planner.outlook(4, cluster)
    assert not out.active
    np.testing.assert_allclose(out.tier_factor, 1.0)
    assert out.pending == 1  # still counted/auditable in the window


# -- end to end through the sim ----------------------------------------------


def test_fleet_scale_surge_runs_with_anticipation():
    sc = get_scenario("fleet_scale_surge", num_apps=96, ticks=16, seed=0)
    rep = run_scenario(sc, policy="balanced", anticipation=True)
    s = rep.summary()
    assert s["rebalances"] >= 1
    assert rep.extra["anticipation"] is True
