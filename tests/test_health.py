"""Telemetry health + circuit breaker unit tests (degraded-mode sensing).

The sim chaos suite (test_chaos.py) proves these end-to-end; here each
state machine is pinned in isolation: staleness scoring, quarantine and
last-known-good hygiene, uncertainty inflation, breaker trip/probe/backoff.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate_cluster
from repro.core.health import (CLOSED, HALF_OPEN, OPEN, BreakerBoard,
                               BreakerConfig, CircuitBreaker, HealthConfig,
                               TelemetryMonitor)


@pytest.fixture()
def cluster():
    return generate_cluster(num_apps=16, seed=0)


def with_demand(cluster, demand):
    return dataclasses.replace(
        cluster, problem=dataclasses.replace(
            cluster.problem, demand=jnp.asarray(demand, jnp.float32)))


# ---------------------------------------------------------------------------
# telemetry monitor
# ---------------------------------------------------------------------------

def test_fresh_plausible_is_identity(cluster):
    mon = TelemetryMonitor()
    sanitized, health = mon.ingest(cluster, now=0, collected_at=0)
    assert sanitized is cluster          # parity-pinned: zero-cost when healthy
    assert health.score == 1.0
    assert health.quarantined == 0


def test_staleness_score_ladder(cluster):
    # stale_after=1, blind_after=5: scores 1, 1, .75, .5, .25, 0 at age 0..5.
    expected = {0: 1.0, 1: 1.0, 2: 0.75, 3: 0.5, 4: 0.25, 5: 0.0, 7: 0.0}
    for age, want in expected.items():
        mon = TelemetryMonitor()
        _, health = mon.ingest(cluster, now=age, collected_at=0)
        assert health.score == pytest.approx(want), f"staleness {age}"
        assert health.staleness == age


def test_stale_telemetry_inflates_demand(cluster):
    mon = TelemetryMonitor()
    sanitized, _ = mon.ingest(cluster, now=3, collected_at=0)
    assert sanitized is not cluster
    inflation = min(1.5, 1.05 ** 3)
    np.testing.assert_allclose(
        np.asarray(sanitized.problem.demand),
        np.asarray(cluster.problem.demand) * inflation, rtol=1e-5)


def test_quarantine_replaces_with_last_known_good(cluster):
    mon = TelemetryMonitor()
    mon.ingest(cluster, now=0, collected_at=0)        # establish LKG
    demand = np.asarray(cluster.problem.demand).copy()
    good_row = demand[3].copy()
    demand[3] = 1e6                                    # absurd jump
    sanitized, health = mon.ingest(with_demand(cluster, demand),
                                   now=1, collected_at=1)
    assert health.signals["demand"].quarantined == 1
    np.testing.assert_allclose(
        np.asarray(sanitized.problem.demand)[3], good_row, rtol=1e-6)
    # 1 of 16 live quarantined, blind at 25%: 1 - (1/16)/0.25 = 0.75.
    assert health.score == pytest.approx(0.75)


def test_lkg_never_absorbs_corrupted_values(cluster):
    mon = TelemetryMonitor()
    mon.ingest(cluster, now=0, collected_at=0)
    demand = np.asarray(cluster.problem.demand).copy()
    demand[3] = 1e6
    corrupt = with_demand(cluster, demand)
    mon.ingest(corrupt, now=1, collected_at=1)
    # Re-ingesting the same corruption must still quarantine it: the LKG
    # advanced with the *sanitized* row, not the laundered 1e6.
    _, health = mon.ingest(corrupt, now=2, collected_at=2)
    assert health.signals["demand"].quarantined == 1


def test_nonfinite_quarantined_without_history(cluster):
    mon = TelemetryMonitor()                           # no LKG yet
    demand = np.asarray(cluster.problem.demand).copy()
    demand[0] = np.nan
    demand[1] = -4.0
    sanitized, health = mon.ingest(with_demand(cluster, demand),
                                   now=0, collected_at=0)
    assert health.signals["demand"].quarantined == 2
    got = np.asarray(sanitized.problem.demand)
    np.testing.assert_array_equal(got[0], 0.0)         # zeroed: conservative
    np.testing.assert_array_equal(got[1], 0.0)
    assert np.isfinite(got).all()


def test_blackout_reingest_does_not_launder_staleness(cluster):
    mon = TelemetryMonitor()
    mon.ingest(cluster, now=0, collected_at=0)
    lkg_before = mon._lkg_demand.copy()
    # A frozen snapshot re-served during a blackout keeps its old stamp;
    # LKG must not advance from it.
    mon.ingest(cluster, now=4, collected_at=0)
    np.testing.assert_array_equal(mon._lkg_demand, lkg_before)


# ---------------------------------------------------------------------------
# circuit breaker state machine
# ---------------------------------------------------------------------------

def run_pass(b, *, fail=False, candidates=0, rejected=0):
    state = b.begin_pass()
    if state != OPEN:
        if fail:
            b.note_failure()
        if candidates:
            b.note_vet(candidates, rejected)
    b.end_pass()
    return state


def test_breaker_trips_on_consecutive_failures():
    b = CircuitBreaker("host")
    for _ in range(2):
        run_pass(b, fail=True)
        assert b.state == CLOSED
    run_pass(b, fail=True)                             # third strike
    assert b.state == OPEN
    assert b.trips == 1
    assert b.cooldown == 2


def test_failure_streak_resets_on_clean_pass():
    b = CircuitBreaker("host")
    run_pass(b, fail=True)
    run_pass(b, fail=True)
    run_pass(b, candidates=4, rejected=1)              # clean: streak resets
    run_pass(b, fail=True)
    run_pass(b, fail=True)
    assert b.state == CLOSED


def test_breaker_trips_on_reject_all_streak():
    b = CircuitBreaker("host")
    for _ in range(3):
        run_pass(b, candidates=5, rejected=5)
    assert b.state == OPEN
    # A level that answers politely but vetoes everything has failed.


def test_passes_without_candidates_do_not_advance_reject_streak():
    b = CircuitBreaker("host")
    run_pass(b, candidates=5, rejected=5)
    run_pass(b)                                        # nothing to vet
    run_pass(b, candidates=5, rejected=5)
    run_pass(b, candidates=5, rejected=5)
    assert b.state == OPEN                             # 3 vetted passes total


def test_half_open_probe_clean_closes():
    b = CircuitBreaker("host")
    for _ in range(3):
        run_pass(b, fail=True)
    assert b.state == OPEN
    assert run_pass(b) == OPEN                         # cooldown 2 -> 1
    state = run_pass(b, candidates=3, rejected=0)      # probe pass
    assert state == HALF_OPEN
    assert b.state == CLOSED
    assert b.probes == 1
    assert b.cooldown == 0                             # backoff reset


def test_half_open_probe_failure_doubles_cooldown():
    b = CircuitBreaker("host")
    for _ in range(3):
        run_pass(b, fail=True)
    run_pass(b)                                        # cooldown 2 -> 1
    run_pass(b, fail=True)                             # failing probe
    assert b.state == OPEN
    assert b.trips == 2
    assert b.cooldown == 4                             # 2 * backoff_factor


def test_backoff_caps_at_max_cooldown():
    cfg = BreakerConfig(fail_threshold=1, cooldown_passes=2, max_cooldown=5)
    b = CircuitBreaker("host", cfg)
    run_pass(b, fail=True)                             # trip: cooldown 2
    for want in (4, 5, 5):
        while b.state == OPEN and b.cooldown_left > 1:
            b.begin_pass()                             # burn cooldown passes
            b.end_pass()
        run_pass(b, fail=True)                         # failing probe
        assert b.cooldown == want


def test_board_health_factor_and_premask_cache():
    board = BreakerBoard()
    assert board.health_factor() == 1.0                # no breakers yet
    a, b = board.breaker("region"), board.breaker("host")
    assert board.breaker("region") is a                # stable identity
    assert board.health_factor() == 1.0
    for _ in range(3):
        run_pass(b, fail=True)
    assert board.open_levels == ["host"]
    assert board.health_factor() == pytest.approx(0.75)
    for _ in range(3):
        run_pass(a, fail=True)
    assert board.health_factor() == pytest.approx(0.5)
    board.cache_premask("host", np.array([True, False]))
    np.testing.assert_array_equal(board.cached_premask("host"),
                                  [True, False])
    assert board.cached_premask("region") is None
    snap = board.snapshot()
    assert snap["host"]["state"] == OPEN
    assert board.trips == 2
