"""Device-resident cooperation: batched all-tier FFD packing parity,
pack-executable sharing across host counts, the region pre-mask contract,
and the hierarchy precompute caches."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import (CoopConfig, HostScheduler, RegionScheduler, Sptlb,
                        generate_cluster)
from repro.core.controller import (BalanceController, ControllerConfig,
                                   TickInput)
from repro.core.hierarchy import region_overlap_avoid
from repro.kernels.pack import pack_ffd, pack_ffd_tiers, pack_trace_count

from _hypothesis_compat import hypothesis, st


def _ffd_seed_reference(demand_sorted, capacity, num_hosts):
    """The seed's per-tier first-fit scan as plain numpy (the oracle):
    same f32 subtractions in the same order, first fit == lowest host."""
    hosts = np.tile(capacity, (num_hosts, 1))
    rejected = np.zeros(len(demand_sorted), bool)
    for i, d in enumerate(demand_sorted):
        fit = np.all(hosts >= d, axis=1)
        if not fit.any():
            rejected[i] = True
            continue
        hosts[int(np.argmax(fit))] -= d
    return rejected


@st.composite
def pack_instances(draw):
    """[T, M, R] sorted-decreasing (zero-padded) demand + per-tier hosts."""
    seed = draw(st.integers(0, 10_000))
    T = draw(st.integers(1, 5))
    M = draw(st.integers(1, 40))
    pad = draw(st.integers(0, 12))
    rng = np.random.default_rng(seed)
    demand = rng.lognormal(0.0, 1.0, size=(T, M, 2)).astype(np.float32)
    order = np.argsort(-demand.max(axis=2), axis=1)
    demand = np.take_along_axis(demand, order[:, :, None], axis=1)
    demand = np.concatenate([demand, np.zeros((T, pad, 2), np.float32)],
                            axis=1)
    capacity = rng.uniform(1.0, 8.0, size=2).astype(np.float32)
    hosts = rng.integers(1, 10, size=T).astype(np.int32)
    return demand, capacity, hosts


@hypothesis.given(pack_instances())
@hypothesis.settings(max_examples=15, deadline=None, derandomize=True,
                     suppress_health_check=[hypothesis.HealthCheck.too_slow])
def test_batched_ffd_bit_identical_to_per_tier_and_seed(inst):
    """pack_ffd_tiers row t == pack_ffd on tier t == the seed numpy scan,
    bit for bit, including -inf-padded dead host bins and zero padding."""
    demand, capacity, hosts = inst
    batched = np.asarray(pack_ffd_tiers(
        jnp.asarray(demand), jnp.asarray(capacity), jnp.asarray(hosts),
        num_hosts_pad=16))
    for t in range(demand.shape[0]):
        per_tier = np.asarray(pack_ffd(
            jnp.asarray(demand[t]), jnp.asarray(capacity),
            jnp.int32(hosts[t]), num_hosts_pad=16))
        seed_ref = _ffd_seed_reference(demand[t], capacity, int(hosts[t]))
        assert np.array_equal(batched[t], per_tier), t
        assert np.array_equal(batched[t], seed_ref), t


def test_one_pack_executable_across_host_counts(cluster300):
    """Tiers with different host counts must share one compiled executable:
    the live count is traced, only the padded bin count is static."""
    host = HostScheduler(cluster300)
    rng = np.random.default_rng(0)
    apps = rng.choice(cluster300.problem.num_apps, size=140, replace=False)
    host.check_tier(0, apps)                     # at most this call traces
    before = pack_trace_count()
    for tier in range(1, cluster300.problem.num_tiers):
        host.check_tier(tier, apps)
    assert pack_trace_count() == before
    assert host.pack_dispatches == cluster300.problem.num_tiers


def _random_proposal(cluster, seed, movers=150, target_tier=None):
    rng = np.random.default_rng(seed)
    x0 = np.asarray(cluster.problem.assignment0)
    x = x0.copy()
    picked = rng.choice(len(x0), size=movers, replace=False)
    if target_tier is None:
        x[picked] = rng.integers(0, cluster.problem.num_tiers, size=movers)
    else:
        x[picked] = target_tier
    return x, x0, np.where(x != x0)[0]


def test_check_tiers_matches_per_tier_path(cluster300):
    """The single batched dispatch must reproduce the per-tier loop's
    rejected-newcomer set exactly — including on an overloaded tier."""
    host = HostScheduler(cluster300)
    smallest = int(np.argmin(cluster300.hosts_per_tier))
    for seed, target in ((3, None), (4, smallest)):
        x, x0, movers = _random_proposal(cluster300, seed, target_tier=target)
        got = np.sort(host.check_tiers(x, x0, movers))
        want = []
        for tier in np.unique(x[movers]):
            newcomers = movers[x[movers] == tier]
            incumbents = np.where((x == tier) & (x0 == tier))[0]
            rej = np.asarray(host.check_tier(
                int(tier), np.concatenate([incumbents, newcomers])), np.int64)
            if rej.size:
                want.extend(rej[x[rej] != x0[rej]].tolist())
        assert np.array_equal(got, np.sort(np.asarray(want, np.int64))), seed
    # the crafted overload actually exercised the reject path
    x, x0, movers = _random_proposal(cluster300, 4, target_tier=smallest)
    assert host.check_tiers(x, x0, movers).size > 0


def test_check_tiers_parity_under_demand_ties(cluster300):
    """Apps tying on max demand (but differing in the other resource) must
    pack in the same order on both paths: check_tier canonicalizes to a
    stable ascending-id sort, matching check_tiers' stable lexsort."""
    import jax.numpy as jnp
    demand = np.asarray(cluster300.problem.demand).copy()
    rng = np.random.default_rng(9)
    tied = rng.choice(len(demand), size=40, replace=False)
    demand[tied, 0] = np.float32(demand[:, 0].max() * 0.9)   # shared dmax...
    demand[tied, 1] = rng.uniform(0.1, demand[:, 1].max(),
                                  size=40).astype(np.float32)  # ...mem differs
    c = dataclasses.replace(
        cluster300, problem=dataclasses.replace(
            cluster300.problem, demand=jnp.asarray(demand)))
    host = HostScheduler(c)
    x0 = np.asarray(c.problem.assignment0)
    x = x0.copy()
    x[tied] = int(np.argmin(c.hosts_per_tier))               # overload one tier
    movers = np.where(x != x0)[0]
    got = np.sort(host.check_tiers(x, x0, movers))
    want = []
    for tier in np.unique(x[movers]):
        newcomers = movers[x[movers] == tier]
        incumbents = np.where((x == tier) & (x0 == tier))[0]
        # membership passed in a scrambled order on purpose
        members = rng.permutation(np.concatenate([incumbents, newcomers]))
        rej = np.asarray(host.check_tier(int(tier), members), np.int64)
        if rej.size:
            want.extend(rej[x[rej] != x0[rej]].tolist())
    assert np.array_equal(got, np.sort(np.asarray(want, np.int64)))


def test_batched_pack_executable_shared_across_proposals(cluster300):
    """Two proposals in the same app bucket reuse one compiled executable."""
    host = HostScheduler(cluster300)
    x, x0, movers = _random_proposal(cluster300, 5)
    host.check_tiers(x, x0, movers)              # at most this call traces
    before = pack_trace_count()
    x2, _, movers2 = _random_proposal(cluster300, 6, movers=120)
    host.check_tiers(x2, x0, movers2)
    assert pack_trace_count() == before


def test_premask_region_cooperation_contract(cluster300):
    """premask_region=True: zero region rejections, violations-free final
    mapping no worse than the unmasked path's, every move region-legal."""
    s = Sptlb(cluster300)
    # Default round cap: the comparison the knob is designed for (with a
    # much larger cap the unmasked path's rejection rounds double as extra
    # search restarts and the two paths' budgets diverge).
    d_on = s.balance("local", timeout_s=30, config=CoopConfig(premask=True))
    d_off = s.balance("local", timeout_s=30, config=CoopConfig(premask=False))
    tm_on, tm_off = d_on.cooperation.timings, d_off.cooperation.timings
    assert tm_on["premask"] and tm_on["region_rejections"] == 0
    assert not tm_off["premask"] and tm_off["region_rejections"] > 0
    assert d_on.violations.ok
    assert (d_on.solve.objective
            <= d_off.solve.objective
            + 1e-4 * max(1.0, abs(d_off.solve.objective)))
    region = RegionScheduler(cluster300)
    x = np.asarray(d_on.assignment)
    x0 = np.asarray(cluster300.problem.assignment0)
    moved = np.where(x != x0)[0]
    assert region.check_many(moved, x[moved]).all()
    # the new counters are reported on both paths
    for tm in (tm_on, tm_off):
        for key in ("rounds", "pack_s", "pack_dispatches", "pack_retraces",
                    "host_rejections"):
            assert key in tm, key


def test_hierarchy_precomputes_cached_on_cluster(cluster300):
    """Region matrices and the w_cnst overlap mask are memoized per cluster
    and recomputed after any dataclasses.replace."""
    r1, r2 = RegionScheduler(cluster300), RegionScheduler(cluster300)
    assert r1._worst_ms is r2._worst_ms
    assert r1.feasibility_matrix() is r2.feasibility_matrix()
    assert region_overlap_avoid(cluster300) is region_overlap_avoid(cluster300)
    # a different budget gets its own feasibility entry
    r3 = RegionScheduler(cluster300, latency_budget_ms=5.0)
    assert r3.feasibility_matrix() is not r1.feasibility_matrix()
    assert r3._worst_ms is r1._worst_ms          # geometry is budget-free
    c2 = dataclasses.replace(cluster300,
                             tier_regions=cluster300.tier_regions.copy())
    assert RegionScheduler(c2)._worst_ms is not r1._worst_ms


def test_controller_reuses_balancer_and_cluster_stays_consistent():
    cluster = generate_cluster(num_apps=120, seed=5)
    ctl = BalanceController(cluster, ControllerConfig(
        trigger_d2b=0.0, trigger_over_ideal=0.0, cooldown_rounds=1,
        timeout_s=4))
    balancer = ctl._sptlb
    for _ in range(2):
        ctl.step(TickInput())
    assert ctl._sptlb is balancer                # reused, not re-instantiated
    assert ctl._sptlb.cluster is ctl.cluster     # tracks applied rebalances
    # caller swaps in fresh telemetry between ticks: tick must re-sync the
    # balancer before deciding, not solve the stale cluster
    ctl.cluster = dataclasses.replace(ctl.cluster)
    ctl.step(TickInput())
    assert ctl._sptlb.cluster is ctl.cluster
