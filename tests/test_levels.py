"""Pluggable scheduler levels (PR 5): protocol, registry, CoopTimings
back-compat, the shard locality plugin, and the shard_skew scenario."""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    CoopConfig,
    CoopTimings,
    Hierarchy,
    SchedulerLevel,
    ShardLocalityScheduler,
    Sptlb,
    generate_cluster,
    register_level,
    shard_affinity_of,
)
from repro.core.levels import SHARD_MIN_AFFINITY, Proposal


@pytest.fixture(scope="module")
def cluster():
    return generate_cluster(num_apps=200, seed=1)


# -- CoopTimings mapping back-compat -----------------------------------------


def test_coop_timings_flat_keys_resolve_into_level_dicts():
    tm = CoopTimings.for_levels(("region", "host"), premask=True)
    tm.add_level_time("region", 0.5)
    tm.add_rejections("host", 7)
    tm.levels["host"].update(pack_s=0.25, pack_dispatches=3, pack_retraces=1)
    assert tm["region_s"] == 0.5
    assert tm["host_rejections"] == 7
    assert tm["region_rejections"] == 0
    assert tm["pack_s"] == 0.25 and tm["pack_dispatches"] == 3
    assert tm["premask"] is True
    # writes through the legacy keys land in the level dicts too
    tm["host_s"] = 1.25
    assert tm.levels["host"]["level_s"] == 1.25
    with pytest.raises(KeyError):
        tm["nonexistent_level_s"]
    assert tm.get("nonexistent_level_s", 42) == 42
    assert "region_s" in tm and "shard_s" not in tm


def test_coop_timings_flattens_like_the_legacy_dict():
    tm = CoopTimings.for_levels(("region", "host"))
    flat = dict(tm)
    for key in (
        "solve_s",
        "feedback_s",
        "total_s",
        "host_side_frac",
        "rounds",
        "region_s",
        "host_s",
        "region_rejections",
        "host_rejections",
        "pack_s",
        "pack_dispatches",
        "pack_retraces",
        "resident_overflows",
        "restarts",
        "movement_cost",
        "budget_trimmed",
        "round_costs",
        "premask",
        "levels",
    ):
        assert key in flat, key


# -- registry / Hierarchy ----------------------------------------------------


def test_hierarchy_from_names_and_unknown_level():
    assert len(Hierarchy.from_names("region,host,shard")) == 3
    assert len(Hierarchy.from_names(("region", "host"))) == 2
    with pytest.raises(KeyError, match="unknown scheduler level"):
        Hierarchy.from_names("region,bogus")


def test_registered_custom_level_is_addressable_by_name(cluster):
    class VetoTierLevel(SchedulerLevel):
        """Rejects every move into tier 0 (a quota-style plugin)."""

        name = "veto0"

        def __init__(self, cluster):
            self.cluster = cluster

        def vet(self, proposal):
            c = proposal.candidates
            return c[proposal.x[c] == 0]

    register_level("veto0", VetoTierLevel)
    d = Sptlb(cluster).balance(
        "local",
        timeout_s=4,
        config=CoopConfig(levels=("region", "host", "veto0")),
    )
    assert d.violations.ok
    x = np.asarray(d.assignment)
    x0 = np.asarray(cluster.problem.assignment0)
    moved = np.where(x != x0)[0]
    assert not (x[moved] == 0).any()  # the veto held in the final mapping
    assert "veto0" in d.cooperation.timings.levels


def test_misbehaving_level_cannot_hang_the_bus_or_poison_home(cluster):
    """Protocol clamp: a plugin that rejects ids outside its candidate set
    (residents, returners) must not deadlock the revert fixpoint or scatter
    an avoid over an app's home column.  The bus clamps rejections to the
    contract; the pass terminates with everything sent home."""

    class BounceEverything(SchedulerLevel):
        name = "bounce"

        def __init__(self, cluster):
            self.n = cluster.problem.num_apps

        def vet(self, proposal):
            return np.arange(self.n, dtype=np.int64)  # protocol violation

    register_level("bounce", BounceEverything)
    d = Sptlb(cluster).balance(
        "local",
        timeout_s=4,
        config=CoopConfig(levels=("region", "host", "bounce"), max_rounds=3),
    )
    x = np.asarray(d.assignment)
    x0 = np.asarray(cluster.problem.assignment0)
    np.testing.assert_array_equal(x, x0)  # every move bounced -> all home
    assert d.violations.ok


def test_controller_config_legacy_fields_override_explicit_coop_with_warning():
    import dataclasses as dc

    from repro.core.controller import ControllerConfig

    with pytest.warns(DeprecationWarning, match="variant"):
        cfg = ControllerConfig(
            variant="no_cnst", coop=CoopConfig(levels=("region", "host", "shard"))
        )
    assert cfg.coop.variant == "no_cnst"  # legacy shim overrides, like balance()
    assert cfg.coop.levels == ("region", "host", "shard")
    # idempotent: dataclasses.replace re-runs __post_init__ silently
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        again = dc.replace(cfg, movement_cost_budget=9.0)
        # legacy fields left at their defaults never touch an explicit coop
        silent = ControllerConfig(coop=CoopConfig(variant="no_cnst"))
    assert again.coop.variant == "no_cnst"
    assert silent.coop.variant == "no_cnst"


# -- shard affinity telemetry ------------------------------------------------


def test_shard_affinity_matrix_shape_and_memoization(cluster):
    aff = shard_affinity_of(cluster)
    N, T = cluster.problem.num_apps, cluster.problem.num_tiers
    assert aff.shape == (N, T)
    assert aff.dtype == np.float32
    assert (aff >= 0).all() and (aff <= 1 + 1e-6).all()
    assert shard_affinity_of(cluster) is aff  # memoized on the cluster
    fresh = dataclasses.replace(cluster)
    assert shard_affinity_of(fresh) is not aff  # replace resets the cache
    override = np.full((N, T), 0.5, np.float32)
    with_field = dataclasses.replace(cluster, shard_affinity=override)
    np.testing.assert_array_equal(shard_affinity_of(with_field), override)


# -- the shard locality level ------------------------------------------------


def _proposal_for(cluster, app, dest):
    x0 = np.asarray(cluster.problem.assignment0, np.int64)
    x = x0.copy()
    x[app] = dest
    return Proposal(x, x0, np.array([app], np.int64))


def test_shard_level_vets_against_affinity_bar(cluster):
    level = ShardLocalityScheduler(cluster)
    aff = shard_affinity_of(cluster)
    x0 = np.asarray(cluster.problem.assignment0)
    # an app whose home tier holds plenty of shard mass
    rich = int(np.argmax(aff[np.arange(len(x0)), x0]))
    good = int(np.argmax(aff[rich]))
    bad = int(np.argmin(aff[rich]))
    assert level.vet(_proposal_for(cluster, rich, good)).size == 0
    if aff[rich, bad] < SHARD_MIN_AFFINITY:
        assert level.vet(_proposal_for(cluster, rich, bad)).tolist() == [rich]


def test_shard_level_bar_capped_by_home_affinity(cluster):
    """An app already below the threshold at home must stay movable to any
    tier at least as good — the bar never exceeds what home provides."""
    level = ShardLocalityScheduler(cluster, min_affinity=0.99)
    aff = shard_affinity_of(cluster)
    x0 = np.asarray(cluster.problem.assignment0)
    app = 0
    better = int(np.argmax(aff[app]))
    assert aff[app, better] >= aff[app, x0[app]]
    assert level.vet(_proposal_for(cluster, app, better)).size == 0


def test_shard_level_premask_keeps_home_open_through_bus(cluster):
    d = Sptlb(cluster).balance(
        "local",
        timeout_s=4,
        config=CoopConfig(levels=("region", "host", "shard")),
    )
    assert d.violations.ok
    assert d.cooperation.timings["shard_rejections"] == 0  # premasked away
    level = ShardLocalityScheduler(cluster)
    x = np.asarray(d.assignment, np.int64)
    x0 = np.asarray(cluster.problem.assignment0, np.int64)
    moved = np.where(x != x0)[0]
    assert level.vet(Proposal(x, x0, moved)).size == 0


def test_shard_level_relax_lowers_bar_for_drain_residents(cluster):
    from repro.core.planner import PlanOutlook

    T = cluster.problem.num_tiers
    relax = np.zeros(T, bool)
    relax[1] = True
    plan = PlanOutlook(
        now=0,
        horizon=8,
        tier_factor=np.ones(T, np.float32),
        avoid_tiers=np.zeros(T, bool),
        slo_off_tiers=np.zeros(T, bool),
        pending=1,
        relax_home_tiers=relax,
        relax_latency_factor=2.0,
    )
    level = ShardLocalityScheduler(cluster)
    bar_before = level._bar.copy()
    level.relax(plan, cluster)
    x0 = np.asarray(cluster.problem.assignment0)
    resident = relax[x0]
    np.testing.assert_allclose(level._bar[resident], bar_before[resident] / 2.0)
    np.testing.assert_array_equal(level._bar[~resident], bar_before[~resident])


def test_shard_level_feedback_escalates_repeat_offenders(cluster):
    from repro.core.levels import BusState

    level = ShardLocalityScheduler(cluster, escalate_after=2)
    aff = shard_affinity_of(cluster)
    x0 = np.asarray(cluster.problem.assignment0)
    candidates = [
        n
        for n in range(len(x0))
        if aff[n].min() < level._bar[n] and int(np.argmin(aff[n])) != x0[n]
    ]
    app = candidates[0]
    bad = int(np.argmin(aff[app]))
    state = BusState(round=1, x=x0, x0=x0, rejections={})
    assert level.feedback(state) is None  # nothing escalated yet
    for _ in range(2):
        rejected = level.vet(_proposal_for(cluster, app, bad))
        assert rejected.tolist() == [app]
    mask = level.feedback(state)
    assert mask is not None and mask[app, bad]
    assert level.counters()["escalated"] == 1
    assert level.feedback(state) is None  # escalates once per app


# -- shard_skew scenario end-to-end ------------------------------------------


def test_shard_skew_scenario_runs_three_level_stack():
    from repro.sim import get_scenario, run_pair

    sc = get_scenario("shard_skew", num_apps=96, ticks=12, seed=0)
    assert sc.levels == ("region", "host", "shard")
    out = run_pair(sc)
    balanced = out["balanced"].summary()
    assert balanced["levels"] == ["region", "host", "shard"]
    assert "shard_misplaced_app_ticks" in balanced
    cmp = out["compare"]["shard_misplaced_app_ticks"]
    assert set(cmp) == {"baseline", "balanced", "ratio"}
    # the controller must not worsen co-location while rebalancing
    assert cmp["balanced"] <= cmp["baseline"]


def test_shard_skew_event_spikes_the_anchored_region():
    from repro.sim import ShardSkew, build_fleet, get_scenario

    sc = get_scenario("shard_skew", num_apps=96, ticks=12, seed=0)
    fleet = build_fleet(sc)
    flash_before = np.asarray(fleet.wl.flash).copy()
    ShardSkew(at=0, region=2, magnitude=5.0).apply(fleet)
    flash_after = np.asarray(fleet.wl.flash)
    hit = flash_after > flash_before + 1e-6
    assert hit.any()
    assert (fleet.cluster.app_region[hit] == 2).all()
    # surprises never declare advisories
    assert ShardSkew(at=0, region=2).declare() is None
