"""End-to-end behaviour: the full driver (SPTLB routing + train loop +
checkpoint/restart + failure rebalance) and the paper's orchestration."""
import numpy as np

from repro.core import CoopConfig, Sptlb, generate_cluster
from repro.launch.train import main as train_main


def test_train_driver_end_to_end(tmp_path):
    """Train a reduced model for a few steps with a mid-run failure +
    checkpoint restart; loss must be finite and improving-ish."""
    final_loss = train_main([
        "--arch", "smollm-360m", "--smoke",
        "--steps", "12", "--global-batch", "4", "--seq-len", "32",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--ckpt-every", "4", "--inject-failure-at", "6",
    ])
    assert np.isfinite(final_loss)
    assert final_loss < 6.0          # ln(256) ~ 5.55 at init; must not blow up


def test_train_driver_resume(tmp_path):
    train_main(["--arch", "smollm-360m", "--smoke", "--steps", "4",
                "--global-batch", "4", "--seq-len", "32",
                "--ckpt-dir", str(tmp_path / "c2"), "--ckpt-every", "2"])
    loss = train_main(["--arch", "smollm-360m", "--smoke", "--steps", "6",
                       "--global-batch", "4", "--seq-len", "32",
                       "--ckpt-dir", str(tmp_path / "c2"),
                       "--ckpt-every", "2", "--resume"])
    assert np.isfinite(loss)


def test_sptlb_full_pipeline_stages():
    """Fig. 1 stages produce a coherent decision record."""
    cluster = generate_cluster(num_apps=200, seed=3)
    decision = Sptlb(cluster).balance("local",
                                      config=CoopConfig(max_rounds=15))
    pm = decision.projected
    assert pm.util_frac.shape == (5, 2)
    assert pm.num_moved == len(pm.moved_apps)
    assert sum(pm.transitions.values()) == pm.num_moved
    assert decision.violations.ok
    assert decision.network_p99_ms >= 0
    assert 0 <= decision.difference_to_balance <= 1.5
