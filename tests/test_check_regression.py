"""The CI perf-regression gate must pass on the committed smoke records and
demonstrably fail on injected regressions (ISSUE 4 satellite)."""

import copy
import json
import os
import shutil

import pytest

from benchmarks.check_regression import SIM_SMOKE, SOLVER_SMOKE, main

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def _stage(tmp_path, name):
    baseline = tmp_path / "baseline"
    current = tmp_path / "current"
    baseline.mkdir(exist_ok=True)
    current.mkdir(exist_ok=True)
    shutil.copy(os.path.join(REPO_ROOT, name), baseline / name)
    shutil.copy(os.path.join(REPO_ROOT, name), current / name)
    return baseline, current


def _rewrite(directory, name, mutate):
    path = directory / name
    record = json.loads(path.read_text())
    mutate(record)
    path.write_text(json.dumps(record))


def _run(baseline, current):
    return main(["--baseline", str(baseline), "--current", str(current)])


def test_gate_passes_on_committed_smoke_records(tmp_path, capsys):
    for name in (SIM_SMOKE, SOLVER_SMOKE):
        _stage(tmp_path, name)
    assert _run(tmp_path / "baseline", tmp_path / "current") == 0
    out = capsys.readouterr().out
    assert "REGRESSION" not in out
    assert "checks passed" in out


def test_gate_fails_on_violation_ratio_regression(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)

    def worsen(record):
        scenario = sorted(record)[0]
        block = record[scenario]["compare"]["slo_violation_ticks"]
        block["ratio"] = (block["ratio"] or 0.0) + 0.5

    _rewrite(current, SIM_SMOKE, worsen)
    assert _run(baseline, current) == 1
    assert "slo_violation_ticks/ratio" in capsys.readouterr().out


def test_gate_treats_null_ratio_as_worst_case(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)

    def nullify(record):
        scenario = sorted(record)[0]
        record[scenario]["compare"]["slo_violation_ticks"]["ratio"] = None

    _rewrite(current, SIM_SMOKE, nullify)
    assert _run(baseline, current) == 1


def test_gate_fails_on_throughput_collapse(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SOLVER_SMOKE)

    def collapse(record):
        for size in record["local_search"].values():
            if isinstance(size, dict) and "batch16" in size:
                size["batch16"]["moves_per_s"] /= 10.0

    _rewrite(current, SOLVER_SMOKE, collapse)
    assert _run(baseline, current) == 1
    assert "moves_per_s" in capsys.readouterr().out


def test_gate_tolerates_cross_machine_wall_clock(tmp_path):
    baseline, current = _stage(tmp_path, SOLVER_SMOKE)

    def slower(record):
        for size in record["local_search"].values():
            if isinstance(size, dict) and "batch16" in size:
                size["batch16"]["moves_per_s"] /= 2.0  # a slower runner, not a bug

    _rewrite(current, SOLVER_SMOKE, slower)
    assert _run(baseline, current) == 0


def test_gate_fails_when_budget_compliance_is_lost(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)
    budgeted = [
        name
        for name, rec in json.loads((baseline / SIM_SMOKE).read_text()).items()
        # Overload records carry the binary/utility pair instead of a
        # static-vs-balanced compare.
        if "compare" in rec and rec["compare"]["movement"]["within_budget"]
    ]
    assert budgeted, "at least one scenario must run under a movement budget"

    def overrun(record):
        record[budgeted[0]]["compare"]["movement"]["within_budget"] = False

    _rewrite(current, SIM_SMOKE, overrun)
    assert _run(baseline, current) == 1
    assert "within_budget" in capsys.readouterr().out


def test_gate_fails_on_retrace_creep(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)

    def creep(record):
        # Chaos records carry degraded/oracle instead of balanced.
        scenario = sorted(n for n, r in record.items() if "balanced" in r)[0]
        record[scenario]["balanced"]["solver_retraces"] += 5

    _rewrite(current, SIM_SMOKE, creep)
    assert _run(baseline, current) == 1
    assert "solver_retraces" in capsys.readouterr().out


def _chaos_scenarios(directory):
    record = json.loads((directory / SIM_SMOKE).read_text())
    return sorted(n for n, r in record.items() if "chaos" in r)


def test_gate_fails_on_unsafe_move(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)
    names = _chaos_scenarios(baseline)
    assert names, "the chaos family must be in the committed smoke record"

    def violate(record):
        record[names[0]]["chaos"]["unsafe_moves"] = 1

    _rewrite(current, SIM_SMOKE, violate)
    assert _run(baseline, current) == 1
    assert "unsafe_moves" in capsys.readouterr().out


def test_gate_fails_when_recovery_is_lost(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)
    names = _chaos_scenarios(baseline)

    def stuck(record):
        record[names[0]]["chaos"]["recovered"] = False

    _rewrite(current, SIM_SMOKE, stuck)
    assert _run(baseline, current) == 1
    assert "recovered" in capsys.readouterr().out


def test_gate_fails_on_degraded_vs_oracle_blowup(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)
    names = _chaos_scenarios(baseline)

    def blowup(record):
        block = record[names[0]]["chaos"]["degraded_vs_oracle"]
        block["ratio"] = block["ratio"] * 2.0 + 1.0

    _rewrite(current, SIM_SMOKE, blowup)
    assert _run(baseline, current) == 1
    assert "degraded_vs_oracle" in capsys.readouterr().out


def test_gate_fails_when_chaos_scenario_dropped(tmp_path, capsys):
    # The named per-scenario ratio checks exist exactly for this: a
    # baseline regeneration that silently dropped a chaos scenario would
    # sail through every wildcard.
    baseline, current = _stage(tmp_path, SIM_SMOKE)
    names = _chaos_scenarios(baseline)

    def drop(record):
        for name in names:
            del record[name]

    _rewrite(baseline, SIM_SMOKE, drop)
    _rewrite(current, SIM_SMOKE, drop)
    assert _run(baseline, current) == 1
    assert "matched no baseline metrics" in capsys.readouterr().out


def _overload_scenarios(directory):
    record = json.loads((directory / SIM_SMOKE).read_text())
    return sorted(n for n, r in record.items() if "overload" in r)


def test_gate_fails_on_infeasible_admission(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)
    names = _overload_scenarios(baseline)
    assert names, "the overload family must be in the committed smoke record"

    def violate(record):
        record[names[0]]["overload"]["infeasible_admissions"] = 1

    _rewrite(current, SIM_SMOKE, violate)
    assert _run(baseline, current) == 1
    assert "infeasible_admissions" in capsys.readouterr().out


def test_gate_fails_when_utility_improvement_collapses(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)

    def collapse(record):
        for name in ("overload_surge", "overload_flash"):
            block = record[name]["overload"]["delivered_utility_ratio"]
            block["improvement"] = 0.9     # worse than the binary baseline
            block["utility"] = block["binary"] * 0.9

    _rewrite(current, SIM_SMOKE, collapse)
    assert _run(baseline, current) == 1
    assert "delivered_utility_ratio" in capsys.readouterr().out


def test_gate_fails_when_overload_scenario_dropped(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)
    names = _overload_scenarios(baseline)

    def drop(record):
        for name in names:
            del record[name]

    _rewrite(baseline, SIM_SMOKE, drop)
    _rewrite(current, SIM_SMOKE, drop)
    assert _run(baseline, current) == 1
    assert "matched no baseline metrics" in capsys.readouterr().out


def test_gate_fails_on_missing_metric(tmp_path, capsys):
    baseline, current = _stage(tmp_path, SIM_SMOKE)

    def drop(record):
        scenario = sorted(record)[0]
        del record[scenario]["compare"]["slo_violation_ticks"]

    _rewrite(current, SIM_SMOKE, drop)
    assert _run(baseline, current) == 1
    assert "missing" in capsys.readouterr().out


def test_gate_fails_when_current_record_is_absent(tmp_path):
    baseline, current = _stage(tmp_path, SIM_SMOKE)
    os.remove(current / SIM_SMOKE)
    assert _run(baseline, current) == 1


def test_gate_skips_cleanly_without_baselines(tmp_path):
    empty = tmp_path / "empty"
    empty.mkdir()
    assert _run(empty, REPO_ROOT) == 0


def test_checks_cover_both_records():
    """Every gated file that exists in the repo is actually exercised."""
    from benchmarks.check_regression import CHECKS

    gated_files = {check.file for check in CHECKS}
    assert gated_files == {SIM_SMOKE, SOLVER_SMOKE}


@pytest.mark.parametrize("name", [SIM_SMOKE, SOLVER_SMOKE])
def test_committed_smoke_records_exist(name):
    """The gate is only meaningful while the baselines stay committed."""
    assert os.path.exists(os.path.join(REPO_ROOT, name))


def test_expand_handles_nested_wildcards():
    from benchmarks.check_regression import _expand

    record = {"a": {"x": {"v": 1}, "y": {"v": 2}}, "b": {"z": {"v": 3}}}
    paths = _expand(record, ("*", "*", "v"))
    assert paths == [("a", "x", "v"), ("a", "y", "v"), ("b", "z", "v")]
    assert _expand(copy.deepcopy(record), ("a", "missing", "v")) == []
