"""Maintenance planner: advisory schedules, time-phased capacity targets,
maintenance placement mode, movement pricing, and cost-budget trimming
(ISSUE 4 tentpole)."""

import numpy as np
import pytest

from repro.core import generate_cluster
from repro.core.hierarchy import REGION_LATENCY_BUDGET_MS, RegionScheduler
from repro.core.planner import (
    CAPACITY,
    OUTAGE,
    RESTORE,
    Advisory,
    MaintenancePlanner,
    PlannerConfig,
    move_costs,
    movement_cost_of,
)
from repro.core.problem import pad_problem
from repro.core.sptlb import Sptlb


@pytest.fixture(scope="module")
def cluster():
    return generate_cluster(num_apps=120, seed=3)


# ---------------------------------------------------------------------------
# advisory schedule
# ---------------------------------------------------------------------------


def test_declared_schedule_is_piecewise_constant():
    planner = MaintenancePlanner(
        [
            Advisory(at=10, kind=CAPACITY, tier=2, scale=0.4),
            Advisory(at=14, kind=CAPACITY, tier=2, scale=0.05),
            Advisory(at=6, kind=OUTAGE, region=1),
            Advisory(at=12, kind=RESTORE, region=1),
        ]
    )
    assert planner.declared_scale(2, 9) == 1.0
    assert planner.declared_scale(2, 10) == 0.4
    assert planner.declared_scale(2, 13) == 0.4
    assert planner.declared_scale(2, 20) == 0.05
    assert planner.declared_scale(0, 20) == 1.0  # undeclared tier
    assert planner.declared_down(5) == set()
    assert planner.declared_down(6) == {1}
    assert planner.declared_down(12) == set()


# ---------------------------------------------------------------------------
# time-phased capacity targets
# ---------------------------------------------------------------------------


def test_outlook_phases_targets_toward_the_event(cluster):
    planner = MaintenancePlanner(
        [
            Advisory(at=10, kind=CAPACITY, tier=2, scale=0.4),
            Advisory(at=14, kind=CAPACITY, tier=2, scale=0.05),
        ],
        PlannerConfig(horizon=8),
    )
    # Both events beyond the horizon: nothing to plan against yet.
    assert not planner.outlook(0, cluster).active

    # Event 8 ticks out has just entered the window: barely tightened.
    far = planner.outlook(2, cluster)
    assert far.active
    assert 0.9 < far.tier_factor[2] < 1.0

    # Halfway there: weight (8 - 5 + 1) / 8 = 0.5 of the 0.6 step.
    mid = planner.outlook(5, cluster)
    assert mid.tier_factor[2] == pytest.approx(0.7, abs=1e-6)
    assert not mid.relax_home_tiers[2]  # 0.4 is not a deep drain

    # One tick before the step fires the target IS the declared scale, the
    # deep follow-up step (0.05 < deep_drain_threshold) arms maintenance
    # placement mode, and the will-drain tier is premasked (< 0.5).
    close = planner.outlook(9, cluster)
    assert close.tier_factor[2] == pytest.approx(0.4, abs=1e-6)
    assert close.relax_home_tiers[2]
    assert close.avoid_tiers[2]

    # Monotone approach: the target never loosens as the event nears.
    factors = [planner.outlook(now, cluster).tier_factor[2] for now in range(2, 10)]
    assert all(a >= b - 1e-6 for a, b in zip(factors, factors[1:]))


def test_outlook_is_relative_to_current_declared_scale(cluster):
    planner = MaintenancePlanner(
        [
            Advisory(at=4, kind=CAPACITY, tier=1, scale=0.5),
            Advisory(at=20, kind=CAPACITY, tier=1, scale=1.0),
        ],
        PlannerConfig(horizon=8),
    )
    # Mid-drain (the 0.5 already fired): only the restore is ahead, and a
    # restore never tightens — the reactive path refills for free.
    assert planner.outlook(14, cluster).tier_factor[1] == pytest.approx(1.0)


def test_outage_outlook_premasks_and_desanctions_overlapping_tiers(cluster):
    planner = MaintenancePlanner(
        [
            Advisory(at=6, kind=OUTAGE, region=0),
            Advisory(at=12, kind=RESTORE, region=0),
        ],
        PlannerConfig(horizon=6),
    )
    out = planner.outlook(3, cluster)
    affected = cluster.tier_regions[:, 0]
    assert affected.any()
    assert out.active
    assert out.slo_off_tiers[affected].all()
    assert out.avoid_tiers[affected].all()
    assert (out.tier_factor[affected] < 1.0).all()
    assert not out.slo_off_tiers[~affected].any()

    # Already inside the declared window: the live cluster reflects the
    # outage, and the upcoming restore is not a tightening — inactive.
    assert not planner.outlook(8, cluster).active


def test_apply_builds_the_planning_problem(cluster):
    planner = MaintenancePlanner(
        [Advisory(at=2, kind=CAPACITY, tier=2, scale=0.3)],
        PlannerConfig(horizon=4),
    )
    out = planner.outlook(1, cluster)
    problem = cluster.problem
    planned = out.apply(problem)
    np.testing.assert_allclose(
        np.asarray(planned.capacity),
        np.asarray(problem.capacity) * out.tier_factor[:, None],
        rtol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(planned.task_limit),
        np.asarray(problem.task_limit) * out.tier_factor,
        rtol=1e-6,
    )
    # Will-drain tier is avoided for everyone except its incumbents (the
    # premask home-column convention: staying put stays legal).
    assert out.avoid_tiers[2]
    x0 = np.asarray(problem.assignment0)
    avoid = np.asarray(planned.avoid)
    assert avoid[x0 != 2, 2].all()
    assert not avoid[x0 == 2, 2].any()


# ---------------------------------------------------------------------------
# maintenance placement mode (relaxed region budgets)
# ---------------------------------------------------------------------------


def test_per_app_region_budgets_relax_feasibility(cluster):
    strict = RegionScheduler(cluster)
    n = cluster.problem.num_apps
    relaxed_budget = np.full(n, REGION_LATENCY_BUDGET_MS * 100.0, np.float32)
    relaxed = RegionScheduler(cluster, latency_budget_ms=relaxed_budget)
    feas_strict = strict.feasibility_matrix()
    feas_relaxed = relaxed.feasibility_matrix()
    # Relaxing only ever adds destinations, and a huge budget opens all of
    # them (every tier has hosts somewhere).
    assert (feas_relaxed | feas_strict).sum() == feas_relaxed.sum()
    assert feas_relaxed.sum() > feas_strict.sum()
    # check_many agrees with the matrix on both schedulers.
    apps = np.arange(n)
    tiers = np.full(n, 2)
    np.testing.assert_array_equal(strict.check_many(apps, tiers), feas_strict[:, 2])
    np.testing.assert_array_equal(relaxed.check_many(apps, tiers), feas_relaxed[:, 2])


# ---------------------------------------------------------------------------
# movement pricing + cost budgets
# ---------------------------------------------------------------------------


def test_move_costs_mean_one_over_live_apps(cluster):
    problem = cluster.problem
    costs = move_costs(problem)
    valid = np.asarray(problem.valid)
    assert costs[valid].mean() == pytest.approx(1.0, abs=1e-5)
    # Demand-proportional: the hungriest live app costs the most.
    load = np.asarray(problem.demand).sum(axis=1)
    assert costs.argmax() == load.argmax()
    # Padding rows are inert and free.
    padded = pad_problem(problem, 256)
    costs_padded = move_costs(padded)
    assert (costs_padded[int(valid.sum()) :] == 0).all()
    np.testing.assert_allclose(costs_padded[: costs.size], costs, rtol=1e-6)


def test_movement_cost_of_counts_and_prices():
    x0 = np.array([0, 1, 2, 0])
    x = np.array([1, 1, 0, 0])
    assert movement_cost_of(x, x0) == 2.0
    costs = np.array([0.5, 9.0, 2.0, 9.0], np.float32)
    assert movement_cost_of(x, x0, costs) == pytest.approx(2.5)


def test_cost_budget_trims_the_decision(cluster):
    baseline = Sptlb(cluster).balance("local", timeout_s=4)
    assert baseline.movement_cost > 2.0
    assert baseline.cooperation.timings["budget_trimmed"] == 0

    budget = baseline.movement_cost / 2.0
    capped = Sptlb(cluster).balance(
        "local",
        timeout_s=4,
        move_cost=move_costs(cluster.problem),
        cost_budget=budget,
    )
    assert capped.movement_cost <= budget + 1e-6
    assert capped.cooperation.timings["budget_trimmed"] > 0
    assert capped.cooperation.timings["movement_cost"] == pytest.approx(
        capped.movement_cost
    )
    assert capped.violations.ok
    # Trimmed decisions still improve on doing nothing.
    assert capped.projected.num_moved > 0


def test_round_costs_are_priced_every_round(cluster):
    decision = Sptlb(cluster).balance(
        "local", timeout_s=4, move_cost=move_costs(cluster.problem)
    )
    round_costs = decision.cooperation.timings["round_costs"]
    assert len(round_costs) >= 1
    assert all(c >= 0.0 for c in round_costs)
