"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode).

Shapes / dtypes / feature flags swept per kernel, as required for (c).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import random_problem_arrays
from repro.kernels import ops

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 64),       # MHA
    (2, 256, 8, 2, 64),       # GQA 4:1
    (1, 200, 4, 2, 80),       # ragged seq, zamba head_dim
    (1, 256, 16, 8, 128),     # gemma2-like ratio
    (2, 64, 15, 5, 64),       # smollm heads (non-pow2)
])
def test_flash_attention_shapes(B, S, H, KV, D):
    q = jnp.asarray(RNG.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, KV, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, KV, D)), jnp.float32)
    o_ref = ops.flash_attention(q, k, v, impl="xla")
    o_pal = ops.flash_attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(o_pal, o_ref, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("window,softcap,causal", [
    (None, None, True),
    (64, None, True),
    (None, 50.0, True),
    (64, 50.0, True),
    (None, None, False),
])
def test_flash_attention_features(window, softcap, causal):
    B, S, H, KV, D = 1, 256, 4, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, KV, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, KV, D)), jnp.float32)
    kw = dict(causal=causal, window=window, softcap=softcap)
    o_ref = ops.flash_attention(q, k, v, impl="xla", **kw)
    o_pal = ops.flash_attention(q, k, v, impl="pallas", **kw)
    np.testing.assert_allclose(o_pal, o_ref, atol=3e-5, rtol=3e-5)


def test_flash_attention_bf16():
    B, S, H, KV, D = 1, 128, 4, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, KV, D)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, KV, D)), jnp.bfloat16)
    o_ref = ops.flash_attention(q, k, v, impl="xla")
    o_pal = ops.flash_attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# mamba SSD chunk scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N", [
    (1, 128, 2, 64, 64),
    (2, 256, 4, 64, 64),
    (1, 384, 8, 32, 16),      # reduced-config dims
])
def test_mamba_scan_shapes(B, S, H, P, N):
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, H), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    D = jnp.asarray(RNG.uniform(0.5, 1.5, H), jnp.float32)
    y_ref, h_ref = ops.mamba_scan(x, dt, A, Bm, Cm, D, impl="xla")
    y_pal, h_pal = ops.mamba_scan(x, dt, A, Bm, Cm, D, impl="pallas")
    np.testing.assert_allclose(y_pal, y_ref, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(h_pal, h_ref, atol=5e-5, rtol=5e-5)


def test_mamba_scan_carry_state():
    """Chunked scan with a carried-in state h0 matches the reference."""
    B, S, H, P, N = 1, 256, 2, 64, 64
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, H), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    D = jnp.asarray(RNG.uniform(0.5, 1.5, H), jnp.float32)
    h0 = jnp.asarray(RNG.normal(0, 0.3, (B, H, P, N)), jnp.float32)
    y_ref, h_ref = ops.mamba_scan(x, dt, A, Bm, Cm, D, h0, impl="xla")
    y_pal, h_pal = ops.mamba_scan(x, dt, A, Bm, Cm, D, h0, impl="pallas")
    np.testing.assert_allclose(y_pal, y_ref, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(h_pal, h_ref, atol=5e-5, rtol=5e-5)


def test_mamba_chunked_matches_recurrent():
    """The chunked algorithm equals the step-by-step recurrence."""
    from repro.models.mamba2 import ssd_step
    B, S, H, P, N = 1, 128, 2, 16, 16
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, H), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (B, S, N)), jnp.float32)
    D = jnp.asarray(RNG.uniform(0.5, 1.5, H), jnp.float32)
    y_chunk, h_chunk = ops.mamba_scan(x, dt, A, Bm, Cm, D, impl="xla")

    h = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y_t, h = ssd_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D)
        ys.append(y_t)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_rec, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h_chunk, h, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# move_eval
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,T", [(64, 5), (300, 5), (500, 17), (1000, 128)])
def test_move_eval_matches_ref(N, T):
    args = random_problem_arrays(N, T, seed=N + T)
    d_ref = ops.move_eval(*args, impl="xla")
    d_pal = ops.move_eval(*args, impl="pallas")
    scale = float(jnp.max(jnp.abs(d_ref))) + 1e-9
    np.testing.assert_allclose(d_pal / scale, d_ref / scale, atol=1e-5)


def test_move_eval_delta_is_exact():
    """delta[n, t] must equal objective(after move) - objective(before)."""
    from repro.core import generate_cluster, objective
    from repro.core.delta import move_delta_cost
    from repro.core.solver_local import _weights_vector
    from repro.core.problem import tier_loads

    cluster = generate_cluster(num_apps=40, seed=2)
    p = cluster.problem
    x = p.assignment0
    util, tasks = tier_loads(p, x)
    delta = move_delta_cost(p.demand, p.tasks, p.criticality, x,
                            p.assignment0, p.capacity, p.task_limit,
                            p.ideal_frac, p.ideal_task_frac, util, tasks,
                            _weights_vector(p))
    base = float(objective(p, x))
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(p.num_apps))
        t = int(rng.integers(p.num_tiers))
        moved = x.at[n].set(t)
        true_delta = float(objective(p, moved)) - base
        assert abs(float(delta[n, t]) - true_delta) < 1e-3 * max(
            1.0, abs(true_delta)), (n, t)


@pytest.mark.parametrize("N,T,moves_left", [(300, 5, 5), (500, 17, 0)])
def test_move_eval_best_matches_ref(N, T, moves_left):
    """Fused sweep+mask+argmin kernel vs the core.delta oracle."""
    args = random_problem_arrays(N, T, seed=N + T)
    rng = np.random.default_rng(N)
    feas = jnp.asarray(rng.random((N, T)) > 0.2)
    ml = jnp.int32(moves_left)
    s_ref, t_ref = ops.move_eval_best(*args, feas, ml, impl="xla")
    s_pal, t_pal = ops.move_eval_best(*args, feas, ml, impl="pallas")
    finite = np.isfinite(np.asarray(s_ref))
    # same apps marked infeasible (+inf)
    assert np.array_equal(np.isfinite(np.asarray(s_pal)), finite)
    scale = float(jnp.max(jnp.abs(jnp.where(finite, s_ref, 0.0)))) + 1e-9
    np.testing.assert_allclose(np.asarray(s_pal)[finite] / scale,
                               np.asarray(s_ref)[finite] / scale, atol=1e-5)
    assert np.array_equal(np.asarray(t_pal)[finite], np.asarray(t_ref)[finite])


def test_solver_with_fused_best_pallas(cluster300):
    """Batched LocalSearch end-to-end on the fused-best kernel path."""
    import functools
    from repro.core import LocalSearchConfig, solve_local, validate
    from repro.kernels.move_eval import move_eval_best_pallas

    p = cluster300.problem
    res = solve_local(
        p, LocalSearchConfig(max_iters=8, batch_moves=8),
        move_best_fn=functools.partial(move_eval_best_pallas, interpret=True))
    assert validate(p, res.assignment).ok
    res_ref = solve_local(p, LocalSearchConfig(max_iters=8, batch_moves=8))
    assert np.array_equal(np.asarray(res.assignment),
                          np.asarray(res_ref.assignment))


def test_solver_with_pallas_move_eval(cluster300):
    """LocalSearch runs end-to-end on the Pallas kernel (interpret mode)."""
    import functools
    from repro.core import LocalSearchConfig, solve_local, validate
    from repro.kernels.move_eval import move_eval_pallas

    p = cluster300.problem
    res = solve_local(p, LocalSearchConfig(max_iters=8),
                      move_eval_fn=functools.partial(move_eval_pallas,
                                                     interpret=True))
    assert validate(p, res.assignment).ok


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Smax,H,KV,D,kv_len,softcap", [
    (2, 512, 4, 2, 64, 300, None),
    (1, 1024, 8, 8, 128, 1024, None),     # MHA, cache full
    (2, 640, 16, 8, 80, 17, 50.0),        # nearly-empty cache + softcap
    (1, 512, 15, 5, 64, 400, None),       # smollm head counts
])
def test_flash_decode_matches_ref(B, Smax, H, KV, D, kv_len, softcap):
    rng = np.random.default_rng(B * Smax + kv_len)
    q = jnp.asarray(rng.normal(0, 1, (B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, Smax, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, Smax, KV, D)), jnp.float32)
    o_ref = ops.flash_decode(q, k, v, kv_len, softcap=softcap, impl="xla")
    o_pal = ops.flash_decode(q, k, v, kv_len, softcap=softcap, impl="pallas")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=3e-5, rtol=3e-5)


def test_flash_decode_bf16():
    rng = np.random.default_rng(7)
    B, Smax, H, KV, D = 2, 512, 4, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (B, 1, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (B, Smax, KV, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (B, Smax, KV, D)), jnp.bfloat16)
    o_ref = ops.flash_decode(q, k, v, 200, impl="xla")
    o_pal = ops.flash_decode(q, k, v, 200, impl="pallas")
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=3e-2, rtol=3e-2)
